"""Serve a small model with batched requests through the wave engine.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --new-tokens 12
"""

import argparse

import numpy as np
import jax

from repro.configs.base import ModelConfig
from repro.models.api import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4, d_model=128,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=1024,
                      dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_batch=args.max_batch,
                         max_len=args.prompt_len + args.new_tokens + 2)

    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    engine.run(reqs, pad_to=args.prompt_len)
    for r in reqs:
        print(f"req {r.uid}: {r.out_tokens}")
    s = engine.stats
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"\n{s.waves} waves, {s.decode_steps} decode steps, "
          f"{total_new} tokens; prefill {s.prefill_s:.2f}s decode {s.decode_s:.2f}s "
          f"({total_new / max(s.decode_s, 1e-9):,.0f} tok/s decode)")


if __name__ == "__main__":
    main()
