"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU.

Uses a scaled-down xlstm-family config (~100M params at full vocab) through
the REAL production path: config → model → data pipeline → fault-tolerant
train loop with async checkpointing — the same code the 512-chip launch uses.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --d-model 256
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import pipeline_for
from repro.models.api import build_model
from repro.optim.adamw import adamw_init
from repro.train.loop import LoopState, train_loop
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=50304)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-demo", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1), d_ff=args.d_model * 4,
        vocab_size=args.vocab, dtype="float32",
    )
    model = build_model(cfg)
    print(f"model: {cfg.n_params()/1e6:.1f}M params "
          f"({cfg.n_layers}L x {cfg.d_model}d, vocab {cfg.vocab_size})")

    params = model.init(jax.random.key(0))
    tcfg = TrainConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                       ckpt_every=50, ckpt_dir=args.ckpt_dir)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    pipe = pipeline_for(cfg, ShapeConfig("train", args.seq, args.batch, "train"))
    batches = lambda i: jax.tree.map(jnp.asarray, pipe(i))

    state = LoopState(params=params, opt_state=adamw_init(params), step=0)
    t0 = time.perf_counter()
    state, report = train_loop(state, step, batches, tcfg, max_steps=args.steps)
    dt = time.perf_counter() - t0
    tok_s = args.steps * args.seq * args.batch / dt
    print(f"\ntrained {report.final_step} steps in {dt:.1f}s ({tok_s:,.0f} tok/s)")
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"(stragglers flagged: {report.stragglers}, restarts: {report.restarts})")
    assert report.losses[-1] < report.losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
