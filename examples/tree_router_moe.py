"""The paper's technique on the LM serving hot path: tree-routed MoE.

Trains a small phi3.5-family MoE whose router is a SOFT decision tree
(differentiable), then serves it with the router HARDENED into the paper's
breadth-first branchless encoding and evaluated with speculative pointer
jumping (Procedure 4/5) — per-token classification into E experts, exactly
the paper's image-segmentation problem shape transposed to tokens.

    PYTHONPATH=src python examples/tree_router_moe.py --steps 60
"""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import pipeline_for
from repro.models.api import build_model
from repro.models.layers import moe as moel
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    assert cfg.moe.router == "tree"
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"MoE: {cfg.moe.n_experts} experts, top-{cfg.moe.top_k}, "
          f"router = depth-{cfg.moe.tree_depth()} soft decision tree")

    # --- train with the soft (differentiable) tree router ---
    pipe = pipeline_for(cfg, ShapeConfig("t", 64, 4, "train"))
    step = jax.jit(make_train_step(model, TrainConfig(lr=2e-3, warmup_steps=5,
                                                      total_steps=args.steps)))
    opt = adamw_init(params)
    first = last = None
    for i in range(args.steps):
        batch = jax.tree.map(jnp.asarray, pipe(i))
        params, opt, metrics = step(params, opt, batch)
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
        if i % 20 == 0:
            print(f"  step {i:3d}  loss {last:.4f}  aux {float(metrics['aux']):.5f}")
    print(f"soft-tree training: loss {first:.3f} -> {last:.3f}")

    # --- serve: harden the tree, route with speculative evaluation ---
    batch = jax.tree.map(jnp.asarray, pipe(999))
    lp0 = jax.tree.map(lambda x: x[0], params["layers"])["moe"]
    e_pad = lp0["wi"].shape[0]
    x = jax.random.normal(jax.random.key(1), (1, 512, cfg.d_model), jnp.float32)
    experts_hard = moel.hard_tree_route(lp0, x, cfg=cfg, e_pad=e_pad)
    probs_soft = moel.router_probs(lp0, x, cfg=cfg, e_pad=e_pad)
    agree = float((jnp.argmax(probs_soft, -1) == experts_hard).mean())
    # NOTE: greedy hard descent equals the soft argmax only where gates are
    # saturated (σ far from 0.5); at temperature 1.0 mid-training some tokens
    # sit near decision boundaries.  As τ→0 agreement → 100 %
    # (property-tested in tests/test_cart_and_forest.py).
    z = x.astype(jnp.float32) @ lp0["router_proj"] - lp0["router_thr"]
    saturated = float((jnp.abs(jax.nn.sigmoid(z) - 0.5) > 0.4).mean())
    print(f"hardened speculative router vs soft argmax agreement: {agree:.1%} "
          f"(gates saturated: {saturated:.1%})")

    counts = np.bincount(np.asarray(experts_hard).ravel(), minlength=cfg.moe.n_experts)
    print(f"expert load (hard routing): {counts.tolist()}")

    # full serving forward with the hard router
    logits, _ = model.forward(params, batch, serve_hard_tree=True)
    print(f"served logits: {logits.shape}, finite={bool(jnp.isfinite(logits).all())}")
    assert agree > 0.5, "hardening should track the learned routing"
    assert len([c for c in counts if c > 0]) >= 2, "router must use several experts"


if __name__ == "__main__":
    main()
