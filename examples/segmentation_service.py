"""The paper's own application as an on-line service: real-time image
segmentation with the speculative tree evaluator.

Simulates the paper's procedure-room workload: a stream of 256×256 "images"
(65 536 pixel records each) classified on-line; reports per-image latency
with the speculative kernel — the paper's deterministic-latency argument
(§3.3: "uniform evaluation times needed in deterministic, real-time
applications") shows up as the tight min/max spread.

    PYTHONPATH=src python examples/segmentation_service.py --images 5
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CartConfig, breadth_first_encode, train_cart, tree_depth
from repro.core.eval_speculative import eval_speculative
from repro.data.segmentation import make_segmentation, replicated_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=5)
    args = ap.parse_args()

    data = make_segmentation(seed=0)
    root = train_cart(data.x_train, data.y_train, 7,
                      CartConfig(max_depth=12, min_samples_split=8, min_gain=4e-3))
    enc = breadth_first_encode(root)
    d = tree_depth(enc)
    print(f"classifier: N={enc.n_nodes} depth={d} (trained offline, as in the paper)")

    tree_args = (jnp.asarray(enc.attr_idx), jnp.asarray(enc.threshold),
                 jnp.asarray(enc.child), jnp.asarray(enc.class_val))
    classify = jax.jit(lambda r: eval_speculative(
        r, *tree_args, max_depth=d, jumps_per_round=2, use_onehot_matmul=True))

    lat = []
    for i in range(args.images):
        img, _ = replicated_dataset(data, 65_536, seed=i + 1)
        t0 = time.perf_counter()
        classes = np.asarray(classify(jnp.asarray(img)))   # H2D + eval + D2H
        lat.append((time.perf_counter() - t0) * 1e3)
        hist = np.bincount(classes, minlength=7)
        print(f"image {i}: {lat[-1]:7.2f} ms  class histogram {hist.tolist()}")
    a = np.asarray(lat[1:]) if len(lat) > 1 else np.asarray(lat)
    print(f"\nsteady-state latency: mean {a.mean():.2f} ms  "
          f"min {a.min():.2f}  max {a.max():.2f}  "
          f"(spread {(a.max()-a.min())/a.mean()*100:.1f}% — the paper's "
          f"time-uniformity argument)")


if __name__ == "__main__":
    main()
