"""Quickstart: the paper's full pipeline in ~60 seconds on CPU.

1. Generate the UCI-Image-Segmentation synthetic twin (19 attrs, 7 classes).
2. Train a CART classifier (the substrate the paper got from Orange).
3. Encode it breadth-first + branchless (Procedure 1).
4. Evaluate 65 536 records with all three algorithms — serial (P2),
   data-parallel (P3), speculative (P4/5) — plus the Pallas TPU kernel in
   interpret mode, verifying they agree exactly.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    CartConfig, accuracy, breadth_first_encode, eval_serial,
    eval_data_parallel_tree, eval_speculative_tree, train_cart, tree_depth,
)
from repro.core.analysis import mean_traversal_depth, observed_depths
from repro.data.segmentation import make_segmentation, replicated_dataset
from repro.kernels.tree_eval import tree_eval


def main():
    print("1) synthetic UCI Image Segmentation twin")
    data = make_segmentation(seed=0)
    print(f"   train {data.x_train.shape}, test {data.x_test.shape}")

    print("2) CART training (Gini, axis-aligned)")
    t0 = time.perf_counter()
    root = train_cart(data.x_train, data.y_train, 7,
                      CartConfig(max_depth=12, min_samples_split=8, min_gain=4e-3))
    enc = breadth_first_encode(root)
    print(f"   tree: N={enc.n_nodes} leaves={enc.n_leaves} depth={tree_depth(enc)} "
          f"({time.perf_counter()-t0:.1f}s)  "
          f"test acc={accuracy(eval_serial(enc, data.x_test), data.y_test):.3f}")

    print("3) replicate to 65 536 records (a 256x256 'image')")
    rec, _ = replicated_dataset(data)
    d_mu = mean_traversal_depth(observed_depths(enc, rec[:2048]))
    print(f"   mean traversal depth d_mu = {d_mu:.2f}")

    print("4) evaluate with every algorithm")
    d = tree_depth(enc)
    ref = eval_serial(enc, rec[:4096])
    outs = {
        "P3 data-parallel": np.asarray(eval_data_parallel_tree(enc, rec[:4096], max_depth=d)),
        "P4/5 speculative": np.asarray(eval_speculative_tree(enc, rec[:4096], max_depth=d)),
        "P4/5 spec (MXU one-hot)": np.asarray(
            eval_speculative_tree(enc, rec[:4096], max_depth=d, use_onehot_matmul=True)),
        "Pallas speculative kernel": np.asarray(
            tree_eval(rec[:4096], enc, algorithm="speculative")),
        "Pallas data-parallel kernel": np.asarray(
            tree_eval(rec[:4096], enc, algorithm="data_parallel")),
    }
    for name, out in outs.items():
        ok = np.array_equal(out, ref)
        print(f"   {name:32s} {'EXACT MATCH' if ok else 'MISMATCH!'}")
        assert ok
    print("\nall evaluators agree — Procedures 1-5 verified end to end")


if __name__ == "__main__":
    main()
