"""granite-moe-3b-a800m — 40-expert top-8 fine-grained MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, 40e top-8.
Tree-router integration: 40 leaves → depth-6 padded tree; top-8 routing via
an 8-tree forest on the serving path (core/forest.route_topk).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, router="tree", router_tree_depth=6),
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=48,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    dtype="float32",
    moe=MoEConfig(n_experts=5, top_k=3, d_ff=64, router="tree", router_tree_depth=3,
                  capacity_factor=8.0),
)
