"""The paper's own experiment configuration (§4).

UCI Image Segmentation: 19 continuous attributes, 7 classes, 2310 train +
2099 test records; tree N=31 nodes / 16 leaves / depth 11; dataset replicated
to 65 536 records (a 256×256 image).  The offline container cannot download
UCI, so ``data/segmentation.py`` generates a statistically matched synthetic
twin with identical shapes and cardinalities.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperExperimentConfig:
    n_attrs: int = 19
    n_classes: int = 7
    n_train: int = 2310
    n_test: int = 2099
    dataset_records: int = 65_536          # 256×256 "image"
    tree_nodes: int = 31
    tree_leaves: int = 16
    tree_depth: int = 11
    n_timing_iters: int = 500
    jumps_per_round: int = 2               # paper: 2 reductions/loop optimal
    record_group: int = 16                 # paper: p=16 (half-warp)


CONFIG = PaperExperimentConfig()
