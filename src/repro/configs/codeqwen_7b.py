"""codeqwen1.5-7b — qwen1.5-arch dense decoder (full MHA, kv=32).

[hf:Qwen/CodeQwen1.5-7B; hf]  32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
)

SMOKE = ModelConfig(
    name="codeqwen-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab_size=512,
    dtype="float32",
)
