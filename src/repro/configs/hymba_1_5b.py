"""hymba-1.5b — hybrid parallel attention + Mamba heads, sliding windows.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Sliding-window attention (1024) with 3 global-attention layers
(first/middle/last, per the Hymba paper); the SSM side runs in parallel with
attention in every block and the outputs are averaged.  Sub-quadratic →
eligible for long_500k.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    dtype="float32",
    sliding_window=8,
    global_attn_layers=(0, 2),
    ssm=SSMConfig(state_dim=4, conv_width=4, expand=2),
)
