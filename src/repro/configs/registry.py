"""Architecture registry: ``--arch <id>`` resolution for every entry point."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "granite-moe-3b-a800m": "repro.configs.granite_moe",
    "whisper-medium": "repro.configs.whisper_medium",
    "yi-6b": "repro.configs.yi_6b",
    "codeqwen1.5-7b": "repro.configs.codeqwen_7b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "xlstm-125m": "repro.configs.xlstm_125m",
}

# short aliases accepted by --arch
_ALIASES = {
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "phi35-moe": "phi3.5-moe-42b-a6.6b",
    "granite-moe": "granite-moe-3b-a800m",
    "whisper": "whisper-medium",
    "yi": "yi-6b",
    "codeqwen": "codeqwen1.5-7b",
    "hymba": "hymba-1.5b",
    "qwen2-vl": "qwen2-vl-72b",
    "xlstm": "xlstm-125m",
}

ARCH_IDS = list(_MODULES)


def _resolve(name: str) -> str:
    name = name.strip()
    if name in _MODULES:
        return name
    if name in _ALIASES:
        return _ALIASES[name]
    raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")


def get_config(name: str) -> ModelConfig:
    """Full (assigned) configuration for ``--arch <name>``."""
    mod = importlib.import_module(_MODULES[_resolve(name)])
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family configuration for CPU smoke tests."""
    mod = importlib.import_module(_MODULES[_resolve(name)])
    return mod.SMOKE


def all_configs() -> dict[str, ModelConfig]:
    return {k: get_config(k) for k in ARCH_IDS}
