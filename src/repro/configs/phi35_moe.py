"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE with GQA.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=6400/expert vocab=32064, 16e top-2.
Primary integration target for the paper's tree router (depth-4 tree over 16
experts, speculative branchless evaluation on the serving path).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400, router="tree"),
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=128, router="tree", capacity_factor=8.0),
)
