"""whisper-medium — encoder-decoder audio backbone (conv frontend stubbed).

[arXiv:2212.04356; unverified]
24L (enc) + 24L (dec), d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
``input_specs`` provides precomputed frame embeddings (B, 1500, 1024) — the
mel-spectrogram conv stack is a stub per the assignment.
"""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    rope_style="none",
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    act="gelu",
    rope_style="none",
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=2, n_frames=24),
)
