"""xlstm-125m — sLSTM + mLSTM block stack (1:3 ratio).

[arXiv:2405.04517; unverified]  12L d_model=768 4H d_ff=0 vocab=50304.
Recurrent decode state is O(1) in sequence length → eligible for long_500k.
"""

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope_style="none",
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0, conv_width=4),
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    dtype="float32",
    rope_style="none",
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0, conv_width=4),
)
