"""Configuration dataclasses for models, parallelism and runs."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp

# "float16" exists for the CPU dry-run only: XLA:CPU cannot codegen bf16 dots
# (FloatNormalization promotes them to f32, inflating every byte count 2x),
# while f16 is natively supported and byte-identical to the TPU's bf16.
_DTYPES = {"bfloat16": jnp.bfloat16, "float16": jnp.float16, "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config."""

    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden width
    router: str = "softmax"         # "softmax" | "tree" (paper integration)
    router_tree_depth: int = 0      # 0 → ceil(log2(n_experts))
    capacity_factor: float = 1.25
    shared_d_ff: int = 0            # optional shared (always-on) expert width
    aux_loss_weight: float = 0.01

    def tree_depth(self) -> int:
        if self.router_tree_depth:
            return self.router_tree_depth
        d = 1
        while (1 << d) < self.n_experts:
            d += 1
        return d


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective-SSM config."""

    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block mix: which layers are sLSTM (others mLSTM)."""

    slstm_every: int = 4            # layer i is sLSTM iff i % slstm_every == slstm_every-1
    proj_factor: float = 2.0        # mLSTM up-projection
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) models; frontend is a stub."""

    n_layers: int
    n_frames: int = 1500            # whisper 30 s @ 50 Hz after conv stub


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Families: dense | moe | hybrid | ssm | audio | vlm."""

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads
    rope_theta: float = 10_000.0
    rope_style: str = "rope"        # "rope" | "mrope" | "none"
    mrope_sections: Sequence[int] = (16, 24, 24)
    norm_eps: float = 1e-5
    act: str = "silu"               # mlp activation: "silu"(SwiGLU) | "gelu"
    tie_embeddings: bool = False
    sliding_window: int = 0         # 0 → full attention
    global_attn_layers: Sequence[int] = ()   # hybrid: layers with full attn
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None
    embeds_input: bool = False      # vlm/audio stub: inputs are embeddings
    dtype: str = "bfloat16"         # activation dtype
    param_dtype: str = "float32"
    # paper integration
    tree_head_classes: int = 0      # >0 → attach tree token-classification head

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def act_dtype(self):
        return _DTYPES[self.dtype]

    @property
    def p_dtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/recurrent/sliding-window)."""
        return self.family in ("hybrid", "ssm")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def n_params(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.xlstm is not None:
            per_layer = self._xlstm_layer_params()
        else:
            if self.moe is not None:
                mlp = 3 * d * self.moe.d_ff * self.moe.n_experts
                if self.moe.shared_d_ff:
                    mlp += 3 * d * self.moe.shared_d_ff
                mlp += self._router_params()
            else:
                mlp = (3 if self.act == "silu" else 2) * d * f
            per_layer = attn + mlp + 2 * d
            if self.ssm is not None and self.family == "hybrid":
                per_layer += self._ssm_layer_params()
        total = self.n_layers * per_layer + v * d + d
        if not self.tie_embeddings:
            total += v * d
        if self.encoder is not None:
            enc_layer = attn + 2 * d * f + 2 * d  # gelu mlp (2 mats) + cross-kv reuse
            total += self.encoder.n_layers * enc_layer
        return int(total)

    def _router_params(self) -> int:
        assert self.moe is not None
        if self.moe.router == "tree":
            n_internal = (1 << self.moe.tree_depth()) - 1
            return self.d_model * n_internal + n_internal
        return self.d_model * self.moe.n_experts

    def _ssm_layer_params(self) -> int:
        s = self.ssm
        d_in = s.expand * self.d_model
        dt_rank = s.dt_rank or -(-self.d_model // 16)
        return (
            2 * self.d_model * d_in          # in_proj (x, z)
            + s.conv_width * d_in            # depthwise conv
            + d_in * (dt_rank + 2 * s.state_dim)  # x→(dt,B,C)
            + dt_rank * d_in                 # dt proj
            + d_in * s.state_dim             # A
            + d_in                            # D skip
            + d_in * self.d_model            # out proj
        )

    def _xlstm_layer_params(self) -> int:
        x = self.xlstm
        d = self.d_model
        d_in = int(x.proj_factor * d)
        # mLSTM block: up 2×, qkv, gates, out
        m = 2 * d * d_in + 3 * d_in * d_in // max(1, self.n_heads) * self.n_heads
        m += 2 * d_in + d_in * d
        # sLSTM block approximated same order
        return m

    def active_params(self) -> int:
        """MoE: params touched per token (top-k experts + shared + backbone)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - self.n_layers * 3 * d * self.moe.d_ff * self.moe.n_experts
        active_mlp = self.n_layers * 3 * d * self.moe.d_ff * self.moe.top_k
        return int(dense + active_mlp)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh."""

    batch_axes: tuple = ("data",)   # ("pod","data") on the multi-pod mesh
    model_axis: str = "model"
    remat: str = "full"             # "none" | "full" | "dots"
    scan_layers: bool = True
    seq_shard: bool = True          # sequence-parallel residual stream
    attn_kv_block: int = 1024       # blockwise-attention KV chunk
    attn_unroll: int = 4            # unroll factor for the KV-block scan
                                    # (fuses acc updates across blocks:
                                    #  +35% roofline frac on ds67, §Perf D7)
    zero1: bool = True              # shard optimizer state over data axis
    grad_compression: bool = False  # int8 cross-pod gradient compression


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0             # 0 → no accumulation
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"
