"""The four assigned input-shape cells.

``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers the serving
prefill; ``decode_32k`` / ``long_500k`` lower ``serve_step`` (one new token
against a KV cache of ``seq_len``).  ``long_500k`` requires sub-quadratic
attention and only runs for the hybrid/ssm archs (skips recorded in
EXPERIMENTS.md per cell).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig(name="train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig(name="long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason).  long_500k is skipped for pure full-attention archs:
    a 524 288-token dense KV cache is architecturally quadratic (DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k dense KV cache is quadratic — skipped per assignment"
    return True, ""


def cells_for(cfg: ModelConfig):
    """Applicable (shape, skip-reason) cells for one arch, in canonical order."""
    out = []
    for name in SHAPE_ORDER:
        sh = SHAPES[name]
        ok, why = cell_applicable(cfg, sh)
        out.append((sh, ok, why))
    return out
