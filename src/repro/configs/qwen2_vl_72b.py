"""qwen2-vl-72b — VLM backbone with M-RoPE (vision frontend stubbed).

[arXiv:2409.12191; hf]  80L d_model=8192 64H (kv=8) d_ff=29568 vocab=152064.
``input_specs`` provides precomputed patch/text embeddings plus (B, 3, S)
M-RoPE position streams (temporal/height/width) — the ViT frontend and
dynamic-resolution packer are stubs per the assignment.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope_style="mrope",
    mrope_sections=(16, 24, 24),
    embeds_input=True,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    rope_style="mrope",
    mrope_sections=(4, 2, 2),
    embeds_input=True,
)
