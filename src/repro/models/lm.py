"""Decoder-only LM covering the dense / moe / hybrid / vlm families.

One code path lowers every assigned architecture:
  * params are declared via the schema (shapes/specs/init from one source);
  * layers are scanned (``lax.scan``) with optionally-rematerialized bodies so
    deepseek-67b (95L) and qwen2-vl (80L) compile quickly and fit HBM;
  * per-layer heterogeneity (hymba's global-attention layers among sliding-
    window layers) rides the scan as a per-layer traced flag consumed by the
    arithmetic block masks — no unrolling, no (S, S) mask tensors;
  * full-sequence attention is blockwise (online softmax over KV chunks) so
    the 32 k cells never materialize quadratic score tensors;
  * the residual stream is sequence-sharded over the 'model' axis between
    blocks (sequence parallelism) when the length divides — XLA inserts the
    gather/scatter collectives at the attention boundary;
  * decode threads stacked KV caches (and SSM states for hybrid) through the
    same scan.

Modes: ``forward`` (train/prefill), ``prefill`` (forward + cache build),
``decode_step`` (one token against the cache).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import schema as sch
from repro.models.layers import attention as attn
from repro.models.layers import mlp as mlpl
from repro.models.layers import moe as moel
from repro.models.layers import ssm as ssml
from repro.models.layers.rope import positions_for
from repro.parallel import sharding as shd
from repro.utils.losses import chunked_softmax_xent, softmax_xent


class DecodeCache(NamedTuple):
    kv: attn.KVCache          # stacked (L, B, S_max, KV, hd)
    ssm: Optional[ssml.SSMState]  # stacked (L, ...) or None
    pos: jax.Array            # scalar int32: tokens already in cache


@dataclasses.dataclass
class DecoderModel:
    cfg: ModelConfig
    axes: shd.MeshAxes
    parallel: ParallelConfig = ParallelConfig()

    # ----------------------------- schema -----------------------------

    def __post_init__(self):
        self.v_pad = shd.pad_vocab(self.cfg.vocab_size, self.axes)

    def layer_schema(self) -> dict:
        cfg, axes = self.cfg, self.axes
        out = {
            "ln1": mlpl.rmsnorm_schema(cfg),
            "attn": attn.attn_schema(cfg, axes),
            "ln2": mlpl.rmsnorm_schema(cfg),
        }
        if cfg.moe is not None:
            out["moe"] = moel.moe_schema(cfg, axes)
        else:
            out["mlp"] = mlpl.mlp_schema(cfg, axes)
        if cfg.family == "hybrid":
            out["ssm"] = ssml.ssm_schema(cfg, axes)
        return out

    def schema(self) -> dict:
        cfg = self.cfg
        layer = self.layer_schema()
        if self.parallel.scan_layers:
            layers = jax.tree.map(
                lambda s: sch.PSpec(
                    (cfg.n_layers, *s.shape), P(None, *s.spec), s.init, s.dtype, s.scale
                ),
                layer,
                is_leaf=sch.is_pspec,
            )
        else:
            layers = {f"layer_{i:03d}": layer for i in range(cfg.n_layers)}
        d_fsdp = self.axes.fsdp_if(cfg.d_model)
        out = {
            "embed": {
                "table": sch.PSpec(
                    (self.v_pad, cfg.d_model), P(self.axes.tp_axis, d_fsdp), dtype=cfg.p_dtype
                )
            },
            "layers": layers,
            "final_norm": mlpl.rmsnorm_schema(cfg),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = {
                "w": sch.PSpec(
                    (cfg.d_model, self.v_pad), P(d_fsdp, self.axes.tp_axis), dtype=cfg.p_dtype
                )
            }
        return out

    def param_shapes(self):
        return sch.shapes_of(self.schema())

    def param_specs(self):
        return sch.specs_of(self.schema())

    def init(self, key):
        return sch.init_params(self.schema(), key)

    # --------------------------- building blocks ---------------------------

    def _constrain_resid(self, x):
        ba = self.axes.batch_axes_for(x.shape[0])
        sp = None
        if self.parallel.seq_shard:
            sp = shd.free_model_seq(self.axes, x.shape[0], x.shape[1])
        return shd.constrain(x, P(ba, sp, None))

    def _is_global_flags(self) -> jax.Array:
        cfg = self.cfg
        if cfg.sliding_window == 0:
            return jnp.ones((cfg.n_layers,), bool)
        flags = [i in set(cfg.global_attn_layers) for i in range(cfg.n_layers)]
        return jnp.asarray(flags)

    def _layer_apply(self, lp, x, positions, is_global, *, serve_hard_tree=False):
        """One transformer block (full-sequence). Returns (x, aux)."""
        cfg, axes = self.cfg, self.axes
        h = mlpl.rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
        q, k, v = attn._project_qkv(lp["attn"], h, None, cfg, positions)
        a = attn.grouped_attention(
            q, k, v, cfg=cfg, causal=True,
            window=cfg.sliding_window, is_global=is_global,
            kv_block=self.parallel.attn_kv_block, unroll=self.parallel.attn_unroll,
        )
        a = a @ lp["attn"]["wo"].astype(x.dtype)
        if cfg.family == "hybrid":
            s = ssml.ssm_apply(lp["ssm"], h, cfg=cfg, axes=axes)
            x = x + 0.5 * (a + s)
        else:
            x = x + a
        h2 = mlpl.rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe is not None:
            y, aux = moel.moe_apply(
                lp["moe"], h2, cfg=cfg, axes=axes, serve_hard_tree=serve_hard_tree
            )
        else:
            y = mlpl.mlp(lp["mlp"], h2, cfg=cfg)
        x = x + y
        x = self._constrain_resid(x)
        if self.parallel.remat == "offload":
            from jax.ad_checkpoint import checkpoint_name
            x = checkpoint_name(x, "resid")
        return x, aux

    def _remat(self, fn):
        if self.parallel.remat == "none":
            return fn
        if self.parallel.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        if self.parallel.remat == "offload":
            # residual stream saves go to host memory (TPU host offload):
            # ~64 MB/layer/chip of HBM becomes PCIe traffic instead
            policy = jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["resid"],
                offload_src="device", offload_dst="pinned_host",
            )
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)

    # ------------------------------ forward ------------------------------

    def embed(self, params, batch) -> tuple[jax.Array, Any]:
        cfg, axes = self.cfg, self.axes
        if cfg.embeds_input:
            x = batch["embeds"].astype(cfg.act_dtype)
        else:
            tok = batch["tokens"]
            x = params["embed"]["table"].astype(cfg.act_dtype)[tok]
        x = self._constrain_resid(x)
        b, s = x.shape[:2]
        positions = batch.get("positions")
        if positions is None and cfg.rope_style != "none":
            positions = positions_for(b, s, style=cfg.rope_style)
        return x, positions

    def logits(self, params, x) -> jax.Array:
        cfg = self.cfg
        if cfg.tie_embeddings:
            w = params["embed"]["table"].astype(x.dtype).T
        else:
            w = params["lm_head"]["w"].astype(x.dtype)
        out = x @ w
        ba = self.axes.batch_axes_for(x.shape[0])
        return shd.constrain(out, P(ba, None, self.axes.tp_axis))

    def hidden(self, params, batch, *, serve_hard_tree: bool = False) -> tuple[jax.Array, jax.Array]:
        """Final normed hidden states (B,S,D) + aux loss (params pre-cast)."""
        cfg = self.cfg
        x, positions = self.embed(params, batch)
        flags = self._is_global_flags()

        if self.parallel.scan_layers:

            def body(carry, xs):
                xc, aux = carry
                lp, is_g = xs
                xc, a = self._layer_apply(lp, xc, positions, is_g, serve_hard_tree=serve_hard_tree)
                return (xc, aux + a), None

            body = self._remat(body)
            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), (params["layers"], flags))
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                lp = params["layers"][f"layer_{i:03d}"]
                x, a = self._layer_apply(lp, x, positions, flags[i], serve_hard_tree=serve_hard_tree)
                aux = aux + a
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        return x, aux

    def forward(self, params, batch, *, serve_hard_tree: bool = False) -> tuple[jax.Array, jax.Array]:
        """Full-sequence forward. Returns (logits (B,S,V_pad), aux_loss)."""
        params = sch.cast_for_compute(params, self.cfg.act_dtype, self.param_specs())
        x, aux = self.hidden(params, batch, serve_hard_tree=serve_hard_tree)
        return self.logits(params, x), aux

    def _out_w(self, params, dtype):
        if self.cfg.tie_embeddings:
            return params["embed"]["table"].astype(dtype).T
        return params["lm_head"]["w"].astype(dtype)

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        x, aux = self.hidden(params, batch)
        nll, _ = chunked_softmax_xent(
            x, self._out_w(params, x.dtype), batch["labels"], vocab_size=cfg.vocab_size
        )
        total = nll + aux
        return total, {"nll": nll, "aux": aux}

    # ------------------------------- decode -------------------------------

    def cache_shapes(self, batch: int, max_len: int) -> DecodeCache:
        cfg = self.cfg
        l = cfg.n_layers
        kv = attn.cache_shape(cfg, batch, max_len)
        stack = lambda s: jax.ShapeDtypeStruct((l, *s.shape), s.dtype)
        kv = attn.KVCache(k=stack(kv.k), v=stack(kv.v))
        sstate = None
        if cfg.family == "hybrid":
            ss = ssml.ssm_state_shape(cfg, batch)
            sstate = ssml.SSMState(conv=stack(ss.conv), h=stack(ss.h))
        return DecodeCache(kv=kv, ssm=sstate, pos=jax.ShapeDtypeStruct((), jnp.int32))

    def cache_specs(self, global_batch: int = 0) -> DecodeCache:
        cfg, axes = self.cfg, self.axes
        kv = attn.cache_spec(cfg, axes, global_batch)
        kv = attn.KVCache(k=P(None, *kv.k), v=P(None, *kv.v))
        sstate = None
        if cfg.family == "hybrid":
            ss = ssml.ssm_state_spec(cfg, axes, global_batch)
            sstate = ssml.SSMState(conv=P(None, *ss.conv), h=P(None, *ss.h))
        return DecodeCache(kv=kv, ssm=sstate, pos=P())

    def init_cache(self, batch: int, max_len: int) -> DecodeCache:
        shapes = self.cache_shapes(batch, max_len)
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        return zeros._replace(pos=jnp.zeros((), jnp.int32))

    def _layer_decode(self, lp, x, kv, sstate, cache_pos, positions, is_global):
        cfg, axes = self.cfg, self.axes
        h = mlpl.rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
        a, new_kv = attn.attention_decode(
            lp["attn"], h, kv, cache_pos, cfg=cfg, positions=positions,
            window=cfg.sliding_window, is_global=is_global,
        )
        new_s = None
        if cfg.family == "hybrid":
            s_out, new_s = ssml.ssm_decode(lp["ssm"], h, sstate, cfg=cfg)
            x = x + 0.5 * (a + s_out)
        else:
            x = x + a
        h2 = mlpl.rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moel.moe_apply(
                lp["moe"], h2, cfg=cfg, axes=axes, group_size=h2.shape[0] * h2.shape[1],
                serve_hard_tree=(cfg.moe.router == "tree"),
            )
        else:
            y = mlpl.mlp(lp["mlp"], h2, cfg=cfg)
        x = x + y
        return x, new_kv, new_s

    def decode_step(self, params, cache: DecodeCache, batch) -> tuple[jax.Array, DecodeCache]:
        """One token for every sequence in the batch. batch: {"tokens": (B,1)}
        (or {"embeds": (B,1,D)}); positions default to cache.pos."""
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        x, _ = self.embed(params, batch)
        b = x.shape[0]
        pos = cache.pos
        if cfg.rope_style == "mrope":
            positions = jnp.broadcast_to(pos[None, None, None], (b, 3, 1)).astype(jnp.int32)
        elif cfg.rope_style == "rope":
            positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
        else:
            positions = None
        flags = self._is_global_flags()

        if self.parallel.scan_layers:

            def body(xc, xs):
                lp, kv_l, ss_l, is_g = xs
                xc, new_kv, new_ss = self._layer_decode(lp, xc, kv_l, ss_l, pos, positions, is_g)
                return xc, (new_kv, new_ss)

            dummy_ss = cache.ssm
            if dummy_ss is None:
                dummy_ss = jnp.zeros((cfg.n_layers,), jnp.float32)  # placeholder xs
            x, (new_kv, new_ss) = jax.lax.scan(
                body, x, (params["layers"], cache.kv, dummy_ss, flags)
            )
            if cache.ssm is None:
                new_ss = None
        else:
            kvs, sss = [], []
            for i in range(cfg.n_layers):
                lp = params["layers"][f"layer_{i:03d}"]
                kv_l = jax.tree.map(lambda a: a[i], cache.kv)
                ss_l = jax.tree.map(lambda a: a[i], cache.ssm) if cache.ssm is not None else None
                x, nkv, nss = self._layer_decode(lp, x, kv_l, ss_l, pos, positions, flags[i])
                kvs.append(nkv)
                sss.append(nss)
            new_kv = jax.tree.map(lambda *a: jnp.stack(a), *kvs)
            new_ss = jax.tree.map(lambda *a: jnp.stack(a), *sss) if cache.ssm is not None else None
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        logits = self.logits(params, x)
        return logits, DecodeCache(kv=new_kv, ssm=new_ss, pos=pos + 1)

    def prefill(self, params, batch, max_len: int | None = None) -> tuple[jax.Array, DecodeCache]:
        """Forward + KV-cache construction (prefill_32k serving step).

        ``max_len``: cache capacity; defaults to the prompt length (the
        dry-run cell convention).  Serving passes prompt+generation budget —
        decode writes past the prompt would otherwise clamp out of bounds."""
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        x, positions = self.embed(params, batch)
        b, s = x.shape[:2]
        flags = self._is_global_flags()

        def body(carry, xs):
            xc = carry
            lp, is_g = xs
            h = mlpl.rmsnorm(lp["ln1"], xc, eps=cfg.norm_eps)
            q, k, v = attn._project_qkv(lp["attn"], h, None, cfg, positions)
            a = attn.grouped_attention(
                q, k, v, cfg=cfg, causal=True,
                window=cfg.sliding_window, is_global=is_g,
                kv_block=self.parallel.attn_kv_block, unroll=self.parallel.attn_unroll,
            )
            a = a @ lp["attn"]["wo"].astype(xc.dtype)
            new_ss = None
            if cfg.family == "hybrid":
                sm = ssml.ssm_apply(lp["ssm"], h, cfg=cfg, axes=self.axes)
                xc = xc + 0.5 * (a + sm)
                # terminal SSM state for subsequent decode: recompute cheaply
                # from the last conv window; hybrid prefill carries state.
                d_in = cfg.ssm.expand * cfg.d_model
                w = cfg.ssm.conv_width
                xz = h @ lp["ssm"]["in_proj"].astype(h.dtype)
                x_in = xz[..., :d_in]
                conv_tail = x_in[:, -(w - 1):, :]
                new_ss = ssml.SSMState(
                    conv=conv_tail.astype(cfg.act_dtype),
                    h=jnp.zeros((b, d_in, cfg.ssm.state_dim), jnp.float32),
                )
            else:
                xc = xc + a
            h2 = mlpl.rmsnorm(lp["ln2"], xc, eps=cfg.norm_eps)
            if cfg.moe is not None:
                # serving path: the hardened speculative tree router, matching
                # decode_step (prefill and decode must route identically)
                y, _ = moel.moe_apply(
                    lp["moe"], h2, cfg=cfg, axes=self.axes,
                    serve_hard_tree=(cfg.moe.router == "tree"),
                )
            else:
                y = mlpl.mlp(lp["mlp"], h2, cfg=cfg)
            xc = xc + y
            xc = self._constrain_resid(xc)
            out = (attn.KVCache(k=k.astype(cfg.act_dtype), v=v.astype(cfg.act_dtype)), new_ss)
            return xc, out

        x, (kv, ss) = jax.lax.scan(body, x, (params["layers"], flags))
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        logits = self.logits(params, x[:, -1:, :])
        if cfg.family != "hybrid":
            ss = None
        if max_len is not None and max_len > s:
            pad = max_len - s
            kv = attn.KVCache(
                k=jnp.pad(kv.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                v=jnp.pad(kv.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            )
        return logits, DecodeCache(kv=kv, ssm=ss, pos=jnp.asarray(s, jnp.int32))
