"""xLSTM language model (sLSTM + mLSTM block stack, xlstm-125m).

Layer ``i`` is an sLSTM block iff ``i % slstm_every == slstm_every - 1``
(default: every 4th), all others are mLSTM — the 1:3 ratio of the xLSTM
paper's 125M configuration.  Blocks have heterogeneous parameters, so layers
are unrolled rather than scanned (12 layers; unrolling is cheap and lets each
block keep its own schema).

Training uses the chunkwise-parallel mLSTM form and a time-scan for sLSTM
(see layers/xlstm.py); decode carries O(1) recurrent state per layer, which is
what qualifies this arch for the ``long_500k`` cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import schema as sch
from repro.models.layers import mlp as mlpl
from repro.models.layers import xlstm as xl
from repro.parallel import sharding as shd
from repro.utils.losses import chunked_softmax_xent, softmax_xent


class XLSTMCache(NamedTuple):
    states: tuple          # per-layer MLSTMState | SLSTMState
    pos: jax.Array


@dataclasses.dataclass
class XLSTMModel:
    cfg: ModelConfig
    axes: shd.MeshAxes
    parallel: ParallelConfig = ParallelConfig()

    def __post_init__(self):
        self.v_pad = shd.pad_vocab(self.cfg.vocab_size, self.axes)
        assert self.cfg.xlstm is not None

    def is_slstm(self, i: int) -> bool:
        k = self.cfg.xlstm.slstm_every
        return k > 0 and i % k == k - 1

    # ----------------------------- schema -----------------------------

    def schema(self) -> dict:
        cfg, axes = self.cfg, self.axes
        layers = {}
        for i in range(cfg.n_layers):
            body = xl.slstm_schema(cfg, axes) if self.is_slstm(i) else xl.mlstm_schema(cfg, axes)
            layers[f"layer_{i:03d}"] = {"ln": mlpl.rmsnorm_schema(cfg), "block": body}
        out = {
            "embed": {
                "table": sch.PSpec(
                    (self.v_pad, cfg.d_model), P(axes.tp_axis, None), dtype=cfg.p_dtype
                )
            },
            "layers": layers,
            "final_norm": mlpl.rmsnorm_schema(cfg),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = {
                "w": sch.PSpec((cfg.d_model, self.v_pad), P(axes.fsdp_if(cfg.d_model), axes.tp_axis), dtype=cfg.p_dtype)
            }
        return out

    def param_shapes(self):
        return sch.shapes_of(self.schema())

    def param_specs(self):
        return sch.specs_of(self.schema())

    def init(self, key):
        return sch.init_params(self.schema(), key)

    # ------------------------------ forward ------------------------------

    def _hidden(self, params, batch) -> tuple[jax.Array, jax.Array]:
        cfg, axes = self.cfg, self.axes
        tok = batch["tokens"]
        x = params["embed"]["table"].astype(cfg.act_dtype)[tok]
        x = shd.constrain(x, P(axes.batch_axes_for(x.shape[0]), None, None))
        for i in range(cfg.n_layers):
            lp = params["layers"][f"layer_{i:03d}"]

            def block(lp_, x_, slstm=self.is_slstm(i)):
                h = mlpl.rmsnorm(lp_["ln"], x_, eps=cfg.norm_eps)
                if slstm:
                    y = xl.slstm_apply(lp_["block"], h, cfg=cfg, axes=axes)
                else:
                    y = xl.mlstm_apply(lp_["block"], h, cfg=cfg, axes=axes)
                return shd.constrain(x_ + y, P(axes.batch_axes_for(x_.shape[0]), None, None))

            if self.parallel.remat != "none":
                block = jax.checkpoint(block)
            x = block(lp, x)
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        return x, jnp.zeros((), jnp.float32)

    def forward(self, params, batch) -> tuple[jax.Array, jax.Array]:
        params = sch.cast_for_compute(params, self.cfg.act_dtype, self.param_specs())
        x, aux = self._hidden(params, batch)
        return self.logits(params, x), aux

    def logits(self, params, x) -> jax.Array:
        if self.cfg.tie_embeddings:
            w = params["embed"]["table"].astype(x.dtype).T
        else:
            w = params["lm_head"]["w"].astype(x.dtype)
        ba = self.axes.batch_axes_for(x.shape[0])
        return shd.constrain(x @ w, P(ba, None, self.axes.tp_axis))

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        x, aux = self._hidden(params, batch)
        if cfg.tie_embeddings:
            w = params["embed"]["table"].astype(x.dtype).T
        else:
            w = params["lm_head"]["w"].astype(x.dtype)
        nll, _ = chunked_softmax_xent(x, w, batch["labels"], vocab_size=cfg.vocab_size)
        return nll + aux, {"nll": nll, "aux": aux}

    # ------------------------------- decode -------------------------------

    def cache_shapes(self, batch: int, max_len: int) -> XLSTMCache:
        cfg = self.cfg
        states = tuple(
            xl.slstm_state_shape(cfg, batch) if self.is_slstm(i) else xl.mlstm_state_shape(cfg, batch)
            for i in range(cfg.n_layers)
        )
        return XLSTMCache(states=states, pos=jax.ShapeDtypeStruct((), jnp.int32))

    def cache_specs(self, global_batch: int = 0) -> XLSTMCache:
        cfg, axes = self.cfg, self.axes
        states = tuple(
            xl.slstm_state_spec(cfg, axes, global_batch) if self.is_slstm(i)
            else xl.mlstm_state_spec(cfg, axes, global_batch)
            for i in range(cfg.n_layers)
        )
        return XLSTMCache(states=states, pos=P())

    def init_cache(self, batch: int, max_len: int) -> XLSTMCache:
        shapes = self.cache_shapes(batch, max_len)

        def zero(s):
            z = jnp.zeros(s.shape, s.dtype)
            return z

        states = jax.tree.map(zero, shapes.states)
        # m-stabilizers start at -inf-ish
        fixed = []
        for i, st in enumerate(states):
            if self.is_slstm(i):
                fixed.append(st._replace(m=jnp.full_like(st.m, -1e30)))
            else:
                fixed.append(st._replace(m=jnp.full_like(st.m, -1e30)))
        return XLSTMCache(states=tuple(fixed), pos=jnp.zeros((), jnp.int32))

    def decode_step(self, params, cache: XLSTMCache, batch) -> tuple[jax.Array, XLSTMCache]:
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        tok = batch["tokens"]
        x = params["embed"]["table"].astype(cfg.act_dtype)[tok]  # (B, 1, D)
        new_states = []
        for i in range(cfg.n_layers):
            lp = params["layers"][f"layer_{i:03d}"]
            h = mlpl.rmsnorm(lp["ln"], x, eps=cfg.norm_eps)
            if self.is_slstm(i):
                y, ns = xl.slstm_decode(lp["block"], h, cache.states[i], cfg=cfg)
            else:
                y, ns = xl.mlstm_decode(lp["block"], h, cache.states[i], cfg=cfg)
            x = x + y
            new_states.append(ns)
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        return self.logits(params, x), XLSTMCache(states=tuple(new_states), pos=cache.pos + 1)

    def prefill(self, params, batch, max_len: int | None = None) -> tuple[jax.Array, XLSTMCache]:
        """Single parallel pass producing both logits and terminal states.

        The chunkwise-parallel mLSTM scan and the sLSTM time scan already
        carry the recurrent state — ``return_state`` surfaces it, so prefill
        costs exactly one forward (no sequential re-pass).
        """
        cfg, axes = self.cfg, self.axes
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        tok = batch["tokens"]
        x = params["embed"]["table"].astype(cfg.act_dtype)[tok]
        x = shd.constrain(x, P(axes.batch_axes_for(x.shape[0]), None, None))
        states = []
        for i in range(cfg.n_layers):
            lp = params["layers"][f"layer_{i:03d}"]
            h = mlpl.rmsnorm(lp["ln"], x, eps=cfg.norm_eps)
            if self.is_slstm(i):
                y, st = xl.slstm_apply(lp["block"], h, cfg=cfg, axes=axes, return_state=True)
            else:
                y, st = xl.mlstm_apply(lp["block"], h, cfg=cfg, axes=axes, return_state=True)
            x = x + y
            x = shd.constrain(x, P(axes.batch_axes_for(x.shape[0]), None, None))
            states.append(st)
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        logits = self.logits(params, x[:, -1:, :])
        return logits, XLSTMCache(
            states=tuple(states), pos=jnp.asarray(tok.shape[1], jnp.int32)
        )
