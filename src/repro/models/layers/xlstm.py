"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM trains with the **chunkwise-parallel form**: within a chunk the
stabilized exponential-gating attention-like quadratic form is used; across
chunks the recurrent matrix state ``(C, n, m)`` is carried by ``lax.scan`` —
this is the TPU-native equivalent of the TFLA kernels (log-free of sequential
work inside a chunk, O(S/chunk) sequential steps across).  Decode uses the
exact recurrent step, so serving state is O(1) in sequence length (the
long_500k cell).

sLSTM has a true nonlinear recurrence (h_{t-1} feeds the gates), so training
runs a ``lax.scan`` over time — faithful to the architecture; xlstm-125m is
small enough that this is the honest cost.

Stabilization follows the xLSTM paper: log-sigmoid forget gates, running
max-state ``m`` so all exponentials are ≤ 1.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.schema import PSpec
from repro.parallel import sharding as shd


def _mdims(cfg: ModelConfig):
    d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
    h = cfg.n_heads
    return d_in, h, d_in // h


class MLSTMState(NamedTuple):
    c: jax.Array     # (B, H, dh, dh)
    n: jax.Array     # (B, H, dh)
    m: jax.Array     # (B, H)
    conv: jax.Array  # (B, W-1, Di)


class SLSTMState(NamedTuple):
    c: jax.Array     # (B, D)
    n: jax.Array     # (B, D)
    h: jax.Array     # (B, D)
    m: jax.Array     # (B, D)


def mlstm_schema(cfg: ModelConfig, axes: shd.MeshAxes) -> dict:
    d = cfg.d_model
    d_in, h, dh = _mdims(cfg)
    w = cfg.xlstm.conv_width
    di = axes.shard_if(d_in)
    pd = cfg.p_dtype
    return {
        "up": PSpec((d, 2 * d_in), P(axes.fsdp_if(d), di), dtype=pd),
        "conv_w": PSpec((w, d_in), P(None, di), dtype=pd),
        "wq": PSpec((d_in, d_in), P(axes.fsdp_if(d_in), di), dtype=pd),
        "wk": PSpec((d_in, d_in), P(axes.fsdp_if(d_in), di), dtype=pd),
        "wv": PSpec((d_in, d_in), P(axes.fsdp_if(d_in), di), dtype=pd),
        "w_if": PSpec((d_in, 2 * h), P(di, None), dtype=jnp.float32),
        "b_if": PSpec((2 * h,), P(None), init="zeros", dtype=jnp.float32),
        "down": PSpec((d_in, d), P(di, axes.fsdp_if(d)), dtype=pd),
    }


def slstm_schema(cfg: ModelConfig, axes: shd.MeshAxes) -> dict:
    d = cfg.d_model
    dm = axes.shard_if(d)
    pd = cfg.p_dtype
    return {
        "w_gates": PSpec((d, 4 * d), P(axes.fsdp_if(d), axes.shard_if(4 * d)), dtype=pd),   # i,f,z,o
        "r_gates": PSpec((d, 4 * d), P(axes.fsdp_if(d), axes.shard_if(4 * d)), dtype=pd),   # recurrent
        "b_gates": PSpec((4 * d,), P(None), init="zeros", dtype=jnp.float32),
        "out": PSpec((d, d), P(None, dm), dtype=pd),
    }


def mlstm_state_shape(cfg: ModelConfig, batch: int) -> MLSTMState:
    d_in, h, dh = _mdims(cfg)
    w = cfg.xlstm.conv_width
    f32 = jnp.float32
    return MLSTMState(
        c=jax.ShapeDtypeStruct((batch, h, dh, dh), f32),
        n=jax.ShapeDtypeStruct((batch, h, dh), f32),
        m=jax.ShapeDtypeStruct((batch, h), f32),
        conv=jax.ShapeDtypeStruct((batch, w - 1, d_in), cfg.act_dtype),
    )


def mlstm_state_spec(cfg: ModelConfig, axes: shd.MeshAxes, global_batch: int = 0) -> MLSTMState:
    d_in, h, dh = _mdims(cfg)
    hs = axes.shard_if(h)
    ds = axes.shard_if(dh) if hs is None else None
    ba = axes.batch_axes_for(global_batch) if global_batch else axes.batch
    return MLSTMState(
        c=P(ba, hs, ds, None),
        n=P(ba, hs, ds),
        m=P(ba, hs),
        conv=P(ba, None, axes.shard_if(d_in)),
    )


def slstm_state_shape(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    s = jax.ShapeDtypeStruct((batch, d), jnp.float32)
    return SLSTMState(c=s, n=s, h=s, m=s)


def slstm_state_spec(cfg: ModelConfig, axes: shd.MeshAxes, global_batch: int = 0) -> SLSTMState:
    ba = axes.batch_axes_for(global_batch) if global_batch else axes.batch
    s = P(ba, None)
    return SLSTMState(c=s, n=s, h=s, m=s)


def _conv_causal(x, w):
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out


def _mlstm_qkv_gates(params, x, cfg: ModelConfig):
    d_in, h, dh = _mdims(cfg)
    b, s, _ = x.shape
    xz = x @ params["up"].astype(x.dtype)
    xm, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv_causal(xm, params["conv_w"].astype(x.dtype)))
    q = (xc @ params["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (xc @ params["wk"].astype(x.dtype)).reshape(b, s, h, dh) * (dh ** -0.5)
    v = (xm @ params["wv"].astype(x.dtype)).reshape(b, s, h, dh)
    gates = xc.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)                # (B, S, H) logits
    return q, k, v, ig, fg, z, xm, xc


def mlstm_apply(
    params: dict,
    x: jax.Array,             # (B, S, D)
    *,
    cfg: ModelConfig,
    axes: shd.MeshAxes,
    chunk: int = 1024,
    return_state: bool = False,
):
    """Chunkwise-parallel mLSTM over a full sequence.

    With ``return_state`` also returns the terminal :class:`MLSTMState`
    (the state the chunk scan already carries, plus the conv tail) so a
    prefill can seed decode without a sequential re-pass."""
    b, s, d = x.shape
    d_in, h, dh = _mdims(cfg)
    q, k, v, ig, fg, z, xm, _ = _mlstm_qkv_gates(params, x, cfg)
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    n_chunks = s // chunk
    assert n_chunks * chunk == s

    def per_chunk(state, args):
        c0, n0, m0 = state                               # (B,H,dh,dh),(B,H,dh),(B,H)
        qc, kc, vc, igc, fgc = args                      # (B, c, H, ...)
        qf = qc.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B,H,c,dh)
        kf = kc.astype(jnp.float32).transpose(0, 2, 1, 3)
        vf = vc.astype(jnp.float32).transpose(0, 2, 1, 3)
        lf = jax.nn.log_sigmoid(fgc).transpose(0, 2, 1)      # (B,H,c)
        ii = igc.transpose(0, 2, 1)                          # (B,H,c)
        bcum = jnp.cumsum(lf, axis=-1)                       # (B,H,c)
        # intra-chunk log decay matrix D[t,s] = b_t - b_s + i_s  (t ≥ s)
        dmat = bcum[..., :, None] - bcum[..., None, :] + ii[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri, dmat, -jnp.inf)
        inter_log = bcum + m0[..., None]                     # (B,H,c)
        m_t = jnp.maximum(inter_log, dmat.max(axis=-1))      # (B,H,c)
        d_exp = jnp.exp(dmat - m_t[..., None])
        sc = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * d_exp   # (B,H,c,c)
        inter_w = jnp.exp(inter_log - m_t)                   # (B,H,c)
        num = jnp.einsum("bhts,bhsd->bhtd", sc, vf) + inter_w[..., None] * jnp.einsum(
            "bhtd,bhde->bhte", qf, c0
        )
        den = jnp.abs(
            sc.sum(-1) + inter_w * jnp.einsum("bhtd,bhd->bht", qf, n0)
        )
        hout = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # ---- carry state to chunk end ----
        btot = bcum[..., -1]                                 # (B,H)
        scale_s = btot[..., None] - bcum + ii                # (B,H,c): decay for kv_s
        m_new = jnp.maximum(btot + m0, scale_s.max(-1))
        w_s = jnp.exp(scale_s - m_new[..., None])            # (B,H,c)
        c_new = jnp.exp(btot + m0 - m_new)[..., None, None] * c0 + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_s, kf, vf
        )
        n_new = jnp.exp(btot + m0 - m_new)[..., None] * n0 + jnp.einsum(
            "bhs,bhsd->bhd", w_s, kf
        )
        return (c_new, n_new, m_new), hout.transpose(0, 2, 1, 3)  # (B,c,H,dh)

    resh = lambda t: t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    (c_f, n_f, m_f), hs = jax.lax.scan(
        per_chunk, (c0, n0, m0), (resh(q), resh(k), resh(v), resh(ig), resh(fg))
    )
    hout = hs.swapaxes(0, 1).reshape(b, s, d_in).astype(x.dtype)
    out = (hout * jax.nn.silu(z)) @ params["down"].astype(x.dtype)
    if return_state:
        w = cfg.xlstm.conv_width
        state = MLSTMState(
            c=c_f, n=n_f, m=m_f, conv=xm[:, -(w - 1):, :].astype(cfg.act_dtype)
        )
        return out, state
    return out


def mlstm_decode(
    params: dict,
    x: jax.Array,             # (B, 1, D)
    state: MLSTMState,
    *,
    cfg: ModelConfig,
) -> tuple[jax.Array, MLSTMState]:
    b = x.shape[0]
    d_in, h, dh = _mdims(cfg)
    xz = x @ params["up"].astype(x.dtype)
    xm, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state.conv.astype(x.dtype), xm], axis=1)
    xc = jax.nn.silu((window * params["conv_w"].astype(x.dtype)[None]).sum(1, keepdims=True))
    q = (xc @ params["wq"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32)
    k = ((xc @ params["wk"].astype(x.dtype)).reshape(b, h, dh) * (dh ** -0.5)).astype(jnp.float32)
    v = (xm @ params["wv"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32)
    gates = xc[:, 0].astype(jnp.float32) @ params["w_if"] + params["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)                 # (B, H)
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(lf + state.m, ig)
    fw = jnp.exp(lf + state.m - m_new)[..., None]
    iw = jnp.exp(ig - m_new)[..., None]
    c_new = fw[..., None] * state.c + iw[..., None] * (k[..., :, None] * v[..., None, :])
    n_new = fw * state.n + iw * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new))
    hout = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    hout = hout.reshape(b, 1, d_in).astype(x.dtype)
    out = (hout * jax.nn.silu(z)) @ params["down"].astype(x.dtype)
    return out, MLSTMState(c=c_new, n=n_new, m=m_new, conv=window[:, 1:].astype(state.conv.dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _slstm_step(params, carry, x_t):
    """carry: (c, n, h, m) each (B, D); x_t = PRECOMPUTED input gates (B, 4D).

    Two scan hygiene rules learned the hard way (§Perf X1/X2):
      * weights referenced inside the 4096-step time scan re-gather every
        iteration (loop-invariant collectives are not hoisted on every XLA
        pipeline) — ``_slstm_weights`` materializes them replicated, once;
      * the input-gate matmul ``x_t @ W`` is time-parallel — precomputing it
        outside the scan turns 4096 tiny matmuls (and their weight-gradient
        all-reduces inside the backward loop) into ONE large matmul.
    Only the irreducibly-recurrent ``h_prev @ R`` stays in the loop."""
    c, n, h_prev, m = carry
    gates = x_t + h_prev @ params["r_gates"] + params["b_gates"]
    d = x_t.shape[-1]
    ig, fg, zg, og = jnp.split(gates, 4, axis=-1)
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(lf + m, ig)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(ig - m_new)
    c_new = fw * c + iw * jnp.tanh(zg)
    n_new = fw * n + iw
    h_new = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_weights(params):
    """Gather-once, replicated f32 gate weights for the time scan."""
    from jax.sharding import PartitionSpec as P

    return {
        "w_gates": shd.constrain(params["w_gates"].astype(jnp.float32), P(None, None)),
        "r_gates": shd.constrain(params["r_gates"].astype(jnp.float32), P(None, None)),
        "b_gates": params["b_gates"],
    }


def slstm_apply(
    params: dict,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    axes: shd.MeshAxes,
    return_state: bool = False,
):
    b, s, d = x.shape
    xf = x.astype(jnp.float32)
    zero = jnp.zeros((b, d), jnp.float32)
    carry = (zero, zero, zero, jnp.full((b, d), -1e30, jnp.float32))
    w = _slstm_weights(params)
    gx = xf @ w["w_gates"]                   # (B, S, 4D): one big matmul

    def step(carry, gx_t):
        return _slstm_step(w, carry, gx_t)

    final, hs = jax.lax.scan(step, carry, gx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    out = h @ params["out"].astype(x.dtype)
    if return_state:
        return out, SLSTMState(*final)
    return out


def slstm_decode(
    params: dict, x: jax.Array, state: SLSTMState, *, cfg: ModelConfig
) -> tuple[jax.Array, SLSTMState]:
    carry = (state.c, state.n, state.h, state.m)
    w = _slstm_weights(params)
    gx = x[:, 0].astype(jnp.float32) @ w["w_gates"]
    new_carry, h = _slstm_step(w, carry, gx)
    out = h[:, None].astype(x.dtype) @ params["out"].astype(x.dtype)
    return out, SLSTMState(*new_carry)
