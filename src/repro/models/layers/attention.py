"""Grouped-query attention with RoPE/M-RoPE, sliding windows, cross-attention
and KV-cache decode — the shared attention substrate for all assigned archs.

Conventions:
  * q heads are grouped over kv heads (GQA): q is reshaped to
    (B, S, KV, G, hd) with G = n_heads // n_kv_heads, and scores are computed
    with a grouped einsum so the KV tensor is never materialized at H width.
  * softmax in float32; outputs in the activation dtype.
  * full-sequence attention is **blockwise** (online-softmax scan over KV
    chunks, flash-attention style): the (Sq, Sk) score matrix is never
    materialized, so prefill_32k fits — at 32 768² a dense score tensor is
    ~17 GB/device, the chunked working set is ~70 MB.  Masks are computed
    arithmetically per chunk from global positions (no (S, S) mask tensor).
  * decode (Sq = 1) takes the direct path against the whole cache.
  * sharding: head dims carry the 'model' axis when divisible; activations
    are constrained at block edges by the caller.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers.rope import apply_mrope, apply_rope
from repro.models.schema import PSpec
from repro.parallel import sharding as shd

NEG_INF = -1e30
DEFAULT_KV_BLOCK = 1024


def attn_schema(cfg: ModelConfig, axes: shd.MeshAxes, *, cross: bool = False) -> dict:
    hd = cfg.head_dim_
    specs = shd.attn_specs(axes, cfg.n_heads, cfg.n_kv_heads, cfg.d_model, cfg.head_dim_)
    d = cfg.d_model
    out = {
        "wq": PSpec((d, cfg.n_heads * hd), specs["wq"], dtype=cfg.p_dtype),
        "wk": PSpec((d, cfg.n_kv_heads * hd), specs["wk"], dtype=cfg.p_dtype),
        "wv": PSpec((d, cfg.n_kv_heads * hd), specs["wv"], dtype=cfg.p_dtype),
        "wo": PSpec((cfg.n_heads * hd, d), specs["wo"], dtype=cfg.p_dtype),
    }
    return out


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, KV, hd)
    v: jax.Array   # (B, S_max, KV, hd)


def cache_shape(cfg: ModelConfig, batch: int, max_len: int) -> KVCache:
    hd = cfg.head_dim_
    s = jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, hd), cfg.act_dtype)
    return KVCache(k=s, v=s)


def cache_spec(cfg: ModelConfig, axes: shd.MeshAxes, global_batch: int = 0) -> KVCache:
    """Decode caches are the largest serving state (up to 412 GB at 32 k × 128)
    and must use every free mesh axis: batch over the divisible batch-axes
    prefix, then KV heads over 'model' when divisible, else sequence over
    'model' (SP) — 'model' is free at decode whenever the batch does not
    extend onto it (b=128 < 256), including for DP-only small archs."""
    ba = axes.batch_axes_for(global_batch) if global_batch else axes.batch
    used = set()
    if ba:
        used.update(ba if isinstance(ba, tuple) else (ba,))
    model_free = axes.model not in used
    msize = axes.model_size
    kv = axes.model if (model_free and cfg.n_kv_heads % msize == 0
                        and cfg.n_kv_heads >= msize) else None
    seq = axes.model if (model_free and kv is None) else None
    s = P(ba, seq, kv, None)
    return KVCache(k=s, v=s)


def _project_qkv(params, x, kv_x, cfg: ModelConfig, positions, pos_offset=None):
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    k = (src @ params["wk"].astype(x.dtype)).reshape(b, sk, cfg.n_kv_heads, hd)
    v = (src @ params["wv"].astype(x.dtype)).reshape(b, sk, cfg.n_kv_heads, hd)
    if cfg.rope_style == "rope" and positions is not None:
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        if kv_x is None:
            k = apply_rope(k, positions, theta=cfg.rope_theta)
    elif cfg.rope_style == "mrope" and positions is not None:
        q = apply_mrope(q, positions, theta=cfg.rope_theta, sections=tuple(cfg.mrope_sections))
        if kv_x is None:
            k = apply_mrope(k, positions, theta=cfg.rope_theta, sections=tuple(cfg.mrope_sections))
    return q, k, v


# ---------------------------------------------------------------------------
# Direct (small / decode) path
# ---------------------------------------------------------------------------


def _grouped_attention(q, k, v, mask, cfg: ModelConfig):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask broadcastable to (B,KV,G,Sq,Sk)."""
    b, sq, h, hd = q.shape
    kv = cfg.n_kv_heads
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h * hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blockwise (online-softmax) path — the full-sequence default
# ---------------------------------------------------------------------------


def _block_mask(q_pos, k_pos, *, causal, window, is_global):
    """(Sq, bk) bool validity from global positions.

    causal: key ≤ query.  window > 0 additionally restricts to the last
    ``window`` positions unless ``is_global`` (a traced scalar bool for
    hybrid layer stacks) lifts the restriction.
    """
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        in_win = k_pos[None, :] > q_pos[:, None] - window
        if is_global is None:
            m &= in_win
        else:
            m &= jnp.logical_or(is_global, in_win)
    return m


def blockwise_attention(
    q: jax.Array,                     # (B, Sq, H, hd)
    k: jax.Array,                     # (B, Sk, KV, hd)
    v: jax.Array,                     # (B, Sk, KV, hd)
    *,
    cfg: ModelConfig,
    causal: bool = True,
    window: int = 0,
    is_global=None,                   # traced scalar bool or None
    q_offset: int = 0,
    kv_block: int = DEFAULT_KV_BLOCK,
    unroll: int = 1,
) -> jax.Array:
    """Flash-style attention: scan KV chunks with a running (m, l, acc)."""
    b, sq, h, hd = q.shape
    kvh = cfg.n_kv_heads
    g = h // kvh
    sk = k.shape[1]
    bk = min(kv_block, sk)
    while sk % bk:
        bk //= 2
    nb = sk // bk
    scale = hd ** -0.5

    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    qg = jnp.transpose(qg, (0, 2, 3, 1, 4))                    # (B,KV,G,Sq,hd)
    ks = jnp.transpose(k.reshape(b, nb, bk, kvh, hd), (1, 0, 2, 3, 4))
    vs = jnp.transpose(v.reshape(b, nb, bk, kvh, hd), (1, 0, 2, 3, 4))
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        j, kc, vc = xs
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        s = jnp.einsum("bkgqh,btkh->bkgqt", qg, kc) * scale     # (B,KV,G,Sq,bk)
        k_pos = j * bk + jnp.arange(bk)
        valid = _block_mask(q_pos, k_pos, causal=causal, window=window, is_global=is_global)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgqt,btkh->bkgqh", p, vc)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.arange(nb), ks, vs),
                                  unroll=min(unroll, nb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]                # (B,KV,G,Sq,hd)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, h * hd)
    return out.astype(q.dtype)


def grouped_attention(
    q, k, v, *, cfg: ModelConfig, causal=True, window=0, is_global=None,
    q_offset: int = 0, kv_block: int = DEFAULT_KV_BLOCK, unroll: int = 1,
) -> jax.Array:
    """Dispatch: blockwise for full sequences, direct for tiny ones."""
    sq, sk = q.shape[1], k.shape[1]
    if sq == 1 or sk <= kv_block:
        if causal:
            q_pos = q_offset + jnp.arange(sq)
            k_pos = jnp.arange(sk)
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window, is_global=is_global)
            mask = mask[None, None, None]
        else:
            mask = None
        return _grouped_attention(q, k, v, mask, cfg)
    return blockwise_attention(
        q, k, v, cfg=cfg, causal=causal, window=window, is_global=is_global,
        q_offset=q_offset, kv_block=kv_block, unroll=unroll,
    )


def causal_mask(sq: int, sk: int, *, window: int = 0, offset: int = 0):
    """(Sq, Sk) mask; query i (global position i+offset) sees keys j ≤ i+offset,
    within ``window`` when sliding.  (Small-sequence/test helper; the model
    paths use arithmetic per-block masks.)"""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m


def attention(
    params: dict,
    x: jax.Array,                     # (B, S, D)
    *,
    cfg: ModelConfig,
    positions: Optional[jax.Array],
    causal: bool = True,
    window: int = 0,
    is_global=None,
    kv_x: Optional[jax.Array] = None,  # cross-attention source
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q, k, v = _project_qkv(params, x, kv_x, cfg, positions)
    caus = causal and kv_x is None
    out = grouped_attention(
        q, k, v, cfg=cfg, causal=caus, window=window, is_global=is_global
    )
    return out @ params["wo"].astype(x.dtype)


def decode_mask(cache_pos, s_max: int, *, window: int = 0, is_global=None):
    """(Sk,) validity for one decode step against a cache of length s_max."""
    t = jnp.arange(s_max)
    valid = t <= cache_pos
    if window > 0:
        in_win = t > cache_pos - window
        valid &= in_win if is_global is None else jnp.logical_or(is_global, in_win)
    return valid


def attention_decode(
    params: dict,
    x: jax.Array,                     # (B, 1, D)
    cache: KVCache,
    cache_pos: jax.Array,             # scalar int32: index to write
    *,
    cfg: ModelConfig,
    positions: jax.Array,             # (B, 1) or (B, 3, 1) or None
    window: int = 0,
    is_global=None,
) -> tuple[jax.Array, KVCache]:
    """One decode step against a persistent KV cache."""
    q, k_new, v_new = _project_qkv(params, x, None, cfg, positions)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), cache_pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), cache_pos, axis=1)
    valid = decode_mask(cache_pos, k.shape[1], window=window, is_global=is_global)
    mask = valid[None, None, None, None, :]
    out = _grouped_attention(q, k, v, mask, cfg)
    out = out @ params["wo"].astype(x.dtype)
    return out, KVCache(k=k, v=v)


def cross_cache_from_encoder(params, enc_out, cfg: ModelConfig) -> KVCache:
    """Precompute cross-attention K/V once per request (enc-dec serving)."""
    b, sk, _ = enc_out.shape
    hd = cfg.head_dim_
    k = (enc_out @ params["wk"].astype(enc_out.dtype)).reshape(b, sk, cfg.n_kv_heads, hd)
    v = (enc_out @ params["wv"].astype(enc_out.dtype)).reshape(b, sk, cfg.n_kv_heads, hd)
    return KVCache(k=k, v=v)


def cross_attention_cached(params, x, cross: KVCache, *, cfg: ModelConfig) -> jax.Array:
    """Decode-time cross attention against precomputed encoder K/V."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    out = grouped_attention(q, cross.k, cross.v, cfg=cfg, causal=False)
    return out @ params["wo"].astype(x.dtype)
