"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., hd) rotated pairwise with cos/sin (..., hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jax.Array,            # (B, S, H, hd)
    positions: jax.Array,    # (B, S) int32
    *,
    theta: float,
) -> jax.Array:
    hd = x.shape[-1]
    inv = _freqs(hd, theta)                              # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(
    x: jax.Array,            # (B, S, H, hd)
    positions: jax.Array,    # (B, 3, S) int32: (t, h, w) position streams
    *,
    theta: float,
    sections: tuple,         # frequency-bands per stream; sums to hd/2
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the hd/2 frequency bands are partitioned
    into (temporal, height, width) sections, each rotated by its own position
    stream.  For pure-text positions the three streams coincide and M-RoPE
    reduces to standard RoPE."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = _freqs(hd, theta)                                # (hd/2,)
    ang_all = positions[..., None].astype(jnp.float32) * inv  # (B, 3, S, hd/2)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[:, i, :, start : start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def positions_for(
    batch: int, seq: int, *, style: str, offset=0
) -> jax.Array:
    """Default position streams (text-only)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if style == "mrope":
        return jnp.broadcast_to(pos[:, None, :], (batch, 3, seq))
    return pos
