"""Mixture-of-Experts layer: GShard-style capacity dispatch, EP sharding, and
the paper's **tree router** as a first-class routing option.

Routing options
---------------
``router="softmax"``  — learned linear router, top-k of softmax probs.
``router="tree"``     — the paper's integration: a *soft decision tree*
  (core/soft_tree) over a learned projection of the hidden state produces the
  expert distribution during training (differentiable); at serving time the
  tree is **hardened** into the branchless breadth-first encoding and each
  token's expert is found with the speculative evaluator (Procedure 4/5) —
  per-token classification into E classes, exactly the paper's problem shape.

Dispatch
--------
Tokens are processed in fixed-size groups (``group_size``); each group builds
a (g, E, C) dispatch/combine tensor (GShard/T5X style) so all expert compute
is dense einsum, sharded E-over-'model' (expert parallelism).  Experts are
padded to a multiple of the model-axis size (phantom experts are masked to
-inf in the router) so EP stays dense for awkward counts (granite 40e → 48).

An alternative sort-based ``ragged`` path (jax.lax.ragged_dot) is provided
for the perf hillclimb; ``dispatch_einsum`` is the portable default.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import soft_tree as st
from repro.models.schema import PSpec
from repro.parallel import sharding as shd


def padded_experts(moe: MoEConfig, axes: shd.MeshAxes) -> int:
    m = axes.model_size
    if moe.n_experts % m == 0 or moe.n_experts < m:
        return max(moe.n_experts, 1)
    return ((moe.n_experts + m - 1) // m) * m


def moe_schema(cfg: ModelConfig, axes: shd.MeshAxes) -> dict:
    moe = cfg.moe
    assert moe is not None
    e_pad = padded_experts(moe, axes)
    specs = shd.moe_specs(axes, e_pad, moe.d_ff, cfg.d_model)
    d, f = cfg.d_model, moe.d_ff
    out = {
        "wi": PSpec((e_pad, d, f), specs["wi"], dtype=cfg.p_dtype),
        "wg": PSpec((e_pad, d, f), specs["wg"], dtype=cfg.p_dtype),
        "wo": PSpec((e_pad, f, d), specs["wo"], dtype=cfg.p_dtype),
    }
    if moe.router == "tree":
        depth = moe.tree_depth()
        n_internal = (1 << depth) - 1
        out["router_proj"] = PSpec((d, n_internal), P(None, None), dtype=jnp.float32)
        out["router_thr"] = PSpec((n_internal,), P(None), init="zeros", dtype=jnp.float32)
    else:
        out["router"] = PSpec((d, e_pad), P(None, None), dtype=jnp.float32)
    if moe.shared_d_ff:
        sspecs = shd.mlp_specs(axes, moe.shared_d_ff, cfg.d_model)
        out["shared_wi"] = PSpec((d, moe.shared_d_ff), sspecs["wi"], dtype=cfg.p_dtype)
        out["shared_wg"] = PSpec((d, moe.shared_d_ff), sspecs["wg"], dtype=cfg.p_dtype)
        out["shared_wo"] = PSpec((moe.shared_d_ff, d), sspecs["wo"], dtype=cfg.p_dtype)
    return out


# ---------------------------------------------------------------------------
# Routers
# ---------------------------------------------------------------------------


def _tree_cfg(cfg: ModelConfig, e_pad: int) -> st.SoftTreeConfig:
    moe = cfg.moe
    return st.SoftTreeConfig(
        depth=moe.tree_depth(),
        in_features=cfg.d_model,
        n_outputs=e_pad,
        temperature=1.0,
    )


def router_probs(params: dict, x: jax.Array, *, cfg: ModelConfig, e_pad: int) -> jax.Array:
    """(..., E_pad) routing probabilities; phantom experts get ~0 mass."""
    moe = cfg.moe
    xf = x.astype(jnp.float32)
    if moe.router == "tree":
        tcfg = _tree_cfg(cfg, e_pad)
        tp = st.SoftTreeParams(
            proj=params["router_proj"],
            threshold=params["router_thr"],
            leaf_map=jnp.arange(tcfg.n_leaves, dtype=jnp.int32) % moe.n_experts,
        )
        probs = st.output_probs(tcfg, tp, xf)  # mass only on real experts
        if e_pad > moe.n_experts:
            # output_probs already emits n_outputs=e_pad with zero phantom mass
            # because leaf_map targets only [0, n_experts).
            pass
        return probs
    logits = xf @ params["router"]
    if e_pad > moe.n_experts:
        mask = jnp.arange(e_pad) < moe.n_experts
        logits = jnp.where(mask, logits, -1e30)
    return jax.nn.softmax(logits, axis=-1)


def hard_tree_route(params: dict, x: jax.Array, *, cfg: ModelConfig, e_pad: int) -> jax.Array:
    """Serving-path routing with the paper's speculative evaluator.

    Projects tokens to per-node features and evaluates the hardened tree with
    branch-free speculative node evaluation + pointer jumping (pure-JAX
    formulation of the Pallas kernel; XLA fuses it into two matmuls and
    log₂(depth) gathers).  Returns (..., ) int32 expert ids.
    """
    from repro.core.eval_speculative import eval_speculative
    from repro.core.tree import BOTTOM

    moe = cfg.moe
    depth = moe.tree_depth()
    n_int = (1 << depth) - 1
    n_leaf = 1 << depth
    n = n_int + n_leaf
    z = x.astype(jnp.float32) @ params["router_proj"]          # (..., I)
    flat = z.reshape(-1, n_int)
    # hardened breadth-first encoding of the perfect router tree
    idx = jnp.arange(n, dtype=jnp.int32)
    is_leaf = idx >= n_int
    attr = jnp.where(is_leaf, 0, idx)
    thr = jnp.where(is_leaf, jnp.inf, jnp.concatenate([params["router_thr"], jnp.zeros(n_leaf)])[idx])
    child = jnp.where(is_leaf, idx, 2 * idx + 1)
    leaf_map = (jnp.arange(n_leaf, dtype=jnp.int32) % moe.n_experts)
    cls = jnp.where(is_leaf, jnp.concatenate([jnp.zeros(n_int, jnp.int32), leaf_map])[idx], BOTTOM)
    out = eval_speculative(
        flat, attr.astype(jnp.int32), thr.astype(jnp.float32), child.astype(jnp.int32),
        cls.astype(jnp.int32), max_depth=depth, jumps_per_round=2, use_onehot_matmul=True,
    )
    return out.reshape(x.shape[:-1])


# ---------------------------------------------------------------------------
# Dispatch-einsum MoE (GShard/T5X)
# ---------------------------------------------------------------------------


def _capacity(group: int, moe: MoEConfig, e_pad: int) -> int:
    c = int(math.ceil(group * moe.top_k * moe.capacity_factor / e_pad))
    return max(4, ((c + 3) // 4) * 4)


def moe_apply(
    params: dict,
    x: jax.Array,                 # (B, S, D)
    *,
    cfg: ModelConfig,
    axes: shd.MeshAxes,
    group_size: int = 512,
    serve_hard_tree: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar)."""
    moe = cfg.moe
    b, s, d = x.shape
    e_pad = params["wi"].shape[0]
    t = b * s
    g = min(group_size, t)
    n_groups = t // g
    assert n_groups * g == t, f"tokens {t} not divisible by group {g}"
    xg = x.reshape(n_groups, g, d)

    if serve_hard_tree and moe.router == "tree":
        # paper's serving path: hard speculative routing, uniform gates
        experts = hard_tree_route(params, xg, cfg=cfg, e_pad=e_pad)  # (n, g)
        k = moe.top_k
        top_idx = jnp.broadcast_to(experts[..., None], (n_groups, g, 1))
        if k > 1:
            # derive k diverse choices by re-routing shifted projections —
            # serving forests use k hardened trees; for the in-model path we
            # take the tree's choice plus (k-1) neighbours mod E.
            offs = jnp.arange(k, dtype=jnp.int32)[None, None, :]
            top_idx = (experts[..., None] + offs) % moe.n_experts
        top_gates = jnp.full((n_groups, g, k), 1.0 / k, jnp.float32)
        probs = jax.nn.one_hot(experts, e_pad, dtype=jnp.float32)
        aux = jnp.zeros((), jnp.float32)
    else:
        probs = router_probs(params, xg, cfg=cfg, e_pad=e_pad)       # (n, g, E)
        top_gates, top_idx = jax.lax.top_k(probs, moe.top_k)          # (n, g, k)
        top_gates = top_gates / jnp.clip(top_gates.sum(-1, keepdims=True), 1e-9)
        # Switch-style load-balance loss over real experts
        me = probs.mean(axis=(0, 1))                                  # (E,)
        onehot_top1 = jax.nn.one_hot(top_idx[..., 0], e_pad, dtype=jnp.float32)
        ce = onehot_top1.mean(axis=(0, 1))
        aux = moe.aux_loss_weight * e_pad * jnp.sum(me * ce)

    cap = _capacity(g, moe, e_pad)
    dtype = x.dtype

    dispatch = jnp.zeros((n_groups, g, e_pad, cap), dtype)
    combine = jnp.zeros((n_groups, g, e_pad, cap), jnp.float32)
    # running per-expert fill count across the k priority classes
    fill = jnp.zeros((n_groups, e_pad), jnp.int32)
    for j in range(moe.top_k):
        idx_j = top_idx[..., j]                                       # (n, g)
        mask_j = jax.nn.one_hot(idx_j, e_pad, dtype=jnp.int32)        # (n, g, E)
        pos_in_e = jnp.cumsum(mask_j, axis=1) - 1 + fill[:, None, :]  # (n, g, E)
        fill = fill + mask_j.sum(axis=1)
        pos_j = jnp.take_along_axis(pos_in_e, idx_j[..., None], axis=-1)[..., 0]
        keep = pos_j < cap
        oh_pos = jax.nn.one_hot(pos_j, cap, dtype=dtype) * keep[..., None].astype(dtype)
        oh_e = jax.nn.one_hot(idx_j, e_pad, dtype=dtype)
        d_j = oh_e[..., :, None] * oh_pos[..., None, :]               # (n, g, E, C)
        dispatch = dispatch + d_j
        combine = combine + d_j.astype(jnp.float32) * (
            top_gates[..., j] * keep.astype(jnp.float32)
        )[..., None, None]

    # --- expert compute (E sharded over 'model' = expert parallelism) ---
    exp_in = jnp.einsum("ngec,ngd->necd", dispatch, xg)
    exp_in = shd.constrain(exp_in, P(axes.batch_axes_for(n_groups), axes.shard_if(e_pad), None, None))
    h = jnp.einsum("necd,edf->necf", exp_in, params["wi"].astype(dtype))
    gate = jnp.einsum("necd,edf->necf", exp_in, params["wg"].astype(dtype))
    h = jax.nn.silu(gate) * h
    out_e = jnp.einsum("necf,efd->necd", h, params["wo"].astype(dtype))
    out_e = shd.constrain(out_e, P(axes.batch_axes_for(n_groups), axes.shard_if(e_pad), None, None))
    y = jnp.einsum("ngec,necd->ngd", combine.astype(dtype), out_e)

    if moe.shared_d_ff:
        hs = xg @ params["shared_wi"].astype(dtype)
        gs = xg @ params["shared_wg"].astype(dtype)
        y = y + (jax.nn.silu(gs) * hs) @ params["shared_wo"].astype(dtype)

    return y.reshape(b, s, d), aux
