"""Mamba-style selective SSM block (hymba's parallel-head SSM side).

Training/prefill uses a **chunked associative scan**: the sequence is split
into fixed chunks; within a chunk the linear recurrence
``h_t = dA_t ⊙ h_{t-1} + dB_t x_t`` is solved with
``jax.lax.associative_scan`` (log-depth, TPU-friendly) and the chunk boundary
state is carried by an outer ``lax.scan``.  The (B, chunk, Di, Ns) working set
stays VMEM/HBM-bounded while the model dim ``Di`` is sharded over 'model'.

Decode keeps a recurrent state per layer: ``(conv_state (B, W-1, Di),
ssm_state (B, Di, Ns))`` — O(1) in sequence length, which is what makes the
hybrid archs eligible for the long_500k cell.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.schema import PSpec
from repro.parallel import sharding as shd


class SSMState(NamedTuple):
    conv: jax.Array   # (B, W-1, Di)
    h: jax.Array      # (B, Di, Ns)


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.state_dim, s.conv_width


def ssm_schema(cfg: ModelConfig, axes: shd.MeshAxes) -> dict:
    d = cfg.d_model
    d_in, dt_rank, ns, w = _dims(cfg)
    di = axes.shard_if(d_in)
    pd = cfg.p_dtype
    return {
        "in_proj": PSpec((d, 2 * d_in), P(axes.fsdp_if(d), di), dtype=pd),
        "conv_w": PSpec((w, d_in), P(None, di), dtype=pd),
        "conv_b": PSpec((d_in,), P(di), init="zeros", dtype=pd),
        "x_dtbc": PSpec((d_in, dt_rank + 2 * ns), P(di, None), dtype=pd),
        "dt_proj": PSpec((dt_rank, d_in), P(None, di), dtype=pd),
        "dt_bias": PSpec((d_in,), P(di), init="zeros", dtype=pd),
        "a_log": PSpec((d_in, ns), P(di, None), init="ssm_log_a", dtype=jnp.float32),
        "d_skip": PSpec((d_in,), P(di), init="ones", dtype=jnp.float32),
        "out_proj": PSpec((d_in, d), P(di, axes.fsdp_if(d)), dtype=pd),
    }


def ssm_state_shape(cfg: ModelConfig, batch: int) -> SSMState:
    d_in, _, ns, w = _dims(cfg)
    return SSMState(
        conv=jax.ShapeDtypeStruct((batch, w - 1, d_in), cfg.act_dtype),
        h=jax.ShapeDtypeStruct((batch, d_in, ns), jnp.float32),
    )


def ssm_state_spec(cfg: ModelConfig, axes: shd.MeshAxes, global_batch: int = 0) -> SSMState:
    d_in, _, _, _ = _dims(cfg)
    di = axes.shard_if(d_in)
    ba = axes.batch_axes_for(global_batch) if global_batch else axes.batch
    return SSMState(conv=P(ba, None, di), h=P(ba, di, None))


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x (B,S,Di), w (W,Di) depthwise causal conv along S."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # W is tiny (4): unrolled shifts beat conv lowering
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _dt_b_c(params, x_a, cfg: ModelConfig):
    d_in, dt_rank, ns, _ = _dims(cfg)
    dtbc = x_a @ params["x_dtbc"].astype(x_a.dtype)
    dt_r, bm, cm = jnp.split(dtbc, [dt_rank, dt_rank + ns], axis=-1)
    dt = jax.nn.softplus(
        dt_r @ params["dt_proj"].astype(x_a.dtype) + params["dt_bias"].astype(x_a.dtype)
    )
    return dt.astype(jnp.float32), bm.astype(jnp.float32), cm.astype(jnp.float32)


def ssm_apply(
    params: dict,
    x: jax.Array,             # (B, S, D)
    *,
    cfg: ModelConfig,
    axes: shd.MeshAxes,
    chunk: int = 256,
) -> jax.Array:
    """Full-sequence selective scan (train / prefill)."""
    b, s, _ = x.shape
    d_in, _, ns, _ = _dims(cfg)
    xz = x @ params["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_a = jax.nn.silu(
        _causal_depthwise_conv(x_in, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype))
    )
    dt, bm, cm = _dt_b_c(params, x_a, cfg)
    a = -jnp.exp(params["a_log"])                        # (Di, Ns)
    x_f = x_a.astype(jnp.float32)

    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert n_chunks * chunk == s, (s, chunk)

    def scan_chunk(h0, args):
        dt_c, bm_c, cm_c, xa_c = args                    # (B, chunk, ...)
        da = jnp.exp(dt_c[..., None] * a)                # (B, c, Di, Ns)
        dbx = (dt_c * xa_c)[..., None] * bm_c[:, :, None, :]
        da = shd.constrain(da, P(axes.batch_axes_for(da.shape[0]), None, axes.shard_if(da.shape[2]), None))

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        pa, pb = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h = pb + pa * h0[:, None]                        # fold in carry
        y = (h * cm_c[:, :, None, :]).sum(-1)            # (B, c, Di)
        return h[:, -1], y

    reshape = lambda t: t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((b, d_in, ns), jnp.float32)
    _, ys = jax.lax.scan(scan_chunk, h0, (reshape(dt), reshape(bm), reshape(cm), reshape(x_f)))
    y = ys.swapaxes(0, 1).reshape(b, s, d_in)
    y = y + params["d_skip"] * x_f
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    return y @ params["out_proj"].astype(x.dtype)


def ssm_decode(
    params: dict,
    x: jax.Array,             # (B, 1, D)
    state: SSMState,
    *,
    cfg: ModelConfig,
) -> tuple[jax.Array, SSMState]:
    """Single-token recurrent step."""
    b = x.shape[0]
    d_in, _, ns, w = _dims(cfg)
    xz = x @ params["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)                  # (B,1,Di)
    window = jnp.concatenate([state.conv.astype(x.dtype), x_in], axis=1)  # (B,W,Di)
    conv_out = (window * params["conv_w"].astype(x.dtype)[None]).sum(axis=1, keepdims=True)
    x_a = jax.nn.silu(conv_out + params["conv_b"].astype(x.dtype))
    dt, bm, cm = _dt_b_c(params, x_a, cfg)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt[:, 0, :, None] * a)                  # (B, Di, Ns)
    dbx = (dt[:, 0] * x_a[:, 0].astype(jnp.float32))[..., None] * bm[:, 0, None, :]
    h = da * state.h + dbx
    y = (h * cm[:, 0, None, :]).sum(-1)                  # (B, Di)
    y = y + params["d_skip"] * x_a[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype) * jax.nn.silu(z))
    out = y @ params["out_proj"].astype(x.dtype)
    return out, SSMState(conv=window[:, 1:].astype(state.conv.dtype), h=h)
