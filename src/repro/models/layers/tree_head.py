"""Tree token-classification head — the paper's image-segmentation use case
transposed to tokens/patches.

A per-token classifier behind an LM/VLM backbone: the hidden state is
projected to one scalar feature per internal node of a perfect tree; during
training the head is a soft decision tree (differentiable, cross-entropy over
leaf-class probabilities); at serving the tree hardens into the paper's
breadth-first branchless encoding and every token is classified with the
speculative evaluator (Procedure 4/5) — per-token class assignment, exactly
the per-pixel segmentation workload of the paper's experiments (qwen2-vl
patch segmentation, whisper frame tagging).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import soft_tree as st
from repro.core.eval_speculative import eval_speculative
from repro.core.tree import BOTTOM
from repro.models.schema import PSpec


def tree_head_depth(n_classes: int) -> int:
    d = 1
    while (1 << d) < n_classes:
        d += 1
    return d


def tree_head_schema(cfg: ModelConfig) -> dict:
    depth = tree_head_depth(cfg.tree_head_classes)
    n_internal = (1 << depth) - 1
    return {
        "proj": PSpec((cfg.d_model, n_internal), P(None, None), dtype=jnp.float32),
        "thr": PSpec((n_internal,), P(None), init="zeros", dtype=jnp.float32),
    }


def _tree_cfg(cfg: ModelConfig) -> st.SoftTreeConfig:
    return st.SoftTreeConfig(
        depth=tree_head_depth(cfg.tree_head_classes),
        in_features=cfg.d_model,
        n_outputs=cfg.tree_head_classes,
    )


def tree_head_probs(params: dict, x: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    """Soft (training) path: (..., n_classes) class probabilities."""
    tcfg = _tree_cfg(cfg)
    tp = st.SoftTreeParams(
        proj=params["proj"],
        threshold=params["thr"],
        leaf_map=jnp.arange(tcfg.n_leaves, dtype=jnp.int32) % cfg.tree_head_classes,
    )
    return st.output_probs(tcfg, tp, x.astype(jnp.float32))


def tree_head_loss(params: dict, x: jax.Array, labels: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    """Cross-entropy over the soft tree's class distribution; labels < 0 masked."""
    probs = tree_head_probs(params, x, cfg=cfg)
    logp = jnp.log(jnp.clip(probs, 1e-9))
    gold = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    return -(gold * valid).sum() / jnp.maximum(valid.sum(), 1.0)


def tree_head_classify(params: dict, x: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    """Serving path: harden + speculative branchless evaluation (Procedure 4/5).

    Returns int32 class ids with the leading shape of ``x``.
    """
    depth = tree_head_depth(cfg.tree_head_classes)
    n_int = (1 << depth) - 1
    n_leaf = 1 << depth
    n = n_int + n_leaf
    z = x.astype(jnp.float32) @ params["proj"]          # (..., I)
    flat = z.reshape(-1, n_int)
    idx = jnp.arange(n, dtype=jnp.int32)
    is_leaf = idx >= n_int
    attr = jnp.where(is_leaf, 0, idx)
    thr_full = jnp.concatenate([params["thr"], jnp.zeros((n_leaf,), jnp.float32)])
    thr = jnp.where(is_leaf, jnp.inf, thr_full[idx])
    child = jnp.where(is_leaf, idx, 2 * idx + 1)
    leaf_map = jnp.arange(n_leaf, dtype=jnp.int32) % cfg.tree_head_classes
    cls_full = jnp.concatenate([jnp.zeros((n_int,), jnp.int32), leaf_map])
    cls = jnp.where(is_leaf, cls_full[idx], BOTTOM)
    out = eval_speculative(
        flat,
        attr.astype(jnp.int32),
        thr.astype(jnp.float32),
        child.astype(jnp.int32),
        cls.astype(jnp.int32),
        max_depth=depth,
        jumps_per_round=2,
        use_onehot_matmul=True,
    )
    return out.reshape(x.shape[:-1])
