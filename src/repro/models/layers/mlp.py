"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import PSpec
from repro.parallel import sharding as shd


def mlp_schema(cfg: ModelConfig, axes: shd.MeshAxes, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    specs = shd.mlp_specs(axes, d_ff, cfg.d_model)
    d = cfg.d_model
    out = {
        "wi": PSpec((d, d_ff), specs["wi"], dtype=cfg.p_dtype),
        "wo": PSpec((d_ff, d), specs["wo"], dtype=cfg.p_dtype),
    }
    if cfg.act == "silu":
        out["wg"] = PSpec((d, d_ff), specs["wg"], dtype=cfg.p_dtype)
    return out


def mlp(params: dict, x: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    h = x @ params["wi"].astype(x.dtype)
    if cfg.act == "silu":
        g = x @ params["wg"].astype(x.dtype)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["wo"].astype(x.dtype)


def rmsnorm_schema(cfg: ModelConfig) -> dict:
    return {"scale": PSpec((cfg.d_model,), init="ones", dtype=jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, *, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)
