"""Unified model construction: one factory for every assigned architecture.

All model classes expose the same protocol:

    schema() / param_shapes() / param_specs() / init(key)
    forward(params, batch) -> (logits, aux)
    loss(params, batch)    -> (loss, metrics)
    prefill(params, batch) -> (last_logits, cache)
    decode_step(params, cache, batch) -> (logits, cache)
    cache_shapes(batch, max_len) / cache_specs() / init_cache(batch, max_len)

Family dispatch: ``audio`` → :class:`EncDecModel`, ``ssm`` →
:class:`XLSTMModel`, everything else (dense/moe/hybrid/vlm) →
:class:`DecoderModel`.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.encdec import EncDecModel
from repro.models.lm import DecoderModel
from repro.models.xlstm_lm import XLSTMModel
from repro.parallel import sharding as shd


def build_model(
    cfg: ModelConfig,
    axes: shd.MeshAxes | None = None,
    parallel: ParallelConfig | None = None,
):
    axes = axes or shd.single_device_axes()
    parallel = parallel or ParallelConfig()
    if cfg.family == "audio":
        return EncDecModel(cfg, axes, parallel)
    if cfg.family == "ssm":
        return XLSTMModel(cfg, axes, parallel)
    return DecoderModel(cfg, axes, parallel)
