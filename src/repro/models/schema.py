"""Parameter schema: one declaration → shapes, shardings, and initializers.

Models declare their parameters as a nested dict of :class:`PSpec`; the
dry-run derives ``ShapeDtypeStruct`` trees from it (no allocation), jit gets
the matching ``PartitionSpec`` tree, and smoke tests materialize real arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple
    spec: P = P()
    init: str = "normal"    # normal | zeros | ones | ssm_log_a | uniform
    dtype: object = jnp.float32
    scale: float = 0.0      # 0 → fan-in default for "normal"


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def shapes_of(schema):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), schema, is_leaf=is_pspec
    )


def specs_of(schema):
    return jax.tree.map(lambda s: s.spec, schema, is_leaf=is_pspec)


def param_count(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_pspec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def _init_leaf(s: PSpec, key) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "ssm_log_a":
        # mamba: A initialised to -[1..N] per channel; store log(-A)=log(1..N)
        n = s.shape[-1]
        a = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), s.shape)
        return a.astype(s.dtype)
    if s.init == "uniform":
        return jax.random.uniform(key, s.shape, s.dtype, -0.5, 0.5)
    # fan-in scaled normal
    fan_in = s.shape[0] if len(s.shape) == 1 else int(np.prod(s.shape[:-1]))
    scale = s.scale or 1.0 / max(1.0, np.sqrt(fan_in))
    return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(s.dtype)


# f32-by-design leaves that must NOT be cast to the activation dtype
# (SSM decay constants, gate biases, norm scales, router params)
_KEEP_F32 = {
    "a_log", "d_skip", "w_if", "b_if", "b_gates", "scale",
    "router", "router_proj", "router_thr", "thr", "proj",
}


def cast_for_compute(params, act_dtype, specs=None):
    """bf16 working copy of the weights, made BEFORE any FSDP gather.

    Casting on the sharded storage halves both the all-gather wire bytes and
    the gathered temp footprint (mixed-precision ZeRO-3); the f32 master
    copy stays in the optimizer path.  1-D leaves and f32-by-design leaves
    keep their dtype.

    ``specs``: matching PartitionSpec tree — REQUIRED under a mesh, because
    GSPMD otherwise propagates the consumer's (replicated) sharding backward
    through the convert and all-gathers the *f32* master instead (measured:
    2× gather bytes on deepseek-67b, EXPERIMENTS.md §Perf).
    """
    import jax.sharding as jsh

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    spec_leaves = (
        jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, jsh.PartitionSpec))
        if specs is not None else [None] * len(flat)
    )
    out = []
    for (path, leaf), spec in zip(flat, spec_leaves):
        name = str(path[-1]).strip("[]'\"")
        if (
            hasattr(leaf, "ndim") and leaf.ndim >= 2
            and leaf.dtype in (jnp.float32, jnp.float64)
            and name not in _KEEP_F32
        ):
            cast = leaf.astype(act_dtype)
            if spec is not None:
                try:
                    cast = jax.lax.with_sharding_constraint(cast, spec)
                except (ValueError, RuntimeError):
                    pass
            out.append(cast)
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def init_params(schema, key):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_pspec)
    out = []
    for i, s in enumerate(leaves):
        out.append(_init_leaf(s, jax.random.fold_in(key, i)))
    return jax.tree.unflatten(treedef, out)
