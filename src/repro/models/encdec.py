"""Encoder-decoder model (whisper-medium backbone).

The conv audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d_model) — the mel→conv stack is
outside the lowered graph.  The encoder adds fixed sinusoidal positions and
runs bidirectional attention; the decoder is causal with cross-attention to
the encoder output and learned positional embeddings.

Decode caches both the decoder self-attention KV and the per-layer
cross-attention K/V (computed once from the encoder output at prefill) — the
standard enc-dec serving layout.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import schema as sch
from repro.models.layers import attention as attn
from repro.models.layers import mlp as mlpl
from repro.parallel import sharding as shd
from repro.utils.losses import chunked_softmax_xent, softmax_xent


class EncDecCache(NamedTuple):
    self_kv: attn.KVCache     # (L, B, S_max, KV, hd) decoder self-attn
    cross_kv: attn.KVCache    # (L, B, F, KV, hd) precomputed encoder K/V
    pos: jax.Array            # scalar int32


def sinusoid_positions(n_pos: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (n_pos, d)."""
    half = d // 2
    log_timescale = np.log(10_000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


@dataclasses.dataclass
class EncDecModel:
    cfg: ModelConfig
    axes: shd.MeshAxes
    parallel: ParallelConfig = ParallelConfig()
    max_positions: int = 32_768   # learned decoder positions table size

    def __post_init__(self):
        self.v_pad = shd.pad_vocab(self.cfg.vocab_size, self.axes)
        assert self.cfg.encoder is not None, "EncDecModel requires cfg.encoder"

    # ----------------------------- schema -----------------------------

    def _enc_layer_schema(self) -> dict:
        cfg, axes = self.cfg, self.axes
        return {
            "ln1": mlpl.rmsnorm_schema(cfg),
            "attn": attn.attn_schema(cfg, axes),
            "ln2": mlpl.rmsnorm_schema(cfg),
            "mlp": mlpl.mlp_schema(cfg, axes),
        }

    def _dec_layer_schema(self) -> dict:
        cfg, axes = self.cfg, self.axes
        return {
            "ln1": mlpl.rmsnorm_schema(cfg),
            "attn": attn.attn_schema(cfg, axes),
            "ln_x": mlpl.rmsnorm_schema(cfg),
            "cross": attn.attn_schema(cfg, axes, cross=True),
            "ln2": mlpl.rmsnorm_schema(cfg),
            "mlp": mlpl.mlp_schema(cfg, axes),
        }

    def _stack(self, layer: dict, n: int) -> dict:
        return jax.tree.map(
            lambda s: sch.PSpec((n, *s.shape), P(None, *s.spec), s.init, s.dtype, s.scale),
            layer,
            is_leaf=sch.is_pspec,
        )

    def schema(self) -> dict:
        cfg = self.cfg
        n_enc = cfg.encoder.n_layers
        return {
            "embed": {
                "table": sch.PSpec(
                    (self.v_pad, cfg.d_model), P(self.axes.tp_axis, None), dtype=cfg.p_dtype
                )
            },
            "pos_embed": sch.PSpec(
                (self.max_positions, cfg.d_model), P(None, None), dtype=cfg.p_dtype
            ),
            "enc_layers": self._stack(self._enc_layer_schema(), n_enc),
            "enc_norm": mlpl.rmsnorm_schema(cfg),
            "dec_layers": self._stack(self._dec_layer_schema(), cfg.n_layers),
            "final_norm": mlpl.rmsnorm_schema(cfg),
        }

    def param_shapes(self):
        return sch.shapes_of(self.schema())

    def param_specs(self):
        return sch.specs_of(self.schema())

    def init(self, key):
        return sch.init_params(self.schema(), key)

    def _remat(self, fn):
        if self.parallel.remat == "none":
            return fn
        if self.parallel.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        return jax.checkpoint(fn)

    # ------------------------------ encoder ------------------------------

    def encode(self, params, embeds: jax.Array) -> jax.Array:
        """(B, F, D) frame embeddings → encoder output (B, F, D)."""
        cfg, axes = self.cfg, self.axes
        f = embeds.shape[1]
        x = embeds.astype(cfg.act_dtype)
        x = x + sinusoid_positions(f, cfg.d_model).astype(cfg.act_dtype)[None]
        x = shd.constrain(x, P(axes.batch_axes_for(x.shape[0]), None, None))

        def body(xc, lp):
            h = mlpl.rmsnorm(lp["ln1"], xc, eps=cfg.norm_eps)
            a = attn.attention(lp["attn"], h, cfg=cfg, positions=None, causal=False)
            xc = xc + a
            h2 = mlpl.rmsnorm(lp["ln2"], xc, eps=cfg.norm_eps)
            xc = xc + mlpl.mlp(lp["mlp"], h2, cfg=cfg)
            xc = shd.constrain(xc, P(axes.batch_axes_for(xc.shape[0]), None, None))
            return xc, None

        body = self._remat(body)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return mlpl.rmsnorm(params["enc_norm"], x, eps=cfg.norm_eps)

    # ------------------------------ decoder ------------------------------

    def _embed_tokens(self, params, tokens, pos_start) -> jax.Array:
        cfg = self.cfg
        x = params["embed"]["table"].astype(cfg.act_dtype)[tokens]
        s = tokens.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(
            params["pos_embed"].astype(cfg.act_dtype), pos_start, s, axis=0
        )
        return x + pos[None]

    def _dec_layer(self, lp, x, enc_out):
        cfg, axes = self.cfg, self.axes
        h = mlpl.rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
        q, k, v = attn._project_qkv(lp["attn"], h, None, cfg, None)
        a = attn.grouped_attention(q, k, v, cfg=cfg, causal=True)
        x = x + a @ lp["attn"]["wo"].astype(x.dtype)
        hx = mlpl.rmsnorm(lp["ln_x"], x, eps=cfg.norm_eps)
        c = attn.attention(lp["cross"], hx, cfg=cfg, positions=None, kv_x=enc_out)
        x = x + c
        h2 = mlpl.rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        x = x + mlpl.mlp(lp["mlp"], h2, cfg=cfg)
        ba = axes.batch_axes_for(x.shape[0])
        sp = shd.free_model_seq(axes, x.shape[0], x.shape[1]) if self.parallel.seq_shard else None
        return shd.constrain(x, P(ba, sp, None))

    def _hidden(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """Final normed decoder hidden (params pre-cast by caller)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["embeds"])
        x = self._embed_tokens(params, batch["tokens"], 0)

        def body(xc, lp):
            return self._dec_layer(lp, xc, enc_out), None

        body = self._remat(body)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        return x, jnp.zeros((), jnp.float32)

    def forward(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """Teacher-forced decode over the full target sequence."""
        params = sch.cast_for_compute(params, self.cfg.act_dtype, self.param_specs())
        x, aux = self._hidden(params, batch)
        return self.logits(params, x), aux

    def logits(self, params, x) -> jax.Array:
        w = params["embed"]["table"].astype(x.dtype).T   # whisper ties embeddings
        ba = self.axes.batch_axes_for(x.shape[0])
        return shd.constrain(x @ w, P(ba, None, self.axes.tp_axis))

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        x, aux = self._hidden(params, batch)
        w = params["embed"]["table"].astype(x.dtype).T
        nll, _ = chunked_softmax_xent(x, w, batch["labels"], vocab_size=cfg.vocab_size)
        return nll + aux, {"nll": nll, "aux": aux}

    # ------------------------------- decode -------------------------------

    def cache_shapes(self, batch: int, max_len: int) -> EncDecCache:
        cfg = self.cfg
        hd = cfg.head_dim_
        l, f = cfg.n_layers, cfg.encoder.n_frames
        mk = lambda s_len: jax.ShapeDtypeStruct(
            (l, batch, s_len, cfg.n_kv_heads, hd), cfg.act_dtype
        )
        return EncDecCache(
            self_kv=attn.KVCache(k=mk(max_len), v=mk(max_len)),
            cross_kv=attn.KVCache(k=mk(f), v=mk(f)),
            pos=jax.ShapeDtypeStruct((), jnp.int32),
        )

    def cache_specs(self, global_batch: int = 0) -> EncDecCache:
        cfg, axes = self.cfg, self.axes
        ba = axes.batch_axes_for(global_batch) if global_batch else axes.batch
        used = set(ba if isinstance(ba, tuple) else ((ba,) if ba else ()))
        model_free = axes.model not in used
        msize = axes.model_size
        kv = axes.model if (model_free and cfg.n_kv_heads % msize == 0
                            and cfg.n_kv_heads >= msize) else None
        seq = axes.model if (model_free and kv is None) else None
        spec = P(None, ba, seq, kv, None)
        cross_spec = P(None, ba, None, kv, None)
        return EncDecCache(
            self_kv=attn.KVCache(k=spec, v=spec),
            cross_kv=attn.KVCache(k=cross_spec, v=cross_spec),
            pos=P(),
        )

    def init_cache(self, batch: int, max_len: int) -> EncDecCache:
        shapes = self.cache_shapes(batch, max_len)
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        return zeros._replace(pos=jnp.zeros((), jnp.int32))

    def prefill(self, params, batch, max_len: int | None = None) -> tuple[jax.Array, EncDecCache]:
        """Encode + teacher-forced prompt pass building both caches."""
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        enc_out = self.encode(params, batch["embeds"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed_tokens(params, tokens, 0)

        def body(xc, lp):
            h = mlpl.rmsnorm(lp["ln1"], xc, eps=cfg.norm_eps)
            q, k, v = attn._project_qkv(lp["attn"], h, None, cfg, None)
            a = attn.grouped_attention(q, k, v, cfg=cfg, causal=True)
            xc = xc + a @ lp["attn"]["wo"].astype(xc.dtype)
            hx = mlpl.rmsnorm(lp["ln_x"], xc, eps=cfg.norm_eps)
            cross = attn.cross_cache_from_encoder(lp["cross"], enc_out, cfg)
            xc = xc + attn.cross_attention_cached(lp["cross"], hx, cross, cfg=cfg)
            h2 = mlpl.rmsnorm(lp["ln2"], xc, eps=cfg.norm_eps)
            xc = xc + mlpl.mlp(lp["mlp"], h2, cfg=cfg)
            ba = self.axes.batch_axes_for(xc.shape[0])
            sp = (shd.free_model_seq(self.axes, xc.shape[0], xc.shape[1])
                  if self.parallel.seq_shard else None)
            xc = shd.constrain(xc, P(ba, sp, None))
            kv = attn.KVCache(k=k.astype(cfg.act_dtype), v=v.astype(cfg.act_dtype))
            return xc, (kv, cross)

        x, (self_kv, cross_kv) = jax.lax.scan(body, x, params["dec_layers"])
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        logits = self.logits(params, x[:, -1:, :])
        cross_kv = attn.KVCache(
            k=cross_kv.k.astype(cfg.act_dtype), v=cross_kv.v.astype(cfg.act_dtype)
        )
        if max_len is not None and max_len > s:
            pad = max_len - s
            self_kv = attn.KVCache(
                k=jnp.pad(self_kv.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                v=jnp.pad(self_kv.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            )
        return logits, EncDecCache(
            self_kv=self_kv, cross_kv=cross_kv, pos=jnp.asarray(s, jnp.int32)
        )

    def decode_step(self, params, cache: EncDecCache, batch) -> tuple[jax.Array, EncDecCache]:
        """One token per sequence. batch: {"tokens": (B, 1)}."""
        cfg = self.cfg
        params = sch.cast_for_compute(params, cfg.act_dtype, self.param_specs())
        tokens = batch["tokens"]
        pos = cache.pos
        x = self._embed_tokens(params, tokens, pos)

        def body(xc, xs):
            lp, kv_l, cross_l = xs
            h = mlpl.rmsnorm(lp["ln1"], xc, eps=cfg.norm_eps)
            a, new_kv = attn.attention_decode(
                lp["attn"], h, kv_l, pos, cfg=cfg, positions=None
            )
            xc = xc + a
            hx = mlpl.rmsnorm(lp["ln_x"], xc, eps=cfg.norm_eps)
            xc = xc + attn.cross_attention_cached(lp["cross"], hx, cross_l, cfg=cfg)
            h2 = mlpl.rmsnorm(lp["ln2"], xc, eps=cfg.norm_eps)
            xc = xc + mlpl.mlp(lp["mlp"], h2, cfg=cfg)
            return xc, new_kv

        x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], cache.self_kv, cache.cross_kv))
        x = mlpl.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        logits = self.logits(params, x)
        return logits, EncDecCache(self_kv=new_kv, cross_kv=cache.cross_kv, pos=pos + 1)
