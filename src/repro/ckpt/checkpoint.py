"""Fault-tolerant checkpointing: atomic step directories, async save,
manifest-driven restore, elastic re-sharding.

Layout (one directory per step, atomically renamed into place):

    <ckpt_dir>/
      step_000120/
        manifest.json       # tree structure, shapes, dtypes, step metadata
        leaf_00000.npy ...  # one file per pytree leaf
      LATEST                # text file: "step_000120"

Writes go to ``step_XXXX.tmp`` and are renamed only after every leaf + the
manifest are fsync'd — a crash mid-save never corrupts the restore target
(the paper-scale analogue: surviving preemption on any host).

``restore`` re-applies a target sharding tree via ``jax.device_put`` so a
checkpoint written on one mesh restarts on another (elastic scaling: N pods →
M pods re-sharding is a device_put with the new NamedSharding).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:06d}")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = _step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return final


class AsyncSaver:
    """Overlap checkpoint writes with training (single in-flight save)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None
        self.error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err

    def submit(self, ckpt_dir: str, step: int, tree: Any, *, extra=None):
        self.wait()
        # device_get on the main thread (arrays may be donated next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                self.last_path = save(ckpt_dir, step, host_tree, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[-1])


def restore(ckpt_dir: str, step: int, target_tree: Any, *, shardings: Any = None):
    """Load a checkpoint into the structure of ``target_tree``.

    ``shardings``: optional pytree of NamedSharding — leaves are device_put
    with the *target mesh's* sharding, which is how an elastic restart onto a
    different mesh re-shards the state.
    """
    final = _step_dir(ckpt_dir, step)
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        e = by_path.get(p)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(final, e["file"]))
        want = tuple(np.shape(leaf)) if hasattr(leaf, "shape") else tuple(e["shape"])
        if tuple(arr.shape) != tuple(want):
            raise ValueError(f"leaf {p!r} shape {arr.shape} != expected {want}")
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` step directories."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
