from repro.ckpt.checkpoint import AsyncSaver, latest_step, prune, restore, save
