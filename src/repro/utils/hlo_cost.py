"""Trip-count-aware static cost analysis of compiled (post-SPMD) HLO.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — for
scan-over-layers models that under-counts FLOPs/bytes/collective traffic by
the trip count (32–95× for the assigned archs).  This module parses the
compiled HLO text, reconstructs the computation call graph, extracts each
while loop's trip count from its condition, and accumulates:

  * ``flops``       — dot ops (2 × |result| × |contracted|, incl. dots inside
                      fusions) × loop multiplicity.
  * ``bytes``       — fusion-aware HBM traffic: Σ (operand + result bytes)
                      over *control-level* instructions (entry, while bodies,
                      conditional branches).  Slice-like ops charge the data
                      actually touched: slice/dynamic-slice/gather → 2×result;
                      dynamic-update-slice → 2×update (the untouched buffer is
                      aliased in place, the KV-cache decode pattern).
  * ``collectives`` — wire bytes per collective kind × loop multiplicity
                      (all-gather: gathered result; others: operand bytes).

Trip counts: jax scans lower to ``while`` whose condition compares an
induction variable to a constant K (direction=LT from 0 → trip=K); the
compare frequently lives inside a fused computation of the condition, so
constants and compares are searched one call level deep.

Validated against ``cost_analysis`` on unrolled programs and hand-counted
sharded examples (tests/test_hlo_cost.py).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_TOKEN = re.compile(r"([a-z]\d*[a-z]*\d*(?:e\d+m\d+(?:fn)?)?)\[([0-9,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*([\w\-]+)\((.*)$"
)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_OPERAND = re.compile(r"%([\w.\-]+)")

# ops that move no HBM bytes themselves (metadata / aliases / async halves)
SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "conditional",
    "copy-start", "copy-done", "all-gather-done", "all-reduce-done",
    "collective-permute-done", "send", "recv", "send-done", "recv-done",
    "domain", "opt-barrier",
}

SLICE_READ_OPS = {"slice", "dynamic-slice", "gather"}

COLLECTIVE_BASES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}

CONTROL_CALLERS = {"while", "conditional"}
FLOPS_ONLY_CALLERS = {
    "fusion", "call", "map", "reduce", "reduce-window", "scatter",
    "select-and-scatter", "sort",
}


def _shapes_of(text: str):
    return [(m.group(1), m.group(2)) for m in _SHAPE_TOKEN.finditer(text)]


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    result_text: str
    op: str
    rest: str
    line: str

    @property
    def result_bytes(self) -> int:
        return sum(_shape_bytes(d, s) for d, s in _shapes_of(self.result_text))

    @property
    def result_first_bytes(self) -> int:
        sh = _shapes_of(self.result_text)
        return _shape_bytes(*sh[0]) if sh else 0

    def attr(self, key: str):
        m = re.search(rf"{key}=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def called(self) -> list[str]:
        out = []
        for key in ("to_apply", "body", "condition", "calls"):
            v = self.attr(key)
            if v:
                out.append(v)
        m = re.search(r"branch_computations=\{([^}]*)\}", self.rest)
        if m:
            out += [p.strip().lstrip("%") for p in m.group(1).split(",") if p.strip()]
        return out

    def operands(self) -> list[str]:
        depth = 1
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return [m.group(1) for m in _OPERAND.finditer(self.rest[:end])]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    by_name: dict
    params: dict          # name -> shapes list (in declaration order)
    param_order: list     # param names ordered by parameter(k)


def parse_module(hlo: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    order: list[str] = []
    cur: Computation | None = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and "->" in line and line.endswith("{"):
            name = hdr.group(1)
            cur = Computation(name, [], {}, {}, [])
            comps[name] = cur
            order.append(name)
            if line.startswith("ENTRY"):
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4), line)
            cur.instrs.append(ins)
    for c in comps.values():
        c.by_name = {i.name: i for i in c.instrs}
        idx = {}
        for i in c.instrs:
            if i.op == "parameter":
                k = re.match(r"\s*(\d+)", i.rest)
                if k:
                    idx[int(k.group(1))] = i.name
                c.params[i.name] = _shapes_of(i.result_text)
        c.param_order = [idx[k] for k in sorted(idx)]
    if entry is None and order:
        entry = order[-1]
    return comps, entry


def _consts_in(comp: Computation, comps: dict, depth: int = 1) -> list[int]:
    out = []
    for ins in comp.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                out.append(int(m.group(1)))
        elif depth > 0:
            for c in ins.called():
                if c in comps:
                    out.extend(_consts_in(comps[c], comps, depth - 1))
    return out


def _compare_dir(comp: Computation, comps: dict, depth: int = 1):
    for ins in comp.instrs:
        if ins.op == "compare":
            m = re.search(r"direction=(\w+)", ins.rest)
            if m:
                return m.group(1)
        if depth > 0:
            for c in ins.called():
                if c in comps:
                    d = _compare_dir(comps[c], comps, depth - 1)
                    if d:
                        return d
    return None


def _trip_count(cond: Computation, comps: dict) -> int | None:
    consts = [c for c in _consts_in(cond, comps) if c > 0]
    if not consts:
        return None
    k = max(consts)
    dirn = _compare_dir(cond, comps)
    if dirn in ("LE", "GE"):
        return k + 1
    return k


def _dot_flops(ins: Instr, comp: Computation) -> int:
    res = _shapes_of(ins.result_text)
    if not res:
        return 0
    out_elems = _shape_elems(res[0][1])
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    ops = ins.operands()
    if not ops:
        return 2 * out_elems
    lhs_shapes = None
    src = comp.by_name.get(ops[0])
    if src is not None:
        lhs_shapes = _shapes_of(src.result_text)
    elif ops[0] in comp.params:
        lhs_shapes = comp.params[ops[0]]
    if not lhs_shapes or cdims is None:
        return 2 * out_elems
    dims = [int(x) for x in lhs_shapes[0][1].split(",")] if lhs_shapes[0][1] else []
    csize = 1
    for ci in cdims.group(1).split(","):
        if ci != "" and int(ci) < len(dims):
            csize *= dims[int(ci)]
    return 2 * out_elems * csize


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_count_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    unknown_trip_counts: int = 0

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll_bytes_by_kind.values())

    def coll_summary(self) -> str:
        parts = [
            f"{k}: {self.coll_count_by_kind[k]} ops, {self.coll_bytes_by_kind[k]/2**20:.1f} MiB"
            for k in sorted(self.coll_bytes_by_kind)
        ]
        return "; ".join(parts) if parts else "none"


def _operand_bytes(name: str, comp: Computation) -> int:
    src = comp.by_name.get(name)
    if src is not None:
        return src.result_bytes
    if name in comp.params:
        return sum(_shape_bytes(d, s) for d, s in comp.params[name])
    return 0


def _fusion_bytes(ins: Instr, comp: Computation, comps: dict) -> int:
    """Callsite HBM bytes of a fusion: operands + result, with slice-aware
    discounts for params consumed only by slicing ops and for in-place
    dynamic-update-slice (the KV-cache write pattern)."""
    called = None
    cname = ins.attr("calls")
    if cname and cname in comps:
        called = comps[cname]
    opnds = ins.operands()
    result_b = ins.result_bytes
    if called is None:
        return result_b + sum(_operand_bytes(o, comp) for o in opnds)

    # map fusion operand position -> inner param name
    inner = called.param_order
    dus_param0 = set()   # inner params that are DUS target buffers
    dus_update_bytes = 0
    for ci in called.instrs:
        if ci.op == "dynamic-update-slice":
            cops = ci.operands()
            if cops:
                if cops[0] in called.params:
                    dus_param0.add(cops[0])
                if len(cops) > 1:
                    dus_update_bytes += _operand_bytes(cops[1], called) or 0
                    # update operand may itself be an inner instr; count its size
                    usrc = called.by_name.get(cops[1])
                    if usrc is not None:
                        dus_update_bytes += 0  # already counted above via _operand_bytes

    total = 0
    dus_result_discount = False
    for pos, o in enumerate(opnds):
        pname = inner[pos] if pos < len(inner) else None
        full = _operand_bytes(o, comp)
        if pname is None:
            total += full
            continue
        consumers = [ci for ci in called.instrs if pname in ci.operands()]
        if pname in dus_param0:
            # in-place updated buffer: read ~update bytes, not the whole thing
            dus_result_discount = True
            continue
        if consumers and all(ci.op in SLICE_READ_OPS for ci in consumers):
            total += sum(ci.result_first_bytes for ci in consumers)
        else:
            total += full
    if dus_result_discount:
        # result aliases the big buffer; charge 2×update (read-modify-write)
        total += 2 * dus_update_bytes
    else:
        total += result_b
    return total


def analyze(hlo: str) -> HloCost:
    comps, entry = parse_module(hlo)
    cost = HloCost()

    def walk(comp_name: str, mult: float, charge_bytes: bool, in_loop: bool = False):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.op
            # XLA:CPU materializes while-carry copies that TPU elides via
            # buffer aliasing — skip them inside loop bodies (metadata-less
            # `copy` ops were 3.8 TB/step of phantom traffic on whisper train)
            if op == "copy" and in_loop:
                continue
            if op == "while":
                body = ins.attr("body")
                cond = ins.attr("condition")
                trip = None
                if cond and cond in comps:
                    trip = _trip_count(comps[cond], comps)
                if trip is None:
                    trip = 1
                    cost.unknown_trip_counts += 1
                if body and body in comps:
                    walk(body, mult * trip, charge_bytes, True)
                if cond and cond in comps:
                    walk(cond, mult * trip, False, True)
                continue
            if op == "conditional":
                for c in ins.called():
                    if c in comps:
                        walk(c, mult, charge_bytes, in_loop)
                continue
            if op in FLOPS_ONLY_CALLERS:
                for c in ins.called():
                    if c in comps:
                        walk(c, mult, False, in_loop)
            # --- flops ---
            if op == "dot":
                cost.flops += mult * _dot_flops(ins, comp)
            elif op == "convolution":
                res = _shapes_of(ins.result_text)
                if res:
                    cost.flops += mult * 2 * _shape_elems(res[0][1])
            # --- collectives ---
            base = op.replace("-start", "")
            if base in COLLECTIVE_BASES and not op.endswith("-done"):
                shapes = _shapes_of(ins.result_text)
                if base == "all-gather":
                    wire = sum(_shape_bytes(d, s) for d, s in shapes)
                else:
                    wire = sum(_operand_bytes(o, comp) for o in ins.operands())
                    if wire == 0:
                        wire = sum(_shape_bytes(d, s) for d, s in shapes)
                cost.coll_bytes_by_kind[base] += mult * wire
                cost.coll_count_by_kind[base] += 1
                continue
            # --- bytes ---
            if not charge_bytes or op in SKIP_BYTES_OPS:
                continue
            if op in SLICE_READ_OPS:
                cost.bytes += mult * 2 * ins.result_first_bytes
            elif op == "dynamic-update-slice":
                ops_ = ins.operands()
                upd = _operand_bytes(ops_[1], comp) if len(ops_) > 1 else 0
                cost.bytes += mult * 2 * upd
            elif op == "fusion":
                cost.bytes += mult * _fusion_bytes(ins, comp, comps)
            else:
                rb = ins.result_bytes
                ob = sum(_operand_bytes(o, comp) for o in ins.operands())
                cost.bytes += mult * (rb + ob)

    walk(entry, 1.0, True, False)
    return cost
