"""Sharding-friendly loss functions.

``softmax_xent`` computes masked next-token cross-entropy **without a gather
along the (model-sharded) vocab dim**: the gold logit is extracted with an
iota-compare-select reduction, which GSPMD partitions as local-select +
tiny all-reduce.  A ``take_along_axis`` on a sharded dim can instead lower to
an all-gather of the full (B, S, V) f32 logits — measured at ~33 GB/chip of
all-reduce traffic on the 16×16 mesh before this rewrite (EXPERIMENTS.md
§Perf, iteration 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(
    logits: jax.Array,      # (B, S, V_pad) any float dtype
    labels: jax.Array,      # (B, S) int32; < 0 = masked
    *,
    vocab_size: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (mean_nll, n_valid).  Padded vocab tail is excluded."""
    v_pad = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (v_pad,), 0)
    if v_pad > vocab_size:
        lg = jnp.where(vocab_ids < vocab_size, lg, -1e30)
    lse = jax.nn.logsumexp(lg, axis=-1)                      # (B, S)
    safe = jnp.maximum(labels, 0)
    onehot_sel = vocab_ids[None, None, :] == safe[..., None]  # (B, S, V)
    gold = jnp.sum(jnp.where(onehot_sel, lg, 0.0), axis=-1)   # local + tiny psum
    valid = (labels >= 0).astype(jnp.float32)
    n_valid = jnp.maximum(valid.sum(), 1.0)
    nll = ((lse - gold) * valid).sum() / n_valid
    return nll, n_valid


def chunked_softmax_xent(
    x: jax.Array,           # (B, S, D) final hidden states
    w: jax.Array,           # (D, V_pad) output projection
    labels: jax.Array,      # (B, S)
    *,
    vocab_size: int,
    chunk: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing the full (B, S, V) logits.

    Scans sequence chunks; each chunk's logits live only inside a
    rematerialized body (recomputed for backward), so peak memory is one
    chunk's logits instead of the whole tensor — the (B,S,V) f32 block was
    a ~3 GB/chip temp on the 70 B-class train cells.
    """
    b, s, d = x.shape
    c = min(chunk, s)
    while s % c:
        c //= 2
    n = s // c
    xs = x.reshape(b, n, c, d).swapaxes(0, 1)          # (n, B, c, D)
    ls = labels.reshape(b, n, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, args):
        nll_sum, n_valid = carry
        xc, lc = args
        logits = xc @ w                                 # (B, c, V)
        nll, valid = softmax_xent_sums(logits, lc, vocab_size=vocab_size)
        return (nll_sum + nll, n_valid + valid), None

    (nll_sum, n_valid), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xs, ls)
    )
    n_valid = jnp.maximum(n_valid, 1.0)
    return nll_sum / n_valid, n_valid


def softmax_xent_sums(logits, labels, *, vocab_size):
    """(sum_nll, n_valid) — unreduced building block for chunking."""
    v_pad = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (v_pad,), 0)
    if v_pad > vocab_size:
        lg = jnp.where(vocab_ids < vocab_size, lg, -1e30)
    lse = jax.nn.logsumexp(lg, axis=-1)
    safe = jnp.maximum(labels, 0)
    onehot_sel = vocab_ids[None, None, :] == safe[..., None]
    gold = jnp.sum(jnp.where(onehot_sel, lg, 0.0), axis=-1)
    valid = (labels >= 0).astype(jnp.float32)
    return ((lse - gold) * valid).sum(), valid.sum()
