"""HLO text analysis: collective-bytes accounting for the roofline.

``cost_analysis()`` reports FLOPs and memory bytes but not collective
traffic, so we parse the (post-SPMD-partitioning) compiled HLO and sum the
operand bytes of every communication op:

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute

Bytes are counted from the op's *output* shape for all-gather (the gathered
bytes cross the wire), *operand* shape for all-reduce / reduce-scatter /
all-to-all / collective-permute — a per-chip, per-step wire-byte estimate
matching the roofline's ``collective_bytes / (chips × link_bw)`` convention.
Ring-algorithm constant factors (2(n-1)/n etc.) are folded into the
effective link bandwidth constant, as is standard in roofline practice.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g. "bf16[16,4096,512]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*(?:e\d+m\d+(?:fn)?)?)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes(text: str):
    """All dtype[shape] groups appearing in one HLO instruction line."""
    return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(text)]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [
            f"{k}: {self.count_by_kind[k]} ops, {self.bytes_by_kind[k]/2**20:.1f} MiB"
            for k in sorted(self.bytes_by_kind)
        ]
        return "; ".join(parts) if parts else "none"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum wire bytes of every collective op in compiled HLO text."""
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        ls = line.strip()
        # instruction lines look like:  "%name = TYPE[SHAPE] kind(...), ..."
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start|-done)?\b", rhs)
        if not m:
            continue
        kind, phase = m.group(1), m.group(2)
        if phase == "-done":
            continue  # avoid double counting start/done pairs
        shapes = _first_shapes(rhs)
        if not shapes:
            continue
        # first shape group on the RHS = the op's result shape (tuple results
        # list every element; sum them for all-to-all tuples)
        if kind == "all-gather":
            total = _shape_bytes(*shapes[0])
        elif kind in ("reduce-scatter",):
            # result is the scattered shard; wire bytes ≈ operand = result × n;
            # count operand (appears after the op name) when present
            total = _shape_bytes(*shapes[0])
            ops = shapes[1:]
            if ops:
                total = max(total, max(_shape_bytes(*s) for s in ops))
        else:
            # all-reduce/all-to-all/collective-permute: result size = operand size
            total = _shape_bytes(*shapes[0])
        bytes_by[kind] += total
        count_by[kind] += 1
    return CollectiveStats(bytes_by_kind=dict(bytes_by), count_by_kind=dict(count_by))


def op_histogram(hlo_text: str, top: int = 20) -> list[tuple[str, int]]:
    """Instruction-kind frequency (debug aid for the perf loop)."""
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1].strip()
        m = re.search(r"\b([a-z][a-z0-9-]*)\(", rhs)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
