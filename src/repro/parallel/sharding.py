"""Divisibility-aware sharding policies.

The production mesh is fixed at (data=16, model=16) (+pod=2), but the assigned
architectures have head counts like 25 (hymba) and 4 (xlstm) and vocabs like
49 155 (granite) that do not divide 16.  Rather than hand-tuning each arch,
every tensor dimension asks the policy: *shard over this axis iff divisible*,
else fall back (replicate, or shard an alternative dimension).  Vocab is
handled by padding to a lane-and-axis multiple (see ``pad_vocab``) so the
embedding/logits shards stay dense.

``MeshAxes`` carries axis names + sizes so the same model code lowers on both
the single-pod and multi-pod meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 top-level API
    from jax import shard_map

    SHARD_MAP_KW = {"check_vma": False}
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401 (re-exported)

    SHARD_MAP_KW = {"check_rep": False}


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical→physical axis mapping for one mesh."""

    batch: tuple                    # e.g. ("data",) or ("pod", "data")
    model: str                      # "model"
    sizes: dict                     # axis name → size
    fsdp: Optional[str] = "data"    # axis for 2-D (FSDP) param sharding; None = off
    tp: bool = True                 # tensor-parallel over 'model'; False → the
                                    # model axis joins the batch axes (DP-only,
                                    # right for sub-1B archs where TP shards
                                    # are tiny and collectives dominate)

    @property
    def batch_size(self) -> int:
        out = 1
        for a in self.batch:
            out *= self.sizes[a]
        return out

    @property
    def model_size(self) -> int:
        return self.sizes[self.model]

    @property
    def tp_axis(self) -> Optional[str]:
        return self.model if self.tp else None

    def shard_if(self, dim: int, axis: Optional[str] = None):
        """Return the model axis name iff ``dim`` divides evenly, else None."""
        if not self.tp and (axis is None or axis == self.model):
            return None
        axis = axis or self.model
        size = self.sizes[axis] if isinstance(axis, str) else 1
        return axis if dim % size == 0 and dim >= size else None

    def fsdp_if(self, dim: int):
        """FSDP axis iff enabled and ``dim`` divides: params gain a second
        shard dim so 67–72 B-param archs fit 16 GB/chip (weights gathered
        just-in-time by XLA SPMD — the ZeRO-3 pattern)."""
        if self.fsdp is None:
            return None
        size = self.sizes.get(self.fsdp, 1)
        return self.fsdp if dim % size == 0 and dim >= size else None

    def batch_if(self, dim: int):
        """Batch axes iff divisible by the full batch extent, else None."""
        return self.batch if dim % self.batch_size == 0 and dim >= self.batch_size else None

    def batch_axes_for(self, dim: int):
        """Largest-product subset of the batch axes dividing ``dim``.

        A greedy prefix is not enough: whisper's global batch 256 on the
        2×16×16 DP-only mesh must pick (data, model)=256 and leave 'pod'
        idle, not the prefix (pod, data)=32 — the latter was an 8× per-device
        activation blowup (87 GB/chip, EXPERIMENTS.md §Dry-run)."""
        best, best_prod = None, 0
        n = len(self.batch)
        for mask in range(1, 1 << n):
            axes = tuple(self.batch[i] for i in range(n) if mask >> i & 1)
            prod = 1
            for a in axes:
                prod *= self.sizes[a]
            if dim % prod == 0 and prod > best_prod:
                best, best_prod = axes, prod
        return best


def from_mesh(mesh: Mesh, *, fsdp: bool = True, tp: bool = True) -> MeshAxes:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    batch = tuple(n for n in names if n in ("pod", "data"))
    if not tp:
        batch = batch + ("model",)   # DP-only: model axis carries batch
    return MeshAxes(
        batch=batch, model="model", sizes=sizes,
        fsdp="data" if fsdp else None, tp=tp,
    )


def single_device_axes() -> MeshAxes:
    """Degenerate axes for smoke tests on one device (everything replicated)."""
    return MeshAxes(batch=("data",), model="model", sizes={"data": 1, "model": 1})


def free_model_seq(axes: MeshAxes, batch_dim: int, seq_dim: int):
    """Sequence-parallel axis when 'model' is not already carrying batch.

    DP-only archs (whisper, xlstm) leave the model axis idle whenever the
    batch does not divide onto it (prefill_32k batch 32 < 256): sharding the
    sequence over that free axis recovers the 16× (§Perf iteration W1)."""
    ba = axes.batch_axes_for(batch_dim) or ()
    if axes.model in ba:
        return None
    m = axes.model_size
    return axes.model if (seq_dim % m == 0 and seq_dim >= m) else None


def constrain(x, spec: P):
    """``with_sharding_constraint`` that no-ops outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def pad_to_multiple(dim: int, size: int) -> int:
    """Round ``dim`` up to a multiple of ``size`` (the divisibility policy's
    other arm: when a dimension *must* shard, pad it dense instead of
    replicating — vocab padding and the dist executor's record/tree padding
    both go through here)."""
    if size <= 1:
        return dim
    return ((dim + size - 1) // size) * size


def pad_vocab(vocab: int, axes: MeshAxes, lane: int = 128) -> int:
    """Pad the vocabulary so it shards densely: multiple of lane·|model|."""
    return pad_to_multiple(vocab, lane * (axes.model_size if axes.tp else 1))


def forest_mesh(record_shards: int, tree_shards: int, devices=None) -> Mesh:
    """(records × trees) mesh over the first R·G devices.

    The ``repro.dist`` layout: axis ``"records"`` carries the data
    decomposition (the §3.6 M/P slicing lifted to devices), axis ``"trees"``
    carries the forest.  Plans may use fewer devices than the host exposes
    (a feasibility-clamped plan on a small workload), so this builds the
    mesh explicitly rather than via ``jax.make_mesh``.
    """
    import numpy as np

    devs = list(devices) if devices is not None else jax.devices()
    need = record_shards * tree_shards
    if need > len(devs):
        raise ValueError(f"plan needs {need} devices, host has {len(devs)}")
    grid = np.array(devs[:need], dtype=object).reshape(record_shards, tree_shards)
    return Mesh(grid, ("records", "trees"))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


# ---------------------------------------------------------------------------
# Canonical spec builders (dims listed logically; scan adds a leading L=None)
# ---------------------------------------------------------------------------


def attn_specs(
    axes: MeshAxes, n_heads: int, n_kv_heads: int, d_model: int = 0,
    head_dim: int = 0,
) -> dict:
    """QKV/O projection *storage* specs: 2-D (data × model) sharding of the
    flattened weight dims.  Storage sharding is decoupled from compute: the
    attention math runs on (batch × sequence)-sharded activations and XLA
    gathers the bf16 weights just-in-time (ZeRO-3) — so the model axis can
    shard the flattened H·hd dim even when the *head count* does not divide
    the mesh (deepseek-67b kv=8, hymba 25H, yi kv=4...)."""
    d = axes.fsdp_if(d_model) if d_model else None
    hd = head_dim or (d_model // max(n_heads, 1) if d_model else 0)
    q_out = axes.shard_if(n_heads * hd) if hd else axes.shard_if(n_heads)
    kv_out = axes.shard_if(n_kv_heads * hd) if hd else axes.shard_if(n_kv_heads)
    return {
        "wq": P(d, q_out),       # (D, H·hd) — flattened projection dims
        "wk": P(d, kv_out),
        "wv": P(d, kv_out),
        "wo": P(q_out, d),       # (H·hd, D)
    }


def mlp_specs(axes: MeshAxes, d_ff: int, d_model: int = 0) -> dict:
    f = axes.shard_if(d_ff)
    d = axes.fsdp_if(d_model) if d_model else None
    return {"wi": P(d, f), "wg": P(d, f), "wo": P(f, d)}


def moe_specs(axes: MeshAxes, n_experts: int, d_ff: int, d_model: int = 0) -> dict:
    e = axes.shard_if(n_experts)
    f = axes.shard_if(d_ff) if e is None else None  # EP first; else TP inside experts
    d = axes.fsdp_if(d_model) if d_model else None
    return {
        "wi": P(e, d, f),        # (E, D, F)
        "wg": P(e, d, f),
        "wo": P(e, f, d),        # (E, F, D)
    }


def embed_specs(axes: MeshAxes, d_model: int = 0) -> dict:
    d = axes.fsdp_if(d_model) if d_model else None
    return {"table": P(axes.model, d)}   # (V_padded, D): vocab-sharded


def norm_specs() -> dict:
    return {"scale": P(None)}


def prepend(spec_tree, extra=None):
    """Add a leading (layer-stack) dim to every spec in a tree."""
    return jax.tree.map(
        lambda s: P(extra, *s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_spec(axes: MeshAxes, *rest) -> P:
    return P(axes.batch, *rest)


def zero1_spec(spec: P, shape: Sequence[int], axes: MeshAxes) -> P:
    """ZeRO-1: additionally shard the largest unsharded dim over 'data'.

    Optimizer-state tensors follow their parameter spec; any dim not already
    sharded is a candidate for slicing over the data axis (classic optimizer
    state sharding).  Falls back to the parameter spec when nothing divides.
    """
    data = "data"
    used = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            used.update(entry)
        else:
            used.add(entry)
    if data in used:
        return spec
    n = axes.sizes.get(data, 1)
    best_dim, best_size = -1, 0
    for i, d in enumerate(shape):
        taken = spec[i] if i < len(spec) else None
        if taken is None and d % n == 0 and d > best_size and d >= n:
            best_dim, best_size = i, d
    if best_dim < 0:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts[best_dim] = data
    return P(*parts)
