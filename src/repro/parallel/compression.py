"""Cross-pod gradient compression: int8 quantized all-reduce + error feedback.

On a multi-pod mesh the 'pod' axis rides the slow DCN links; compressing the
cross-pod gradient reduction 4× (f32 → int8 on the wire) cuts the dominant
inter-pod collective term.  Scheme (1-bit-Adam-family, per-tensor scale):

  1. residual-corrected gradient  g' = g + e   (error feedback state e)
  2. per-tensor scale  s = max|g'| / 127, shared via a tiny f32 pmax
  3. q = round(g'/s) ∈ int8;  wire all-reduce in int16 (Σ over ≤ 256 pods
     of int8 fits int16), then dequantize with the shared scale
  4. e ← g' − dequant(q)  (local quantization error carried to next step)

The quantized reduction happens inside ``shard_map`` over the 'pod' axis only;
the intra-pod (data-axis) reduction stays f32 and is produced by the usual
pjit gradient psum.  With error feedback the compressed SGD/Adam trajectory
converges to the uncompressed one (Karimireddy et al. 2019).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(g / jnp.maximum(scale, 1e-20))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _compressed_reduce_leaf(g, e, axis_name: str, n_pods: int):
    """int8-wire mean-reduction of one gradient leaf over ``axis_name``."""
    gf = g.astype(jnp.float32) + e
    scale = jnp.max(jnp.abs(gf)) / 127.0
    scale = jax.lax.pmax(scale, axis_name)          # shared scale (1 f32 on wire)
    q = quantize(gf, scale)
    total = jax.lax.psum(q.astype(jnp.int16), axis_name)  # int16 wire format
    mean = dequantize(total, scale) / n_pods
    new_e = gf - dequantize(q, scale)               # local quantization error
    return mean.astype(g.dtype), new_e


def compressed_psum_tree(grads, err, *, axis_name: str, n_pods: int):
    return jax.tree.map(
        lambda g, e: _compressed_reduce_leaf(g, e, axis_name, n_pods), grads, err
    )


def init_error_feedback(param_shapes):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), param_shapes)


def error_feedback_shapes(param_shapes):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_shapes
    )


def cross_pod_compressed_mean(mesh, grads, err, specs):
    """Apply the compressed cross-pod reduction to a full gradient pytree.

    ``grads`` must already be reduced over the intra-pod axes (the usual pjit
    data-parallel mean) and replicated over 'pod'... — in the pjit flow we
    instead arrange the loss to mean over ('data',) only and do the pod-axis
    reduction here explicitly with shard_map.  Returns (mean_grads, new_err).
    """
    from repro.parallel.sharding import SHARD_MAP_KW as smap_kw
    from repro.parallel.sharding import shard_map

    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    if n_pods == 1:
        return grads, err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))

    def body(*args):
        k = len(args) // 2
        gs, es = args[:k], args[k:]
        outs = [_compressed_reduce_leaf(g, e, "pod", n_pods) for g, e in zip(gs, es)]
        return tuple(o[0] for o in outs) + tuple(o[1] for o in outs)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(flat_s) + tuple(flat_s),
        out_specs=tuple(flat_s) + tuple(flat_s),
        **smap_kw,
    )
    outs = fn(*flat_g, *flat_e)
    k = len(flat_g)
    new_g = jax.tree.unflatten(tdef, outs[:k])
    new_e = jax.tree.unflatten(tdef, outs[k:])
    return new_g, new_e
