"""Streaming chunker: double-buffer host→device transfer against evaluation.

The paper's t_s(M) = σ·M + γ transmission term is paid *serially* in its
CUDA timings — copy the whole record array in, run, copy assignments out.
For segmentation-scale streams (millions of records) the copy need not
serialize: JAX dispatch is asynchronous, so submitting chunk k+1's
``device_put`` + evaluation while chunk k is still running overlaps the σ·M
wire time with compute, hiding min(t_s, T_eval) per chunk.  The chunker
submits *before* it drains — chunk k+1's dispatch is queued before the host
blocks on chunk k — and keeps at most ``inflight`` chunks pending after each
submit settles, so host memory and device queues stay bounded.

Two per-chunk measurements land in :class:`StreamStats` (and in the caller's
stats via ``on_chunk``):

* ``chunk_ms`` — submit→ready latency, the stream analogue of
  ``TreeServeEngine``'s per-wave accounting;
* ``overlap_ratio`` — the fraction of this chunk's submit→ready window
  during which the *previous* chunk was still in flight, i.e. how much of
  the pipeline actually ran double-buffered (0.0 for the first chunk).

Chunking is only a win while the overlapped transfer outweighs the fixed
per-dispatch cost; on transfer-free backends (CPU, fully resident data) it
is pure overhead.  With ``auto_coalesce`` (default) the chunker measures its
own throughput per effective chunk size and grows the size — up to
``max_coalesce``× the configured ``chunk_records`` — while bigger chunks
keep winning, retreating to the best size seen when they stop.  The first
``eval()`` always runs at the configured ``chunk_records`` (sizes are only
explored once a baseline throughput exists), so one-shot callers see
exactly the chunk geometry they asked for.
"""

from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs


class StreamStats:
    """Chunker accounting on a :class:`repro.obs.Registry`.

    The pre-obs dataclass fields survive: scalars as read properties over
    locked instruments, the per-chunk sequences (``chunk_ms``,
    ``overlap_ratio``) as plain lists next to their histogram twins —
    benches take medians over the lists, dashboards read the histograms.
    """

    def __init__(self, registry: obs.Registry | None = None):
        self.registry = registry if registry is not None else obs.Registry()
        r = self.registry
        self.m_chunks = r.counter("stream.chunks", "chunks drained")
        self.m_records = r.counter("stream.records", "records streamed")
        self.m_wall_s = r.counter(
            "stream.wall_s", "submit-first → drain-last seconds, per eval()")
        self.m_chunk_ms = r.histogram(
            "stream.chunk_ms", "submit→ready latency per chunk")
        self.m_overlap = r.histogram(
            "stream.overlap_ratio",
            "fraction of each chunk's submit→ready window shared with the "
            "previous in-flight chunk",
            boundaries=obs.DEFAULT_RATIO_BOUNDARIES)
        self.g_coalesced = r.gauge(
            "stream.coalesced_chunk_records",
            "effective chunk size after throughput-feedback adaptation")
        self.m_coalesce = r.counter(
            "stream.coalesce_decisions",
            "throughput-feedback coalescing decisions", ("decision",))
        self.chunk_ms: list = []        # submit→ready per chunk
        # fraction of each chunk's submit→ready window shared with the
        # previous in-flight chunk (0.0 for the first chunk of an eval)
        self.overlap_ratio: list = []

    @property
    def chunks(self) -> int:
        return int(self.m_chunks.value)

    @property
    def records(self) -> int:
        return int(self.m_records.value)

    @property
    def wall_s(self) -> float:
        return self.m_wall_s.value

    @property
    def coalesced_chunk_records(self) -> int:
        return int(self.g_coalesced.value)


class StreamingChunker:
    """Chunked, overlap-friendly driver for a (sharded) forest evaluator.

    ``evaluator`` is any callable records → (T, m) that does *not* block on
    the device (:class:`repro.dist.ShardedForestEvaluator` by contract); the
    chunker owns synchronisation.  Sharding and divisibility padding happen
    inside the evaluator's single fused program, so each chunk costs exactly
    one asynchronous dispatch here.
    """

    def __init__(self, evaluator, *, chunk_records: int = 65536, inflight: int = 2,
                 stats: StreamStats | None = None, auto_coalesce: bool = True,
                 max_coalesce: int = 8,
                 registry: obs.Registry | None = None,
                 tracer: obs.Tracer | None = None):
        if chunk_records < 1:
            raise ValueError("chunk_records must be >= 1")
        self.evaluator = evaluator
        self.chunk_records = chunk_records
        self.inflight = max(1, inflight)
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.stats = stats if stats is not None else StreamStats(registry)
        self.auto_coalesce = auto_coalesce
        self.max_coalesce = max(1, int(max_coalesce))
        self._effective = chunk_records      # current adapted chunk size
        self._evals = 0
        self._tput: dict[int, float] = {}    # effective size → records/s (EMA)
        self._seen: set[int] = set()         # sizes whose compile eval is spent
        self._prev_ready: float | None = None

    def _drain_one(self, pending: deque, outs: list, on_chunk) -> None:
        out, t_submit, n = pending.popleft()
        with self.tracer.span("stream.drain", cat="stream", records=n) as dspan:
            arr = np.asarray(jax.block_until_ready(out))
        t_ready = time.perf_counter()
        latency_ms = (t_ready - t_submit) * 1e3
        window = max(t_ready - t_submit, 1e-9)
        if self._prev_ready is None:
            overlap = 0.0
        else:
            overlap = min(max((self._prev_ready - t_submit) / window, 0.0), 1.0)
        self._prev_ready = t_ready
        dspan.set(chunk_ms=round(latency_ms, 3), overlap=round(overlap, 3))
        self.stats.m_chunks.inc()
        self.stats.m_records.inc(n)
        self.stats.m_chunk_ms.observe(latency_ms)
        self.stats.m_overlap.observe(overlap)
        self.stats.chunk_ms.append(latency_ms)
        self.stats.overlap_ratio.append(overlap)
        if on_chunk is not None:
            on_chunk(latency_ms, n)
        outs.append(arr)

    def _note_eval(self, size: int, n_chunks: int, records: int, wall: float) -> None:
        """Throughput-feedback coalescing: grow the effective chunk size while
        bigger chunks keep winning, retreat to the best size seen when not."""
        if not self.auto_coalesce or records == 0 or wall <= 0.0:
            return
        if size not in self._seen:
            # the first eval at a new size pays jit compilation for the new
            # chunk shape; stay here one more eval and measure compile-free
            self._seen.add(size)
            self.stats.g_coalesced.set(self._effective)
            return
        tput = records / wall
        prev = self._tput.get(size)
        self._tput[size] = tput if prev is None else 0.5 * prev + 0.5 * tput
        best = max(self._tput, key=self._tput.get)
        if best != size:
            self._effective = best       # the explored size lost; go back
            decision = "retreat"
        else:
            cap = self.chunk_records * self.max_coalesce
            nxt = min(size * 2, cap)
            if n_chunks > 1 and nxt > size and nxt not in self._tput:
                self._effective = nxt    # current best; explore one size up
                decision = "grow"
            else:
                decision = "hold"
        self.stats.m_coalesce.labels(decision=decision).inc()
        self.tracer.instant("stream.coalesce", cat="stream", decision=decision,
                            size=size, effective=self._effective)
        self.stats.g_coalesced.set(self._effective)

    def eval(self, records, *, on_chunk=None) -> np.ndarray:
        """Evaluate a (possibly huge) record batch; returns host (T, M).

        ``on_chunk(latency_ms, n_records)`` fires as each chunk completes —
        serve engines feed their own stats through it.
        """
        rec = np.asarray(records, np.float32)
        m = rec.shape[0]
        t0 = time.perf_counter()
        pending: deque = deque()
        outs: list[np.ndarray] = []
        self._prev_ready = None
        # the first eval honours the configured chunk size exactly; adapted
        # sizes only apply once a baseline throughput has been measured
        size = self._effective if (self.auto_coalesce and self._evals > 0) else self.chunk_records
        n_chunks = 0
        with self.tracer.span("stream.eval", cat="stream", records=m,
                              chunk_records=size) as espan:
            for start in range(0, m, size):
                chunk = rec[start : start + size]
                # the executor's fused program shards/pads the chunk as part
                # of its single dispatch, so no explicit device_put hop is
                # needed — the dispatch (and with it the transfer) is queued
                # asynchronously
                with self.tracer.span("stream.chunk.submit", cat="stream",
                                      chunk=n_chunks, records=chunk.shape[0]):
                    out = self.evaluator(jnp.asarray(chunk))
                pending.append((out, time.perf_counter(), chunk.shape[0]))
                n_chunks += 1
                # submit-before-drain: the new chunk's dispatch is already
                # queued when the host blocks on the oldest one, so device
                # work never gaps on the drain; at most ``inflight`` stay
                # pending after it
                while len(pending) > self.inflight:
                    self._drain_one(pending, outs, on_chunk)
            while pending:
                self._drain_one(pending, outs, on_chunk)
            espan.set(chunks=n_chunks)
        wall = time.perf_counter() - t0
        self.stats.m_wall_s.inc(wall)
        self._evals += 1
        self._note_eval(size, n_chunks, m, wall)
        if not outs:
            n_trees = getattr(getattr(self.evaluator, "forest", None), "n_trees", 0)
            return np.zeros((n_trees, 0), np.int32)
        if len(outs) == 1:       # fully coalesced: no concat copy
            return outs[0]
        return np.concatenate(outs, axis=1)


def stream_eval_forest(forest, records, *, chunk_records: int = 65536, inflight: int = 2,
                       stats: StreamStats | None = None, **evaluator_kw) -> np.ndarray:
    """One-shot convenience: sharded + chunked forest evaluation.

    Args:
      forest: an ``EncodedForest`` (or list of encoded trees).
      records: (M, A) float batch, arbitrarily large — chunks of
        ``chunk_records`` stream through the sharded executor with at most
        ``inflight`` pending (double buffering at the default of 2).
      stats: optional :class:`StreamStats` to accumulate into.
      **evaluator_kw: forwarded to :class:`ShardedForestEvaluator`
        (``mesh``/``plan``/``decomposition``/``cache``/``autotune``/…).

    Returns:
      Host (T, M) int32 per-tree class assignments, bit-identical to the
      monolithic ``eval_forest_tuned`` call.
    """
    from repro.dist.executor import ShardedForestEvaluator

    ev = ShardedForestEvaluator(forest, **evaluator_kw)
    return StreamingChunker(ev, chunk_records=chunk_records, inflight=inflight,
                            stats=stats).eval(records)
