"""Streaming chunker: double-buffer host→device transfer against evaluation.

The paper's t_s(M) = σ·M + γ transmission term is paid *serially* in its
CUDA timings — copy the whole record array in, run, copy assignments out.
For segmentation-scale streams (millions of records) the copy need not
serialize: JAX dispatch is asynchronous, so submitting chunk k+1's
``device_put`` + evaluation while chunk k is still running overlaps the σ·M
wire time with compute, hiding min(t_s, T_eval) per chunk.  The chunker
keeps at most ``inflight`` chunks pending (double buffering at the default
of 2) so host memory and device queues stay bounded.

Per-chunk submit→ready latency lands in :class:`StreamStats` (and in the
caller's stats via ``on_chunk``) — the stream analogue of
``TreeServeEngine``'s per-wave accounting.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class StreamStats:
    chunks: int = 0
    records: int = 0
    wall_s: float = 0.0                 # submit-first → drain-last, per eval()
    chunk_ms: list = dataclasses.field(default_factory=list)  # submit→ready per chunk


class StreamingChunker:
    """Chunked, overlap-friendly driver for a (sharded) forest evaluator.

    ``evaluator`` is any callable records → (T, m) that does *not* block on
    the device (:class:`repro.dist.ShardedForestEvaluator` by contract); the
    chunker owns synchronisation.  When the evaluator exposes a
    ``record_sharding``, chunks are ``device_put`` with it so the transfer
    lands sharded — no gather-then-scatter hop through device 0.
    """

    def __init__(self, evaluator, *, chunk_records: int = 65536, inflight: int = 2,
                 stats: StreamStats | None = None):
        if chunk_records < 1:
            raise ValueError("chunk_records must be >= 1")
        self.evaluator = evaluator
        self.chunk_records = chunk_records
        self.inflight = max(1, inflight)
        self.stats = stats if stats is not None else StreamStats()

    def _drain_one(self, pending: deque, outs: list, on_chunk) -> None:
        out, t_submit, n = pending.popleft()
        arr = np.asarray(jax.block_until_ready(out))
        latency_ms = (time.perf_counter() - t_submit) * 1e3
        self.stats.chunks += 1
        self.stats.records += n
        self.stats.chunk_ms.append(latency_ms)
        if on_chunk is not None:
            on_chunk(latency_ms, n)
        outs.append(arr)

    def eval(self, records, *, on_chunk=None) -> np.ndarray:
        """Evaluate a (possibly huge) record batch; returns host (T, M).

        ``on_chunk(latency_ms, n_records)`` fires as each chunk completes —
        serve engines feed their own stats through it.
        """
        rec = np.asarray(records, np.float32)
        m = rec.shape[0]
        t0 = time.perf_counter()
        pending: deque = deque()
        outs: list[np.ndarray] = []
        for start in range(0, m, self.chunk_records):
            # drain before submit so at most ``inflight`` chunks are ever
            # resident (the documented double-buffer bound)
            while len(pending) >= self.inflight:
                self._drain_one(pending, outs, on_chunk)
            chunk = rec[start : start + self.chunk_records]
            sharding = getattr(self.evaluator, "record_sharding", None)
            dev = jnp.asarray(chunk)
            if sharding is not None and chunk.shape[0] % sharding.mesh.shape.get("records", 1) == 0:
                # full chunks land pre-sharded; a ragged tail chunk goes in
                # unsharded and picks up its padding inside the executor
                dev = jax.device_put(dev, sharding)
            out = self.evaluator(dev)
            pending.append((out, time.perf_counter(), chunk.shape[0]))
        while pending:
            self._drain_one(pending, outs, on_chunk)
        self.stats.wall_s += time.perf_counter() - t0
        if not outs:
            n_trees = getattr(getattr(self.evaluator, "forest", None), "n_trees", 0)
            return np.zeros((n_trees, 0), np.int32)
        return np.concatenate(outs, axis=1)


def stream_eval_forest(forest, records, *, chunk_records: int = 65536, inflight: int = 2,
                       stats: StreamStats | None = None, **evaluator_kw) -> np.ndarray:
    """One-shot convenience: sharded + chunked forest evaluation.

    Args:
      forest: an ``EncodedForest`` (or list of encoded trees).
      records: (M, A) float batch, arbitrarily large — chunks of
        ``chunk_records`` stream through the sharded executor with at most
        ``inflight`` pending (double buffering at the default of 2).
      stats: optional :class:`StreamStats` to accumulate into.
      **evaluator_kw: forwarded to :class:`ShardedForestEvaluator`
        (``mesh``/``plan``/``decomposition``/``cache``/``autotune``/…).

    Returns:
      Host (T, M) int32 per-tree class assignments, bit-identical to the
      monolithic ``eval_forest_tuned`` call.
    """
    from repro.dist.executor import ShardedForestEvaluator

    ev = ShardedForestEvaluator(forest, **evaluator_kw)
    return StreamingChunker(ev, chunk_records=chunk_records, inflight=inflight,
                            stats=stats).eval(records)
