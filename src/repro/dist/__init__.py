"""repro.dist — sharded forest evaluation across a device mesh.

The paper's §3 contest (data vs speculative decomposition, one SIMD engine)
gains a dimension at fleet scale: how to split M records and T trees across
D devices *before* choosing the kernel within each device.  This package is
that layer:

  plan.py      decomposition planner — extends the §3.6 CostModel
               (t_e, t_c, t_i, σ, γ) to a device mesh and ranks the
               record-sharded (R=D), tree-sharded (G=D) and hybrid (R×G)
               factorizations by predicted time.  See its docstring for the
               planner-term → §3.6-symbol map.
  executor.py  lowers the chosen ShardPlan over a (records × trees) Mesh
               with ``shard_map``, resolving the per-shard kernel through
               ``repro.tune`` so the autotuner remains the single selection
               point.  Exact: bit-identical to ``eval_forest_tuned`` for
               every plan; degrades to the plain tuned path on one device.
  stream.py    streaming chunker — double-buffers host→device transfer
               against evaluation (hides the paper's σ·M transmission term)
               and reports per-chunk latency, serve-engine style.

Entry points: ``repro.core.forest.eval_forest_sharded`` (functional) and
``repro.serve.ForestServeEngine`` (wave-batched serving).
"""

from repro.dist.executor import DistStats, ShardedForestEvaluator
from repro.dist.plan import (
    ForestWorkload,
    MeshCostModel,
    ShardPlan,
    calibrate_mesh_cost,
    enumerate_plans,
    make_plan,
    plan_forest,
    predicted_plan_time,
    shard_extents,
)
from repro.dist.stream import StreamingChunker, StreamStats, stream_eval_forest

__all__ = [
    "DistStats",
    "ForestWorkload",
    "MeshCostModel",
    "ShardPlan",
    "ShardedForestEvaluator",
    "StreamStats",
    "StreamingChunker",
    "calibrate_mesh_cost",
    "enumerate_plans",
    "make_plan",
    "plan_forest",
    "predicted_plan_time",
    "shard_extents",
    "stream_eval_forest",
]
