"""Decomposition planner: the §3.6 runtime model lifted to a device mesh.

The paper's analysis is a two-way contest on one SIMD engine — data
decomposition (Procedure 3, T₃) vs speculative decomposition (Procedure 5,
T₅) over M records of mean traversal depth d_µ.  At fleet scale the decision
gains a dimension: D devices can shard the *records* (each device evaluates
every tree over M/R records), the *trees* (each device evaluates T/G trees
over all records), or both (an R×G grid).  This module extends the closed
forms of :mod:`repro.core.analysis` with the mesh-level terms and picks the
factorization with the smallest predicted time.

Symbol map (planner term → §3.6 symbol):

  ``ForestWorkload.m``        → M   (record count)
  ``ForestWorkload.d_mu``     → d_µ (mean traversal depth; measured when the
                                executor has a batch sample, else the
                                geometry prior of ``tune.heuristic``)
  ``MeshCostModel.cm``        → t_e, t_c, t_i, σ, γ (per-engine constants)
  ``MeshCostModel.p_device``  → P   (processors *within* one device — the
                                SIMD lanes T₃/T₅ divide work over)
  ``ShardPlan.record_shards`` → R   (mesh extent of the M/R data slicing,
                                Procedure 3's ``D[m·p .. m(p+1))`` lifted
                                across devices)
  ``ShardPlan.tree_shards``   → G   (mesh extent over the forest; §3.6 is
                                single-tree, so T/G multiplies the per-tree
                                form instead of appearing inside it)
  ``MeshCostModel.sigma_*``   → σ   (t_s(M) = σ·M + γ transmission slopes,
                                split per operand: records in, tree tables
                                in, class assignments out)
  ``MeshCostModel.gamma_launch`` → γ + t_i (per-plan dispatch overhead)

Per-tree kernel time inside a device comes from
:func:`repro.tune.heuristic.predicted_times` — the same T₃/T₅ evaluation
dispatch uses — so the planner and the autotuner read one model.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.analysis import CostModel


@dataclasses.dataclass(frozen=True)
class ForestWorkload:
    """The (M, T, N, A, d, d_µ) operating point of one forest evaluation."""

    m: int          # M: records
    n_trees: int    # T: trees in the forest
    n_nodes: int    # N: nodes per (padded) tree
    n_attrs: int    # A: record attributes
    depth: int      # max root→leaf depth over the forest (edges)
    d_mu: float     # mean traversal depth (measured or prior)

    @classmethod
    def of(cls, forest, records, *, d_mu: float | None = None) -> "ForestWorkload":
        """Derive the workload from an EncodedForest + record batch.

        ``d_mu`` defaults to the geometry prior (§3.6: between log₂ N and
        depth); the executor passes a measured value when it has records.
        """
        import numpy as np

        from repro.tune.heuristic import default_d_mu
        from repro.tune.space import WorkloadShape

        shape = records.shape if hasattr(records, "shape") else np.asarray(records).shape
        depth = max(int(forest.max_depth), 1)
        if d_mu is None:
            d_mu = default_d_mu(
                WorkloadShape(m=int(shape[0]), n_nodes=int(forest.n_nodes),
                              n_attrs=int(shape[1]), depth=depth)
            )
        return cls(
            m=int(shape[0]),
            n_trees=int(forest.n_trees),
            n_nodes=int(forest.n_nodes),
            n_attrs=int(shape[1]),
            depth=depth,
            d_mu=max(float(d_mu), 1.0),
        )


@dataclasses.dataclass(frozen=True)
class MeshCostModel:
    """§3.6 constants plus the mesh-level transmission/overhead terms.

    Defaults are in node-evaluation units (t_e = t_c = 1, the paper's
    normalization): a record element costs ~5% of a node evaluation to move,
    and one dispatch costs ~50 node evaluations.  Absolute values only matter
    relatively — the planner ranks factorizations, it does not predict
    milliseconds.
    """

    cm: CostModel = CostModel(t_e=1.0, t_c=1.0)
    p_device: float = 128.0    # P per device: the 128-lane SIMD width
    sigma_rec: float = 0.05    # σ per record element scattered to a device
    sigma_tree: float = 0.05   # σ per tree-table element broadcast to a device
    sigma_out: float = 0.05    # σ per class assignment gathered back
    gamma_launch: float = 50.0 # γ + t_i: per-plan dispatch overhead


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """One (R, G) factorization with its model-predicted cost.

    ``algorithm`` is the §3.6 winner *within* a device shard (the same
    choice ``repro.tune``'s heuristic would make at the shard shape), kept
    for provenance — actual kernel selection happens through the tune cache
    at execution time.
    """

    record_shards: int          # R
    tree_shards: int            # G
    algorithm: str              # 'speculative' | 'data_parallel' (per-shard T₅ vs T₃)
    predicted: float            # model time units (rank-valid, not ms)

    @property
    def n_devices(self) -> int:
        return self.record_shards * self.tree_shards

    @property
    def decomposition(self) -> str:
        r, g = self.record_shards > 1, self.tree_shards > 1
        if r and g:
            return "hybrid"
        if r:
            return "records"
        if g:
            return "trees"
        return "single"


def shard_extents(wl: ForestWorkload, record_shards: int, tree_shards: int) -> tuple[int, int]:
    """(records, trees) held by each device, after divisibility padding."""
    return (
        math.ceil(max(wl.m, 1) / record_shards),
        math.ceil(wl.n_trees / tree_shards),
    )


def predicted_plan_time(
    wl: ForestWorkload,
    record_shards: int,
    tree_shards: int,
    mesh_cost: MeshCostModel = MeshCostModel(),
) -> tuple[float, str]:
    """Model time of the (R, G) factorization; returns (time, algorithm).

    Devices run concurrently, so the plan costs what one device pays:

        T(R, G) = (T/G) · min(T₃, T₅)(M/R; P_dev)          compute
                + σ_rec·(M/R)·A + σ_tree·(T/G)·4N          operand scatter
                + σ_out·(T/G)·(M/R)                        result gather
                + γ_launch                                 dispatch

    with T₃/T₅ evaluated by ``repro.tune.heuristic.predicted_times`` at the
    shard operating point (same closed forms dispatch uses).
    """
    from repro.tune.heuristic import predicted_times
    from repro.tune.space import WorkloadShape

    m_shard, t_shard = shard_extents(wl, record_shards, tree_shards)
    shape = WorkloadShape(m=m_shard, n_nodes=wl.n_nodes, n_attrs=wl.n_attrs, depth=wl.depth)
    times = predicted_times(shape, cm=mesh_cost.cm, d_mu=wl.d_mu, p_total=mesh_cost.p_device)
    algorithm = min(times, key=times.get)
    compute = t_shard * times[algorithm]
    scatter = (
        mesh_cost.sigma_rec * m_shard * wl.n_attrs
        + mesh_cost.sigma_tree * t_shard * 4 * wl.n_nodes  # 4 tables per tree
    )
    gather = mesh_cost.sigma_out * t_shard * m_shard
    return compute + scatter + gather + mesh_cost.gamma_launch, algorithm


def make_plan(
    wl: ForestWorkload,
    record_shards: int,
    tree_shards: int,
    mesh_cost: MeshCostModel = MeshCostModel(),
) -> ShardPlan:
    """An explicit (R, G) plan with its predicted cost filled in."""
    t, alg = predicted_plan_time(wl, record_shards, tree_shards, mesh_cost)
    return ShardPlan(record_shards=record_shards, tree_shards=tree_shards,
                     algorithm=alg, predicted=t)


def enumerate_plans(
    wl: ForestWorkload,
    n_devices: int,
    mesh_cost: MeshCostModel = MeshCostModel(),
) -> list[ShardPlan]:
    """Every feasible (R, G) factorization with R·G ≤ D, costed.

    Feasibility: no more record shards than records, no more tree shards
    than trees (an idle shard is never predicted-cheaper, but a plan may
    legitimately leave devices idle when the workload is too small to fill
    them).  The degenerate (1, 1) plan is always present.
    """
    out: dict[tuple[int, int], ShardPlan] = {}
    for r in range(1, n_devices + 1):
        if r > max(wl.m, 1):
            continue
        for g in range(1, n_devices // r + 1):
            if g > wl.n_trees:
                continue
            out[(r, g)] = make_plan(wl, r, g, mesh_cost)
    if (1, 1) not in out:
        out[(1, 1)] = make_plan(wl, 1, 1, mesh_cost)
    return sorted(out.values(), key=lambda p: (p.predicted, -p.record_shards, p.tree_shards))


def plan_forest(
    wl: ForestWorkload,
    n_devices: int | None = None,
    *,
    mesh_cost: MeshCostModel = MeshCostModel(),
    decomposition: str | None = None,
) -> ShardPlan:
    """Choose the cheapest predicted factorization for this workload.

    ``decomposition`` forces the family ('records' | 'trees' | 'hybrid') —
    used by the crossover bench and by callers that must match an existing
    mesh.  Ties break toward more record shards (replication-free operands).
    On one device the plan degrades to (1, 1) and the executor runs the
    plain tuned path with no ``shard_map``.
    """
    import jax

    if n_devices is None:
        n_devices = jax.device_count()
    plans = enumerate_plans(wl, n_devices, mesh_cost)
    if decomposition is not None:
        wanted = [p for p in plans if p.decomposition == decomposition]
        if not wanted:
            raise ValueError(
                f"no feasible {decomposition!r} plan for {wl} on {n_devices} devices"
            )
        plans = wanted
    return plans[0]
