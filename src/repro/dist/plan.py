"""Decomposition planner: the §3.6 runtime model lifted to a device mesh.

The paper's analysis is a two-way contest on one SIMD engine — data
decomposition (Procedure 3, T₃) vs speculative decomposition (Procedure 5,
T₅) over M records of mean traversal depth d_µ.  At fleet scale the decision
gains a dimension: D devices can shard the *records* (each device evaluates
every tree over M/R records), the *trees* (each device evaluates T/G trees
over all records), or both (an R×G grid).  This module extends the closed
forms of :mod:`repro.core.analysis` with the mesh-level terms and picks the
factorization with the smallest predicted time.

Symbol map (planner term → §3.6 symbol):

  ``ForestWorkload.m``        → M   (record count)
  ``ForestWorkload.d_mu``     → d_µ (mean traversal depth; measured when the
                                executor has a batch sample, else the
                                geometry prior of ``tune.heuristic``)
  ``MeshCostModel.cm``        → t_e, t_c, t_i, σ, γ (per-engine constants)
  ``MeshCostModel.p_device``  → P   (processors *within* one device — the
                                SIMD lanes T₃/T₅ divide work over)
  ``ShardPlan.record_shards`` → R   (mesh extent of the M/R data slicing,
                                Procedure 3's ``D[m·p .. m(p+1))`` lifted
                                across devices)
  ``ShardPlan.tree_shards``   → G   (mesh extent over the forest; §3.6 is
                                single-tree, so T/G multiplies the per-tree
                                form instead of appearing inside it)
  ``MeshCostModel.sigma_*``   → σ   (t_s(M) = σ·M + γ transmission slopes,
                                split per operand: records in, tree tables
                                in, class assignments out)
  ``MeshCostModel.gamma_launch`` → γ + t_i (per-plan dispatch overhead)
  ``MeshCostModel.gamma_axis``   → t_i per *used* mesh axis — the measured
                                collective-program cost that ranks
                                single-axis meshes over hybrids (calibrated
                                from BENCH_dist.json, see docs/tuning.md)

Per-tree kernel time inside a device comes from
:func:`repro.tune.heuristic.predicted_times` — the same T₃/T₅ evaluation
dispatch uses — so the planner and the autotuner read one model.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.analysis import CostModel


@dataclasses.dataclass(frozen=True)
class ForestWorkload:
    """The (M, T, N, A, d, d_µ) operating point of one forest evaluation."""

    m: int          # M: records
    n_trees: int    # T: trees in the forest
    n_nodes: int    # N: nodes per (padded) tree
    n_attrs: int    # A: record attributes
    depth: int      # max root→leaf depth over the forest (edges)
    d_mu: float     # mean traversal depth (measured or prior)

    @classmethod
    def of(cls, forest, records, *, d_mu: float | None = None) -> "ForestWorkload":
        """Derive the workload from an EncodedForest + record batch.

        ``d_mu`` defaults to the geometry prior (§3.6: between log₂ N and
        depth); the executor passes a measured value when it has records.
        """
        import numpy as np

        from repro.tune.heuristic import default_d_mu
        from repro.tune.space import WorkloadShape

        shape = records.shape if hasattr(records, "shape") else np.asarray(records).shape
        depth = max(int(forest.max_depth), 1)
        if d_mu is None:
            d_mu = default_d_mu(
                WorkloadShape(m=int(shape[0]), n_nodes=int(forest.n_nodes),
                              n_attrs=int(shape[1]), depth=depth)
            )
        return cls(
            m=int(shape[0]),
            n_trees=int(forest.n_trees),
            n_nodes=int(forest.n_nodes),
            n_attrs=int(shape[1]),
            depth=depth,
            d_mu=max(float(d_mu), 1.0),
        )


@dataclasses.dataclass(frozen=True)
class MeshCostModel:
    """§3.6 constants plus the mesh-level transmission/overhead terms.

    All constants are in node-evaluation units (t_e = t_c = 1, the paper's
    normalization).  Absolute values only matter relatively — the planner
    ranks factorizations, it does not predict milliseconds.

    Defaults are **calibrated** from the measured ``results/BENCH_dist.json``
    sweep on the forced-8-host-device CPU mesh via
    :func:`calibrate_mesh_cost` (derivation in ``docs/tuning.md``; re-run
    the fit after regenerating the sweep to keep these in step): the σ
    transmission slopes fit orders of magnitude below the old 0.05 priors
    (a host "mesh" has no wire — transfers are memcpys), and the dispatch
    overhead splits into a per-plan constant plus ``gamma_axis`` — a
    collective-program cost per *used* mesh axis.  σ_tree fit to zero and
    is floored at σ_rec/10 to preserve the record-vs-tree transfer
    asymmetry on meshes with a real interconnect.
    """

    cm: CostModel = CostModel(t_e=1.0, t_c=1.0)
    p_device: float = 128.0      # P per device: the 128-lane SIMD width
    sigma_rec: float = 1.1e-3    # σ per record element scattered to a device
    sigma_tree: float = 1.1e-4   # σ per tree-table element broadcast to a device
    sigma_out: float = 1.1e-3    # σ per class assignment gathered back
    gamma_launch: float = 135.0  # γ + t_i: per-plan dispatch overhead
    gamma_axis: float = 105.0    # per used mesh axis (R>1, G>1): collective program cost

    def n_axes(self, record_shards: int, tree_shards: int) -> int:
        """Mesh axes a (R, G) factorization actually uses (0, 1 or 2)."""
        return int(record_shards > 1) + int(tree_shards > 1)


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """One (R, G) factorization with its model-predicted cost.

    ``algorithm`` is the §3.6 winner *within* a device shard (the same
    choice ``repro.tune``'s heuristic would make at the shard shape), kept
    for provenance — actual kernel selection happens through the tune cache
    at execution time.
    """

    record_shards: int          # R
    tree_shards: int            # G
    algorithm: str              # 'speculative' | 'data_parallel' (per-shard T₅ vs T₃)
    predicted: float            # model time units (rank-valid, not ms)

    @property
    def n_devices(self) -> int:
        return self.record_shards * self.tree_shards

    @property
    def decomposition(self) -> str:
        r, g = self.record_shards > 1, self.tree_shards > 1
        if r and g:
            return "hybrid"
        if r:
            return "records"
        if g:
            return "trees"
        return "single"


def shard_extents(wl: ForestWorkload, record_shards: int, tree_shards: int) -> tuple[int, int]:
    """(records, trees) held by each device, after divisibility padding.

    Args:
      wl: the forest workload being factorized.
      record_shards/tree_shards: the (R, G) mesh extents.

    Returns:
      (M/R, T/G) rounded up — what one device actually evaluates once the
      executor pads records/trees to mesh-divisible counts.
    """
    return (
        math.ceil(max(wl.m, 1) / record_shards),
        math.ceil(wl.n_trees / tree_shards),
    )


def predicted_plan_time(
    wl: ForestWorkload,
    record_shards: int,
    tree_shards: int,
    mesh_cost: MeshCostModel = MeshCostModel(),
) -> tuple[float, str]:
    """Model time of the (R, G) factorization; returns (time, algorithm).

    Devices run concurrently, so the plan costs what one device pays:

        T(R, G) = (T/G) · min(T₃, T₅)(M/R; P_dev)          compute
                + σ_rec·(M/R)·A + σ_tree·(T/G)·4N          operand scatter
                + σ_out·(T/G)·(M/R)                        result gather
                + γ_launch + γ_axis·[(R>1) + (G>1)]        dispatch

    with T₃/T₅ evaluated by ``repro.tune.heuristic.predicted_times`` at the
    shard operating point (same closed forms dispatch uses).  The γ_axis
    term is the calibrated per-mesh-axis collective-program cost (§3.6's
    t_i paid once per sharded axis): it is what ranks single-axis meshes
    over hybrids when the transmission terms are small.
    """
    from repro.tune.heuristic import predicted_times
    from repro.tune.space import WorkloadShape

    m_shard, t_shard = shard_extents(wl, record_shards, tree_shards)
    shape = WorkloadShape(m=m_shard, n_nodes=wl.n_nodes, n_attrs=wl.n_attrs, depth=wl.depth)
    times = predicted_times(shape, cm=mesh_cost.cm, d_mu=wl.d_mu, p_total=mesh_cost.p_device)
    algorithm = min(times, key=times.get)
    compute = t_shard * times[algorithm]
    scatter = (
        mesh_cost.sigma_rec * m_shard * wl.n_attrs
        + mesh_cost.sigma_tree * t_shard * 4 * wl.n_nodes  # 4 tables per tree
    )
    gather = mesh_cost.sigma_out * t_shard * m_shard
    dispatch = mesh_cost.gamma_launch + mesh_cost.gamma_axis * mesh_cost.n_axes(
        record_shards, tree_shards
    )
    return compute + scatter + gather + dispatch, algorithm


def make_plan(
    wl: ForestWorkload,
    record_shards: int,
    tree_shards: int,
    mesh_cost: MeshCostModel = MeshCostModel(),
) -> ShardPlan:
    """An explicit (R, G) plan with its predicted cost filled in."""
    t, alg = predicted_plan_time(wl, record_shards, tree_shards, mesh_cost)
    return ShardPlan(record_shards=record_shards, tree_shards=tree_shards,
                     algorithm=alg, predicted=t)


def enumerate_plans(
    wl: ForestWorkload,
    n_devices: int,
    mesh_cost: MeshCostModel = MeshCostModel(),
) -> list[ShardPlan]:
    """Every feasible (R, G) factorization with R·G ≤ D, costed.

    Feasibility: no more record shards than records, no more tree shards
    than trees (an idle shard is never predicted-cheaper, but a plan may
    legitimately leave devices idle when the workload is too small to fill
    them).  The degenerate (1, 1) plan is always present.
    """
    out: dict[tuple[int, int], ShardPlan] = {}
    for r in range(1, n_devices + 1):
        if r > max(wl.m, 1):
            continue
        for g in range(1, n_devices // r + 1):
            if g > wl.n_trees:
                continue
            out[(r, g)] = make_plan(wl, r, g, mesh_cost)
    if (1, 1) not in out:
        out[(1, 1)] = make_plan(wl, 1, 1, mesh_cost)
    return sorted(out.values(), key=lambda p: (p.predicted, -p.record_shards, p.tree_shards))


def calibrate_mesh_cost(
    bench_path,
    *,
    p_device: float = 128.0,
    min_gamma: float = 1.0,
    sigma_tree_floor_frac: float = 0.1,
) -> MeshCostModel:
    """Fit σ slopes + γ terms to a measured ``BENCH_dist.json`` sweep.

    The planner's prediction is linear in its unknown constants once the
    §3.6 compute term is evaluated at each (workload, mesh) point:

        T(R, G) ≈ α·compute + β_rec·(M/R)·A + β_tree·(T/G)·4N
                  + β_out·(T/G)·(M/R) + γ_ax·[(R>1)+(G>1)] + γ₀   [ms]

    A one-shot regression is ill-posed on sweep data, because R·G = D is
    constant across a workload's meshes: the compute and result-gather
    terms are then *identical* within every workload and only vary across
    the few workloads.  The fit is therefore staged:

      1. **within-workload** (per-workload demeaned rows): identifies the
         slopes that rank meshes — β_rec, β_tree and the per-axis
         dispatch cost γ_ax — free of workload-level offsets;
      2. **across-workload** (one equation per workload mean, stage-1
         slopes subtracted, β_out tied to β_rec — assignments ride the
         same wire as records): identifies the scale α (ms per
         node-evaluation unit) and the constant launch cost γ₀.

    Negative stage-1 coefficients are clamped to zero (the constants are
    physically non-negative; on a forced-host "mesh" the transfer slopes
    genuinely fit ≈ 0 — there is no wire).  Dividing the millisecond
    coefficients by α returns them to the planner's node-evaluation
    units: ``σ* = β*/α``, ``γ_axis = γ_ax/α``, ``γ_launch = γ₀/α``.

    Args:
      bench_path: path to a ``results/BENCH_dist.json`` written by
        ``benchmarks/dist_sweep.py`` (needs ``summaries[].workload_shape``
        and the per-mesh ``entries[]``).
      p_device: P per device when evaluating the compute term (must match
        what the planner will use).
      min_gamma: floor for ``gamma_launch`` (a zero launch overhead makes
        the planner prefer degenerate over-sharding).
      sigma_tree_floor_frac: floor for σ_tree as a fraction of σ_rec,
        preserving the record-vs-tree transfer asymmetry when σ_tree fits
        to zero.

    Returns:
      A :class:`MeshCostModel` with fitted ``sigma_*`` / ``gamma_*``.  The
      derivation — and the fitted constants baked into this class's
      defaults — is recorded in ``docs/tuning.md``.
    """
    import json
    from pathlib import Path

    import numpy as np

    from repro.tune.heuristic import predicted_times
    from repro.tune.space import WorkloadShape

    raw = json.loads(Path(bench_path).read_text())
    shapes = {s["workload"]: s["workload_shape"] for s in raw.get("summaries", [])}
    per_wl: dict[str, list[dict]] = {}
    for e in raw.get("entries", []):
        if e.get("mode") or e["workload"] not in shapes:
            continue  # streaming entries measure overlap, not the plan form
        wl_ = ForestWorkload(**shapes[e["workload"]])
        r, g = e["mesh"]
        m_shard, t_shard = shard_extents(wl_, r, g)
        shape = WorkloadShape(m=m_shard, n_nodes=wl_.n_nodes,
                              n_attrs=wl_.n_attrs, depth=wl_.depth)
        times = predicted_times(shape, d_mu=wl_.d_mu, p_total=p_device)
        per_wl.setdefault(e["workload"], []).append({
            "compute": t_shard * min(times.values()),
            "rec": m_shard * wl_.n_attrs,
            "tree": t_shard * 4 * wl_.n_nodes,   # 4 tables per tree
            "out": t_shard * m_shard,
            "axes": float((r > 1) + (g > 1)),
            "ms": float(e["measured_ms"]),
        })
    n_rows = sum(len(v) for v in per_wl.values())
    if len(per_wl) < 2 or n_rows < 6:
        raise ValueError(f"{bench_path}: too few plan entries to fit ({n_rows})")

    # stage 1: per-workload demeaned slopes (β_rec, β_tree, γ_ax in ms)
    xs, ys = [], []
    for rows in per_wl.values():
        f = lambda k: np.array([r[k] for r in rows], float)  # noqa: E731
        cols = np.stack([f("rec"), f("tree"), f("axes")], axis=1)
        xs.append(cols - cols.mean(axis=0))
        ys.append(f("ms") - f("ms").mean())
    sol, *_ = np.linalg.lstsq(np.concatenate(xs), np.concatenate(ys), rcond=None)
    b_rec, b_tree, b_axis = np.maximum(sol, 0.0)

    # stage 2: workload means identify α and γ₀ (β_out tied to β_rec)
    lhs, rhs = [], []
    for rows in per_wl.values():
        f = lambda k: np.mean([r[k] for r in rows])  # noqa: E731
        resid = (
            f("ms") - b_rec * f("rec") - b_tree * f("tree")
            - b_rec * f("out") - b_axis * f("axes")
        )
        lhs.append([f("compute"), 1.0])
        rhs.append(resid)
    (alpha, gamma0), *_ = np.linalg.lstsq(np.asarray(lhs), np.asarray(rhs), rcond=None)
    if alpha <= 0:
        # measured times anti-correlated with the compute term: the data
        # cannot anchor the unit scale, keep the current defaults
        return MeshCostModel(p_device=p_device)

    sigma_rec = float(b_rec / alpha)
    sigma_tree = float(max(b_tree / alpha, sigma_tree_floor_frac * sigma_rec))
    return MeshCostModel(
        p_device=p_device,
        sigma_rec=sigma_rec,
        sigma_tree=sigma_tree,
        sigma_out=sigma_rec,
        gamma_launch=float(max(gamma0 / alpha, min_gamma)),
        gamma_axis=float(b_axis / alpha),
    )


def plan_forest(
    wl: ForestWorkload,
    n_devices: int | None = None,
    *,
    mesh_cost: MeshCostModel = MeshCostModel(),
    decomposition: str | None = None,
) -> ShardPlan:
    """Choose the cheapest predicted factorization for this workload.

    ``decomposition`` forces the family ('records' | 'trees' | 'hybrid') —
    used by the crossover bench and by callers that must match an existing
    mesh.  Ties break toward more record shards (replication-free operands).
    On one device the plan degrades to (1, 1) and the executor runs the
    plain tuned path with no ``shard_map``.
    """
    import jax

    if n_devices is None:
        n_devices = jax.device_count()
    plans = enumerate_plans(wl, n_devices, mesh_cost)
    if decomposition is not None:
        wanted = [p for p in plans if p.decomposition == decomposition]
        if not wanted:
            raise ValueError(
                f"no feasible {decomposition!r} plan for {wl} on {n_devices} devices"
            )
        plans = wanted
    return plans[0]
