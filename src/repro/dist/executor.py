"""Multi-device executor: lower a ShardPlan over a (records × trees) mesh.

Lowering maps the planner's symbols onto ``shard_map``:

  R = plan.record_shards → mesh axis ``"records"``: each device column holds
      M/R records — Procedure 3's ``D[m·p .. m(p+1))`` slicing at mesh level.
  G = plan.tree_shards   → mesh axis ``"trees"``: each device row holds T/G
      stacked tree encodings (the forest analogue of the paper's replicated
      constant-memory tree).
  per-shard kernel       → resolved through ``repro.tune`` at the *shard*
      operating point, forest-first: the ForestShape bucket (M/R records ×
      T/G trees) is consulted for a stored shared-family winner, falling
      back to the per-tree chain (:class:`repro.tune.TunedEvaluator`) at
      the shard record shape — the autotuner stays the single selection
      point; the winning candidate's (algorithm, jump mode, jump count)
      lowers via its array-level formulation
      (:func:`repro.core.eval_speculative.eval_speculative` /
      :func:`repro.core.eval_dataparallel.eval_data_parallel`) inside the
      shard body, vmapped over the local tree axis.

Padding follows the divisibility policy of :mod:`repro.parallel.sharding`:
records pad to a multiple of R with zero rows (sliced off the output), trees
pad to a multiple of G by repeating tree 0 (rows discarded) — both are the
§3.2 phantom-node trick applied to the mesh axes.  All variants are exact,
so any plan returns results bit-identical to ``eval_forest_tuned``; on a
single device the executor *is* ``eval_forest_tuned`` (no ``shard_map`` in
the path at all).
"""

from __future__ import annotations

import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core.eval_dataparallel import eval_data_parallel
from repro.core.eval_speculative import eval_speculative
from repro.core.forest import EncodedForest
from repro.dist.plan import ForestWorkload, MeshCostModel, ShardPlan, make_plan, plan_forest
from repro.kernels.tree_eval.ops import get_variant
from repro.parallel import sharding as shd
from repro.parallel.sharding import SHARD_MAP_KW as _SMAP_KW
from repro.parallel.sharding import shard_map as _shard_map


class DistStats:
    """Executor accounting on a :class:`repro.obs.Registry`.

    ``resolve_source`` stays a plain last-write attribute (tests assert on
    the latest provenance); each resolution also lands in the labelled
    ``dist.resolutions{source=...}`` counter so a snapshot shows the full
    cache-hit/heuristic mix, not just the most recent outcome.
    """

    def __init__(self, registry: obs.Registry | None = None):
        self.registry = registry if registry is not None else obs.Registry()
        r = self.registry
        self.m_calls = r.counter("dist.calls", "executor dispatches")
        self.m_records = r.counter("dist.records", "records dispatched")
        self.m_resolutions = r.counter(
            "dist.resolutions", "shard-kernel resolutions by tune provenance",
            ("source",))
        self.resolve_source = ""    # where the shard kernel came from (tune provenance)

    def note_resolution(self, source: str) -> None:
        self.resolve_source = source
        self.m_resolutions.labels(source=source).inc()

    @property
    def calls(self) -> int:
        return int(self.m_calls.value)

    @property
    def records(self) -> int:
        return int(self.m_records.value)


class ShardedForestEvaluator:
    """Reusable sharded dispatcher for one encoded forest.

    Planning is lazy: the first batch supplies M and a d_µ sample, the
    planner picks (R, G) (unless ``plan``/``mesh``/``decomposition`` pins
    it), and subsequent equal-shaped calls replay one jitted ``shard_map``
    program.  ``__call__`` never blocks on the device — callers (stream
    chunker, serve engine, benches) own synchronisation, which is what lets
    transfer overlap evaluation.
    """

    def __init__(
        self,
        forest: "EncodedForest | list",
        *,
        mesh=None,
        plan: ShardPlan | None = None,
        decomposition: str | None = None,
        n_devices: int | None = None,
        mesh_cost: MeshCostModel | None = None,
        cache=None,
        autotune: bool = False,
        engines: tuple[str, ...] | None = None,
        layouts: tuple[str, ...] | None = None,
        registry: obs.Registry | None = None,
        tracer: obs.Tracer | None = None,
        profiler=None,
    ):
        from repro.tune import TuneCache

        self.forest = forest if isinstance(forest, EncodedForest) else EncodedForest(list(forest))
        self.cache = cache if cache is not None else TuneCache()  # one handle, one disk read
        self.autotune = autotune
        self.engines = engines
        # node-table layout opt-in, forwarded to the single-device
        # ForestTunedEvaluator path (shard bodies stay on the f32 tables)
        self.layouts = layouts
        self.obs = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        # a TraversalProfiler (serve engine's): measured per-bucket d_µ /
        # survival flow into the forest evaluator's heuristic resolutions
        self.profiler = profiler
        self.mesh_cost = mesh_cost if mesh_cost is not None else MeshCostModel()
        self.decomposition = decomposition
        self._given_mesh = mesh
        self._given_plan = plan
        self._n_devices = n_devices
        self.plan: ShardPlan | None = None
        self.mesh = None
        self.record_sharding = None   # set once planned; exposed for callers
        self.resolved = None          # (Candidate, source) provenance
        self.stats = DistStats(self.obs)
        self._fast: dict[int, tuple] = {}   # M → (fn, m_pad, t_pad, tree_args)
        self._forest_ev = None        # lazy ForestTunedEvaluator (single selection point)
        # swap generation: a _build() racing invalidate_resolution() must not
        # re-install its pre-promotion kernel (same guard as the evaluators)
        self._swap_lock = threading.Lock()
        self._gen = 0

    # -- planning -----------------------------------------------------------

    def _measured_d_mu(self, rec: np.ndarray, sample: int = 128) -> float:
        """Forest d_µ: measured mean over a few trees × a record sample
        (delegates to the shared helper so the planner and the forest
        heuristic read the same measurement)."""
        from repro.tune.heuristic import measured_forest_d_mu

        return measured_forest_d_mu(self.forest, rec, sample=sample)

    def _prepare(self, rec) -> None:
        if self.plan is not None:
            return
        if self._given_plan is not None:
            self.plan = self._given_plan
        elif self._given_mesh is not None:
            sizes = dict(zip(self._given_mesh.axis_names, self._given_mesh.devices.shape))
            wl = ForestWorkload.of(self.forest, rec)
            self.plan = make_plan(
                wl, sizes.get("records", 1), sizes.get("trees", 1), self.mesh_cost
            )
        else:
            host = np.asarray(rec)
            wl = ForestWorkload.of(self.forest, host, d_mu=self._measured_d_mu(host))
            self.plan = plan_forest(
                wl,
                n_devices=self._n_devices,
                mesh_cost=self.mesh_cost,
                decomposition=self.decomposition,
            )
        if self.plan.n_devices > 1:
            self.mesh = self._given_mesh if self._given_mesh is not None else shd.forest_mesh(
                self.plan.record_shards, self.plan.tree_shards
            )
            self.record_sharding = shd.named(self.mesh, P("records", None))

    # -- lowering -----------------------------------------------------------

    def _forest_evaluator(self):
        """The lazily built :class:`repro.tune.ForestTunedEvaluator`.

        One evaluator serves both roles: the whole single-device path (the
        plain tuned forest call, all three candidate families available)
        and, on a mesh, the depth-profile metadata the per-shard resolution
        keys its forest buckets with.
        """
        if self._forest_ev is None:
            from repro.tune import ForestTunedEvaluator

            self._forest_ev = ForestTunedEvaluator(
                self.forest,
                cache=self.cache,
                autotune=self.autotune,
                engines=self.engines,
                layouts=self.layouts,
                registry=self.obs,
                tracer=self.tracer,
                profiler=self.profiler,
            )
        return self._forest_ev

    def invalidate_resolution(self) -> None:
        """Drop kernel-resolution state; the next call re-reads the tune cache.

        The serve engines' background re-tune promotes a freshly measured
        winner by writing it to the shared cache and calling this — an
        atomic swap from the caller's view (in-flight calls finish on the
        old kernel, subsequent calls resolve the new one).  The (R, G) plan
        is kept: re-planning is a separate concern (see ROADMAP).
        """
        with self._swap_lock:
            self._gen += 1
            self._fast.clear()
        if self._forest_ev is not None:
            self._forest_ev.invalidate()

    def retune(self, records, *, warmup: int = 1, iters: int = 3):
        """Re-measure the kernel choice at this executor's operating point.

        The measurement must land under the key the next resolution will
        actually probe, which depends on the plan:

        * one device — the full forest-family sweep at the batch shape; the
          winner lands under the forest bucket key the
          :class:`~repro.tune.ForestTunedEvaluator` resolves;
        * a mesh — the shared (vmap) candidates are timed at the *shard*
          operating point (M/R records × T/G trees, the shapes the shard
          bodies really run) and the winner is stored under the exact
          shard-shape key :meth:`_shard_kernel` looks up on its next build.

        Called from the serve engines' background re-tune worker; follow
        with :meth:`invalidate_resolution` to promote the stored winner.

        Returns:
          The winning :class:`repro.tune.TuneEntry`.
        """
        from repro.tune.measure import tune_forest_workload
        from repro.tune.space import ForestShape

        rec = np.asarray(records, np.float32)
        self._prepare(jnp.asarray(rec))
        if self.plan.n_devices == 1:
            entry, _ = tune_forest_workload(
                rec, self.forest, cache=self.cache, engines=self.engines,
                warmup=warmup, iters=iters, autotune_trees=True,
            )
            return entry

        plan, forest = self.plan, self.forest
        m_pad = shd.pad_to_multiple(max(rec.shape[0], plan.record_shards), plan.record_shards)
        m_shard = m_pad // plan.record_shards
        t_shard = shd.pad_to_multiple(forest.n_trees, plan.tree_shards) // plan.tree_shards
        sample = np.zeros((m_shard, rec.shape[1]), np.float32)
        rows = min(rec.shape[0], m_shard)
        sample[:rows] = rec[:rows]
        # forest.tree(i) returns the already common-padded encoding, so the
        # sub-forest keeps the full forest's node count
        sub = EncodedForest([forest.tree(i % forest.n_trees) for i in range(t_shard)])
        entry, _ = tune_forest_workload(
            sample, sub, cache=None, engines=self.engines, families=("vmap",),
            warmup=warmup, iters=iters, store=False,
        )
        fev = self._forest_evaluator()
        fshape = ForestShape(
            t=t_shard, m=m_shard, n_nodes=int(forest.n_nodes), n_attrs=int(rec.shape[1]),
            depth_min=fev.depth_min, depth_max=fev.depth_max,
        )
        self.cache.store(fshape.key(), entry)
        return entry

    def _shard_kernel(self, m_shard: int, t_shard: int, n_attrs: int, rec_host: np.ndarray):
        """Resolve the per-shard kernel through repro.tune; return array fn.

        Resolution is forest-first: a :class:`repro.tune.space.ForestShape`
        bucket at the shard operating point (M/R records × T/G trees) is
        looked up in the shared cache, and a stored shared-family winner
        (vmap/fused) supplies the algorithm, jump mode and jump count.  On a
        miss — or a ``per_tree`` winner, which has no single-kern lowering
        inside a ``shard_map`` body — resolution falls back to the per-tree
        chain at the shard record shape (memo → cache → autotune →
        heuristic), exactly the PR 3 behaviour.  Either way the winning
        candidate lowers via its algorithm's array-level formulation
        (:func:`repro.core.eval_speculative.eval_speculative` /
        :func:`repro.core.eval_dataparallel.eval_data_parallel`) — the
        kernel launch itself is per-device work that ``shard_map`` bodies
        express as plain traced ops — vmapped over the local tree axis.
        """
        from repro.kernels.tree_eval.ops import FOREST_VARIANTS, get_forest_variant
        from repro.tune import TunedEvaluator
        from repro.tune.space import Candidate, ForestShape, backend_tag

        depth = max(int(self.forest.max_depth), 1)
        fev = self._forest_evaluator()
        fshape = ForestShape(
            t=t_shard, m=m_shard, n_nodes=int(self.forest.n_nodes), n_attrs=n_attrs,
            depth_min=fev.depth_min, depth_max=fev.depth_max,
        )
        entry = self.cache.lookup(fshape.key(backend_tag()))
        if entry is not None and entry.variant in FOREST_VARIANTS:
            spec = get_forest_variant(entry.variant)
            cand = Candidate.make(entry.variant, **entry.params)
            self.resolved = (cand, "cache")
            self.stats.note_resolution("cache")
            if spec.algorithm == "data_parallel":
                return partial(eval_data_parallel, max_depth=depth)
            return partial(
                eval_speculative,
                max_depth=depth,
                jumps_per_round=int(entry.params.get("jumps_per_round", 2)),
                use_onehot_matmul=(spec.jump_mode == "onehot"),
            )

        sample = np.zeros((m_shard, n_attrs), np.float32)
        rows = min(rec_host.shape[0], m_shard)
        sample[:rows] = rec_host[:rows]
        ev = TunedEvaluator(
            self.forest.tree(0),
            cache=self.cache,
            autotune=self.autotune,
            engines=self.engines,
        )
        ev.depth = depth
        cand, source = ev.resolve(sample)
        self.resolved = (cand, source)
        self.stats.note_resolution(source)

        spec = get_variant(cand.variant)
        params = cand.param_dict
        if spec.algorithm == "data_parallel":
            return partial(eval_data_parallel, max_depth=depth)
        return partial(
            eval_speculative,
            max_depth=depth,
            jumps_per_round=int(params.get("jumps_per_round", 2)),
            use_onehot_matmul=(spec.jump_mode == "onehot"),
        )

    def _build(self, m: int, n_attrs: int, rec_host: np.ndarray) -> tuple:
        plan, mesh, forest = self.plan, self.mesh, self.forest
        m_pad = shd.pad_to_multiple(max(m, plan.record_shards), plan.record_shards)
        t_pad = shd.pad_to_multiple(forest.n_trees, plan.tree_shards)

        def pad_t(x, dtype):
            x = np.asarray(x)
            if t_pad > x.shape[0]:
                x = np.concatenate([x, np.repeat(x[:1], t_pad - x.shape[0], axis=0)])
            return jax.device_put(
                jnp.asarray(x, dtype), shd.named(mesh, P("trees", None))
            )

        tree_args = (
            pad_t(forest.attr_idx, jnp.int32),
            pad_t(forest.threshold, jnp.float32),
            pad_t(forest.child, jnp.int32),
            pad_t(forest.class_val, jnp.int32),
        )
        kern = self._shard_kernel(
            m_pad // plan.record_shards, t_pad // plan.tree_shards, n_attrs, rec_host
        )

        def body(r, ai, ti, ci, ki):
            # r: (M/R, A) local records; tree tables: (T/G, N) local stack
            return jax.vmap(lambda a_, t_, c_, k_: kern(r, a_, t_, c_, k_))(ai, ti, ci, ki)

        smap = _shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P("records", None),
                P("trees", None),
                P("trees", None),
                P("trees", None),
                P("trees", None),
            ),
            out_specs=P("trees", "records"),
            **_SMAP_KW,
        )
        n_trees = forest.n_trees

        def run(r, ai, ti, ci, ki):
            # Divisibility pad, shard_map and the output slice are traced
            # into ONE program: a streamed chunk costs a single dispatch, not
            # a pad program + an eval program + a slice program.  That fixed
            # per-chunk overhead is what made chunked streaming lose to the
            # monolithic call on transfer-free backends.
            if m_pad != m:
                r = jnp.zeros((m_pad, r.shape[1]), r.dtype).at[:m].set(r)
            return smap(r, ai, ti, ci, ki)[:n_trees, :m]

        # Donate the records buffer where donation is real (XLA CPU ignores
        # it with a warning): streamed chunks are single-use by contract, so
        # their pages can be recycled for the padded copy / the output.
        donate = (0,) if jax.default_backend() != "cpu" else ()
        fn = jax.jit(run, donate_argnums=donate)
        return fn, m_pad, t_pad, tree_args

    # -- evaluation ---------------------------------------------------------

    def __call__(self, records) -> jax.Array:
        """Evaluate the forest over a record batch across the mesh.

        Args:
          records: (M, A) float array (converted to float32 on device).

        Returns:
          (T, M) int32 per-tree class assignments — *asynchronously*: the
          result is not blocked on the device, so callers (stream chunker,
          serve engines, benches) own synchronisation, which is what lets
          chunk transfer overlap evaluation.

        On non-CPU backends the device records buffer is donated to the
        evaluation (chunks are single-use by the streaming contract); pass a
        fresh array — or host data, converted here — per call.
        """
        if not (isinstance(records, jax.Array) and records.dtype == jnp.float32):
            records = jnp.asarray(records, jnp.float32)
        self._prepare(records)
        m = records.shape[0]
        self.stats.m_calls.inc()
        self.stats.m_records.inc(int(m))

        if self.plan.n_devices == 1:
            # single-device fallback: the plain forest-tuned path, no
            # shard_map.  The ForestTunedEvaluator is built once — its
            # internal memo makes steady-state calls (serve waves, stream
            # chunks) pure dict probes, and the fused stacked-kernel
            # candidate stays in play, same as eval_forest_tuned.
            with self.tracer.span("kernel.dispatch", cat="kernel",
                                  records=int(m), devices=1):
                return self._forest_evaluator()(records)

        fast = self._fast.get(m)
        if fast is None:
            gen = self._gen
            with self.tracer.span("dist.build", cat="dist", records=int(m),
                                  devices=self.plan.n_devices):
                fast = self._build(m, int(records.shape[1]), np.asarray(records))
            with self._swap_lock:
                if gen == self._gen:   # don't cache a pre-swap resolution
                    self._fast[m] = fast
        fn, _m_pad, _t_pad, tree_args = fast
        # fn pads, reshards, evaluates and slices in one program — one
        # asynchronous dispatch per call, whatever sharding the input has
        with self.tracer.span("kernel.dispatch", cat="kernel", records=int(m),
                              devices=self.plan.n_devices):
            return fn(records, *tree_args)   # (n_trees, m)
