"""Production serving launcher: batched requests through the wave engine.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3.5-moe-42b-a6.6b \\
        --smoke --requests 8 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import build_model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.embeds_input:
        raise SystemExit("vlm archs need precomputed embeddings; see examples/")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"serving {cfg.name}: {cfg.n_params()/1e6:.1f}M params"
          + (", tree-routed MoE (speculative hard routing)" if cfg.moe and cfg.moe.router == "tree" else ""))

    engine = ServeEngine(model, params, max_batch=args.max_batch,
                         max_len=args.prompt_len + args.new_tokens + 2,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    engine.run(reqs, pad_to=args.prompt_len)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    s = engine.stats
    print(f"{len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({s.waves} waves; prefill {s.prefill_s:.2f}s, decode {s.decode_s:.2f}s, "
          f"{total / max(s.decode_s, 1e-9):,.0f} tok/s decode)")
    for r in reqs[:4]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}{'...' if len(r.out_tokens) > 10 else ''}")


if __name__ == "__main__":
    main()
