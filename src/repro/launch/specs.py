"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape × step).

``input_specs(cfg, shape)`` returns the batch pytree of ShapeDtypeStructs
matching what the corresponding step function consumes — weak-type-correct,
shardable, no device allocation.  ``batch_specs`` gives the matching
PartitionSpec tree (batch dims over ('pod','data') when divisible).

``step_arguments`` assembles the full ``(args, in_specs, out_specs?)`` for the
dry-run: train steps take (params, opt_state, batch); prefill/decode take
(params[, cache], batch) with serving params in bf16 (serving frameworks do
not keep f32 master weights).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig, TrainConfig
from repro.models.api import build_model
from repro.optim.adamw import adamw_state_shapes, adamw_state_specs
from repro.parallel import sharding as shd


def _batch_axes_for(axes: shd.MeshAxes, global_batch: int):
    """Largest prefix of the batch axes that divides the global batch."""
    return axes.batch_axes_for(global_batch)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch pytree of ShapeDtypeStructs for one cell."""
    b = shape.global_batch
    if shape.kind == "decode":
        s = 1
    else:
        s = shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out: dict[str, Any] = {}
    if cfg.family == "audio":
        out["embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.n_frames, cfg.d_model), jnp.float32
        )
        out["tokens"] = tok
    elif cfg.embeds_input:
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
        out["positions"] = jax.ShapeDtypeStruct((b, 3, s), jnp.int32)
    else:
        out["tokens"] = tok
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "decode" and cfg.family == "audio":
        # decode consumes tokens only; encoder frames live in the cross cache
        out.pop("embeds", None)
    return out


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, axes: shd.MeshAxes) -> dict:
    ba = _batch_axes_for(axes, shape.global_batch)
    sp = input_specs(cfg, shape)

    def spec_for(k, v):
        return P(ba, *([None] * (len(v.shape) - 1)))

    return {k: spec_for(k, v) for k, v in sp.items()}


@dataclasses.dataclass
class CellPlan:
    """Everything the dry-run needs to lower one (arch × shape) cell."""

    step_name: str               # train_step | prefill_step | decode_step
    fn: Any                      # callable(params, ...) for jax.jit
    args: tuple                  # ShapeDtypeStructs
    in_specs: tuple              # PartitionSpecs (pytrees)
    donate: tuple = ()


def serving_config(cfg: ModelConfig) -> ModelConfig:
    """bf16 weights for serving cells (no f32 master copies at inference)."""
    return dataclasses.replace(cfg, param_dtype=cfg.dtype)


def dryrun_config(cfg: ModelConfig) -> ModelConfig:
    """bf16→f16 swap for CPU lowering: byte-identical to the TPU target (see
    configs.base._DTYPES).  Real TPU runs keep bfloat16."""
    out = cfg
    if cfg.dtype == "bfloat16":
        out = dataclasses.replace(out, dtype="float16")
    if cfg.param_dtype == "bfloat16":
        out = dataclasses.replace(out, param_dtype="float16")
    return out


TP_MIN_PARAMS = 1e9    # below this, TP shards are tiny and collectives
                       # dominate: run DP-only (model axis joins batch)


def axes_for(cfg: ModelConfig, axes: shd.MeshAxes) -> shd.MeshAxes:
    """Size-aware parallelism policy (EXPERIMENTS.md §Perf iteration 1)."""
    if cfg.n_params() < TP_MIN_PARAMS and axes.tp:
        return dataclasses.replace(
            axes, tp=False, batch=tuple(axes.batch) + (axes.model,)
        )
    return axes


def plan_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    axes: shd.MeshAxes,
    *,
    parallel: ParallelConfig | None = None,
    tcfg: TrainConfig | None = None,
) -> CellPlan:
    parallel = parallel or ParallelConfig()
    tcfg = tcfg or TrainConfig()
    axes = axes_for(cfg, axes)
    batch = input_specs(cfg, shape)
    bspecs = batch_pspecs(cfg, shape, axes)

    if shape.kind == "train":
        model = build_model(cfg, axes, parallel)
        from repro.train.step import make_train_step

        if tcfg.microbatch == 0:
            # size-aware gradient accumulation: only the ≥60 B dense archs
            # (deepseek-67b, qwen2-vl-72b) need accumulation to fit; phi3.5-moe
            # (42 B total / 6.6 B active) fits at microbatch 1 — and every
            # extra microbatch re-gathers FSDP weights and re-reduces grads
            # (§Perf iterations 6-7)
            n = cfg.n_params()
            # fit-driven accumulation: deepseek-67b fits at microbatch 2 via
            # donated-buffer aliasing (14.9 GB); qwen2-vl-72b's wider MLP
            # (d_ff 29568) needs 4; everything else runs unaccumulated
            # (§Perf #4/#9)
            micro = 4 if n >= 70e9 else (2 if n >= 60e9 else 1)
            tcfg = dataclasses.replace(tcfg, microbatch=micro)
        step = make_train_step(model, tcfg)
        pshapes = model.param_shapes()
        pspecs = model.param_specs()
        oshapes = adamw_state_shapes(pshapes)
        ospecs = adamw_state_specs(pspecs, pshapes, axes, zero1=parallel.zero1)
        return CellPlan(
            step_name="train_step",
            fn=step,
            args=(pshapes, oshapes, batch),
            in_specs=(pspecs, ospecs, bspecs),
            donate=(0, 1),
        )

    scfg = serving_config(cfg)
    # serving: small archs keep weights TP-sharded only (replicated over the
    # data axis like a replica set — no per-token FSDP gathers); archs whose
    # bf16 weights exceed ~6 GB/chip at 16-way TP also shard over 'data'
    # (serving-FSDP): deepseek-67b and qwen2-vl-72b at 145 GB bf16 cannot
    # live on 16 chips.
    per_chip = cfg.n_params() * 2 / axes.model_size
    saxes = dataclasses.replace(axes, fsdp=None) if per_chip < 6e9 else axes
    model = build_model(scfg, saxes, parallel)
    pshapes = model.param_shapes()
    pspecs = model.param_specs()

    if shape.kind == "prefill":
        def prefill_step(params, batch_):
            return model.prefill(params, batch_)

        return CellPlan(
            step_name="prefill_step",
            fn=prefill_step,
            args=(pshapes, batch),
            in_specs=(pspecs, bspecs),
        )

    # decode: one new token against a seq_len-deep cache
    cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
    cache_specs = model.cache_specs(shape.global_batch)

    def serve_step(params, cache, batch_):
        return model.decode_step(params, cache, batch_)

    return CellPlan(
        step_name="serve_step",
        fn=serve_step,
        args=(pshapes, cache_shapes, batch),
        in_specs=(pspecs, cache_specs, bspecs),
        donate=(1,),
    )
