"""Production training launcher.

On real hardware this runs under ``jax.distributed`` with one process per
host; in this container it runs the same code on the local device(s):

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \\
        --steps 50 --seq 128 --batch 4

``--smoke`` selects the reduced config; omit on a real pod slice to train
the assigned architecture at full size with the production mesh/shardings.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig, ShapeConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import pipeline_for
from repro.models.api import build_model
from repro.optim.adamw import adamw_init
from repro.parallel import sharding as shd
from repro.train.loop import LoopState, train_loop
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS + list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="auto", help="auto | dxm e.g. 16x16")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh == "auto":
        n = jax.device_count()
        mesh = jax.make_mesh((n, 1), ("data", "model"))
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    axes = shd.from_mesh(mesh)
    model = build_model(cfg, axes, ParallelConfig())
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir)
    with mesh:
        params = model.init(jax.random.key(0))
        params = jax.device_put(params, shd.tree_named(mesh, model.param_specs()))
        step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
        pipe = pipeline_for(cfg, ShapeConfig("train", args.seq, args.batch, "train"))
        batches = lambda i: jax.tree.map(jnp.asarray, pipe(i))
        state = LoopState(params=params, opt_state=adamw_init(params), step=0)
        t0 = time.perf_counter()
        state, report = train_loop(state, step, batches, tcfg, max_steps=args.steps)
    dt = time.perf_counter() - t0
    print(f"\n{report.final_step} steps in {dt:.1f}s "
          f"({args.steps * args.seq * args.batch / dt:,.0f} tok/s); "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}; "
          f"restarts={report.restarts} stragglers={report.stragglers}")


if __name__ == "__main__":
    main()
