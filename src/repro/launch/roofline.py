"""Roofline-term derivation from a compiled dry-run cell.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  Terms in seconds:

    compute    = HLO_FLOPs / (chips × 197e12)
    memory     = HLO_bytes / (chips × 819e9)
    collective = collective_bytes_per_chip / 50e9

``cost_analysis`` reports whole-program FLOPs/bytes (already per-partition in
SPMD mode — verified against per-chip expectations in tests); collective
bytes come from parsing the compiled HLO (utils/hlo.py) and are per-chip wire
bytes.  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) measures how much
of the compiled compute is "useful" (remat/dispatch overhead shows up as a
ratio < 1).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (effective)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    step: str
    mesh: str
    chips: int
    hlo_flops: float           # per chip
    hlo_bytes: float           # per chip
    coll_bytes: float          # per chip
    coll_summary: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float         # whole-step useful FLOPs (6ND)
    peak_memory_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound spent on useful model FLOPs: the score
        axis — (model_flops/chips/peak) / max(compute, memory, collective)."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.bound_s if self.bound_s else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "step": self.step,
            "mesh": self.mesh, "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_summary": self.coll_summary,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N·D useful-FLOPs estimate for the step."""
    n = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens      # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def terms_from_compiled(
    *, arch: str, shape, step: str, mesh_name: str, chips: int,
    cost: dict, coll_stats, cfg, memory_stats: Optional[dict] = None,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    coll = float(getattr(coll_stats, "coll_bytes", 0.0) or getattr(coll_stats, "total_bytes", 0.0))
    return RooflineTerms(
        arch=arch,
        shape=shape.name,
        step=step,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=bytes_,
        coll_bytes=coll,
        coll_summary=(coll_stats.coll_summary() if hasattr(coll_stats, "coll_summary")
                      else coll_stats.summary()),
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops=model_flops_for(cfg, shape),
        peak_memory_bytes=float((memory_stats or {}).get("temp_size_in_bytes", 0.0)),
    )


def format_table(rows: list[dict]) -> str:
    cols = ["arch", "shape", "step", "mesh", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio", "roofline_fraction"]
    hdr = " | ".join(f"{c:>18s}" for c in cols)
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        vals = []
        for c in cols:
            v = r[c]
            vals.append(f"{v:>18.3e}" if isinstance(v, float) else f"{str(v):>18s}")
        lines.append(" | ".join(vals))
    return "\n".join(lines)


def save_rows(path: str, rows: list[dict]) -> None:
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
