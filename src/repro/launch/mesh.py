"""Production meshes: 16×16 single pod, 2×16×16 multi-pod.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — only ``dryrun.py`` (which sets
``--xla_force_host_platform_device_count=512`` before any jax import) should
construct the production shapes in this container.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *, n_pod: int = 0):
    """Small mesh for CPU multi-device tests (requires forced host devices)."""
    if n_pod:
        return jax.make_mesh((n_pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
