import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract the roofline terms.

MUST be run as a module entry (``python -m repro.launch.dryrun``) so the
XLA_FLAGS line above executes before any other jax-touching import.

    python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out results/
"""

import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs.base import ParallelConfig, TrainConfig                # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config                   # noqa: E402
from repro.configs.shapes import SHAPES, cells_for                        # noqa: E402
from repro.launch import roofline as rl                                   # noqa: E402
from repro.launch.mesh import make_production_mesh                        # noqa: E402
from repro.launch.specs import dryrun_config, plan_cell                   # noqa: E402
from repro.parallel import sharding as shd                                # noqa: E402
from repro.utils.hlo_cost import analyze as hlo_analyze                   # noqa: E402


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, verbose: bool = True,
             parallel: ParallelConfig | None = None, collect_hlo: bool = False,
             microbatch: int = 0, remat: str | None = None):
    """Lower + compile one cell; return (RooflineTerms, wall seconds)."""
    cfg = get_config(arch)
    lcfg = dryrun_config(cfg)   # f16 stand-in for bf16 (CPU backend, same bytes)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = shd.from_mesh(mesh)
    chips = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    if remat is not None:
        parallel = ParallelConfig(**{**(parallel.__dict__ if parallel else ParallelConfig().__dict__), "remat": remat})
    plan = plan_cell(lcfg, shape, axes, parallel=parallel,
                     tcfg=TrainConfig(microbatch=microbatch))
    t0 = time.perf_counter()
    with mesh:
        in_shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            plan.in_specs,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
        )
        jitted = jax.jit(plan.fn, in_shardings=in_shardings,
                         donate_argnums=plan.donate or ())
        lowered = jitted.lower(*plan.args)
        compiled = lowered.compile()
    wall = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    hc = hlo_analyze(hlo)                     # trip-count-aware (see utils/hlo_cost)
    cost = {"flops": hc.flops, "bytes accessed": hc.bytes,
            "xla_flops_once": xla_cost.get("flops", 0.0)}

    mem_stats = {
        "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_size_in_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    terms = rl.terms_from_compiled(
        arch=arch, shape=shape, step=plan.step_name, mesh_name=mesh_name,
        chips=chips, cost=cost, coll_stats=hc, cfg=cfg, memory_stats=mem_stats,
    )
    if verbose:
        per_dev = (
            mem_stats["argument_size_in_bytes"] + mem_stats["temp_size_in_bytes"]
        )
        print(f"[{arch} × {shape_name} × {mesh_name}] {plan.step_name} "
              f"compiled in {wall:.1f}s")
        print(f"  memory_analysis: args={mem_stats['argument_size_in_bytes']/2**30:.2f} GiB  "
              f"temps={mem_stats['temp_size_in_bytes']/2**30:.2f} GiB  "
              f"out={mem_stats['output_size_in_bytes']/2**30:.2f} GiB  "
              f"(per device: {per_dev/2**30:.2f} GiB)")
        print(f"  cost_analysis: flops/chip={terms.hlo_flops:.3e}  bytes/chip={terms.hlo_bytes:.3e}")
        print(f"  collectives: {hc.coll_summary()}  (unknown trips: {hc.unknown_trip_counts})")
        print(f"  roofline: compute={terms.compute_s:.3e}s memory={terms.memory_s:.3e}s "
              f"collective={terms.collective_s:.3e}s → dominant={terms.dominant} "
              f"useful={terms.useful_ratio:.2f} frac={terms.roofline_fraction:.3f}")
    out = terms.row()
    out["compile_s"] = wall
    out["mem"] = mem_stats
    # TPU-donation-adjusted fit: XLA:CPU does not alias donated buffers, so
    # decode cells double-count the updated KV cache (args copy + output
    # copy in temps).  On TPU donation aliases them in place.
    donated = mem_stats["output_size_in_bytes"] if plan.donate else 0
    out["fit_bytes"] = mem_stats["argument_size_in_bytes"] + mem_stats["temp_size_in_bytes"]
    out["fit_bytes_tpu"] = max(out["fit_bytes"] - donated, 0)
    if collect_hlo:
        out["hlo"] = hlo
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all applicable)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--out", default=None, help="write JSON rows here")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default=None)
    args = ap.parse_args(argv)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    parallel = ParallelConfig(seq_shard=not args.no_seq_shard)

    rows, failures = [], []
    for arch in archs:
        cfg = get_config(arch)
        for shape, ok, why in cells_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            if not ok:
                print(f"[{arch} × {shape.name}] SKIP: {why}")
                rows.append({"arch": arch, "shape": shape.name, "skip": why})
                continue
            for mp in meshes:
                try:
                    rows.append(run_cell(arch, shape.name, multi_pod=mp, parallel=parallel,
                                         microbatch=args.microbatch, remat=args.remat))
                except Exception as e:  # record and continue: failures are bugs
                    traceback.print_exc()
                    failures.append((arch, shape.name, mp, repr(e)))
                    rows.append({"arch": arch, "shape": shape.name,
                                 "mesh": "2x16x16" if mp else "16x16", "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.out}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print(" ", f_)
        sys.exit(1)
    print(f"\nall {len(rows)} cells OK")


if __name__ == "__main__":
    main()
