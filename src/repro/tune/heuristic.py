"""Model-based fallback: pick a variant from the paper's runtime analysis.

When the cache has no entry for a shape bucket (first call on a new machine,
or tuning disabled) dispatch still has to pick a variant.  We evaluate the
paper's closed-form runtime model (§3.6 / §4 analysis, `repro.core.analysis`)
at the workload's operating point:

    T₃(P) = (M/P)·d_µ·(t_e + t_c) + t_i + t_s(M)          (data decomposition)
    T₅(P) = (M·p/P)·(t_e + log₂(d_µ)·t_c) + t_i + t_s(M)  (speculative)

with p = the record-group processor count, which in our TPU mapping is the
number of *internal* nodes each record's lane-group evaluates speculatively.
The cheaper predicted time picks the algorithm — equivalently, equation (1)'s
crossover ``p < 2·d_µ/(1 + log₂ d_µ)`` under t_e ≈ t_c — and backend rules
pick engine/jump-mode (Pallas + one-hot MXU on TPU, XLA gather elsewhere).
"""

from __future__ import annotations

from repro.core.analysis import CostModel, t3_data_parallel, t5_speculative
from repro.kernels.tree_eval.ops import choose_block_m, on_tpu
from repro.tune.space import MAX_ONEHOT_NODES, Candidate, WorkloadShape, default_engines


def default_p_group(shape: WorkloadShape) -> int:
    """Processors per record group: the internal nodes of a full binary tree."""
    return max(1, (shape.n_nodes - 1) // 2)


def default_d_mu(shape: WorkloadShape) -> float:
    """Estimated mean traversal depth when no measurement is supplied.

    Real d_µ lies between log₂(leaves) (balanced) and depth (vine); the
    midpoint is a serviceable prior for an untuned shape.
    """
    import math

    balanced = math.log2(max(shape.n_nodes, 2))
    return max(1.0, (balanced + shape.depth) / 2.0)


def measured_d_mu(enc, records, *, sample: int = 256) -> float:
    """d_µ measured on a record sample (the paper's "significant sample").

    The geometry prior of :func:`default_d_mu` can sit far from the truth —
    a deep vine whose traffic all exits at the first split has measured
    d_µ ≈ 1 but a large prior — and equation (1)'s crossover moves with d_µ,
    so the prior can pick the wrong algorithm.  Dispatch feeds the actual
    batch through the branchless descent (host-side, on at most ``sample``
    records) and hands the measured mean to the §3.6 model instead.
    """
    import numpy as np

    from repro.core.analysis import mean_traversal_depth, observed_depths

    rec = np.asarray(records)
    if rec.shape[0] == 0:
        return 1.0
    if rec.shape[0] > sample:
        rec = rec[:sample]
    return max(1.0, float(mean_traversal_depth(observed_depths(enc, rec))))


def predicted_times(
    shape: WorkloadShape,
    *,
    cm: CostModel = CostModel(),
    d_mu: float | None = None,
    p_group: float | None = None,
    p_total: float = 1.0,
) -> dict[str, float]:
    """§3.6 model runtimes per algorithm for this shape."""
    d = d_mu if d_mu is not None else default_d_mu(shape)
    d = max(float(d), 1.0)
    p = p_group if p_group is not None else default_p_group(shape)
    return {
        "data_parallel": t3_data_parallel(shape.m, d, p_total, cm),
        "speculative": t5_speculative(shape.m, d, p_total, p, cm),
    }


def heuristic_candidate(
    shape: WorkloadShape,
    *,
    cm: CostModel = CostModel(),
    d_mu: float | None = None,
    p_group: float | None = None,
    engines: tuple[str, ...] | None = None,
) -> Candidate:
    """Shape-derived variant choice mirroring the paper's analysis."""
    times = predicted_times(shape, cm=cm, d_mu=d_mu, p_group=p_group)
    algorithm = min(times, key=times.get)
    engines = default_engines() if engines is None else tuple(engines)
    engine = "pallas" if "pallas" in engines else "jnp"

    onehot_ok = shape.n_nodes <= MAX_ONEHOT_NODES
    if engine == "pallas":
        if algorithm == "data_parallel":
            name, jump_mode = "pallas_data_parallel", "gather"
        else:
            jump_mode = "onehot" if (on_tpu() and onehot_ok) else "gather"
            name = f"pallas_speculative_{jump_mode}"
        b = shape.bucket()
        bm = choose_block_m(b.n_nodes, b.n_attrs, jump_mode=jump_mode)
        return Candidate.make(name, block_m=bm)

    if algorithm == "data_parallel":
        return Candidate.make("jnp_data_parallel")
    # paper: 2 jumps per synchronisation round was the measured optimum
    return Candidate.make("jnp_speculative_gather", jumps_per_round=2)
