"""Model-based fallback: pick a variant from the paper's runtime analysis.

When the cache has no entry for a shape bucket (first call on a new machine,
or tuning disabled) dispatch still has to pick a variant.  We evaluate the
paper's closed-form runtime model (§3.6 / §4 analysis, `repro.core.analysis`)
at the workload's operating point:

    T₃(P) = (M/P)·d_µ·(t_e + t_c) + t_i + t_s(M)          (data decomposition)
    T₅(P) = (M·p/P)·(t_e + log₂(d_µ)·t_c) + t_i + t_s(M)  (speculative)

with p = the record-group processor count, which in our TPU mapping is the
number of *internal* nodes each record's lane-group evaluates speculatively.
The cheaper predicted time picks the algorithm — equivalently, equation (1)'s
crossover ``p < 2·d_µ/(1 + log₂ d_µ)`` under t_e ≈ t_c — and backend rules
pick engine/jump-mode (Pallas + one-hot MXU on TPU, XLA gather elsewhere).
"""

from __future__ import annotations

from repro.core.analysis import CostModel, t3_data_parallel, t5_speculative
from repro.kernels.tree_eval.cascade import MAJORITY_FAMILY, plan_cascade
from repro.kernels.tree_eval.ops import PER_TREE_FAMILY, choose_block_m, on_tpu
from repro.tune.space import (
    MAX_ONEHOT_NODES,
    Candidate,
    ForestShape,
    WorkloadShape,
    cascade_stage_grid,
    default_engines,
)

# Per-launch dispatch overhead in §3.6 node-evaluation units (the planner's
# γ_launch prior): what the per-tree family pays T times and the stacked
# families pay once.  Only the *ratio* against the compute terms matters —
# the heuristic ranks families, it does not predict milliseconds.
FOREST_LAUNCH_OVERHEAD = 50.0


def default_p_group(shape: WorkloadShape) -> int:
    """Processors per record group: the internal nodes of a full binary tree.

    The paper's p — each record group assigns one processor per internal
    node during speculative node evaluation ((N-1)/2 for a full tree).
    """
    return max(1, (shape.n_nodes - 1) // 2)


def default_d_mu(shape: WorkloadShape) -> float:
    """Estimated mean traversal depth when no measurement is supplied.

    Real d_µ lies between log₂(leaves) (balanced) and depth (vine); the
    midpoint is a serviceable prior for an untuned shape.
    """
    import math

    balanced = math.log2(max(shape.n_nodes, 2))
    return max(1.0, (balanced + shape.depth) / 2.0)


def measured_d_mu(enc, records, *, sample: int = 256) -> float:
    """d_µ measured on a record sample (the paper's "significant sample").

    The geometry prior of :func:`default_d_mu` can sit far from the truth —
    a deep vine whose traffic all exits at the first split has measured
    d_µ ≈ 1 but a large prior — and equation (1)'s crossover moves with d_µ,
    so the prior can pick the wrong algorithm.  Dispatch feeds the actual
    batch through the branchless descent (host-side, on at most ``sample``
    records) and hands the measured mean to the §3.6 model instead.
    """
    import numpy as np

    from repro.core.analysis import mean_traversal_depth, observed_depths

    rec = np.asarray(records)
    if rec.shape[0] == 0:
        return 1.0
    if rec.shape[0] > sample:
        rec = rec[:sample]
    return max(1.0, float(mean_traversal_depth(observed_depths(enc, rec))))


def predicted_times(
    shape: WorkloadShape,
    *,
    cm: CostModel = CostModel(),
    d_mu: float | None = None,
    p_group: float | None = None,
    p_total: float = 1.0,
) -> dict[str, float]:
    """§3.6 model runtimes per algorithm for this shape.

    Args:
      shape: the (M, N, A, depth) operating point.
      cm: §3.6 machine constants (t_e, t_c, t_i, σ, γ).
      d_mu: mean traversal depth; default = the geometry prior.
      p_group: processors per record group; default = internal-node count.
      p_total: total processors P the work divides over.

    Returns:
      {"data_parallel": T₃, "speculative": T₅} in model units — rank-valid
      per shape, not milliseconds.
    """
    d = d_mu if d_mu is not None else default_d_mu(shape)
    d = max(float(d), 1.0)
    p = p_group if p_group is not None else default_p_group(shape)
    return {
        "data_parallel": t3_data_parallel(shape.m, d, p_total, cm),
        "speculative": t5_speculative(shape.m, d, p_total, p, cm),
    }


def heuristic_candidate(
    shape: WorkloadShape,
    *,
    cm: CostModel = CostModel(),
    d_mu: float | None = None,
    p_group: float | None = None,
    engines: tuple[str, ...] | None = None,
) -> Candidate:
    """Shape-derived variant choice mirroring the paper's analysis."""
    times = predicted_times(shape, cm=cm, d_mu=d_mu, p_group=p_group)
    algorithm = min(times, key=times.get)
    engines = default_engines() if engines is None else tuple(engines)
    engine = "pallas" if "pallas" in engines else "jnp"

    onehot_ok = shape.n_nodes <= MAX_ONEHOT_NODES
    if engine == "pallas":
        if algorithm == "data_parallel":
            name, jump_mode = "pallas_data_parallel", "gather"
        else:
            jump_mode = "onehot" if (on_tpu() and onehot_ok) else "gather"
            name = f"pallas_speculative_{jump_mode}"
        b = shape.bucket()
        bm = choose_block_m(b.n_nodes, b.n_attrs, jump_mode=jump_mode)
        return Candidate.make(name, block_m=bm)

    if algorithm == "data_parallel":
        return Candidate.make("jnp_data_parallel")
    # paper: 2 jumps per synchronisation round was the measured optimum
    return Candidate.make("jnp_speculative_gather", jumps_per_round=2)


# ---------------------------------------------------------------------------
# Forest-level heuristic: per-tree vector vs stacked (vmap / fused)
# ---------------------------------------------------------------------------


def measured_forest_d_mu(forest, records, *, trees: int = 4, sample: int = 256) -> float:
    """Forest d_µ: measured mean over a few trees × a record sample.

    Args:
      forest: an :class:`repro.core.forest.EncodedForest`.
      records: (M, A) record batch (host or device array).
      trees: how many trees to walk (the first ``min(T, trees)``).
      sample: records per tree (:func:`measured_d_mu`'s sample bound).

    Returns:
      Mean traversal depth ≥ 1.0 — the d_µ the §3.6 forms are evaluated at.
    """
    import numpy as np

    rec = np.asarray(records)[:sample]
    picked = range(min(int(forest.n_trees), max(trees, 1)))
    return float(np.mean([measured_d_mu(forest.tree(i), rec, sample=sample) for i in picked]))


def forest_heuristic_candidate(
    shape: ForestShape,
    *,
    cm: CostModel = CostModel(),
    d_mu: float | None = None,
    p_group: float | None = None,
    engines: tuple[str, ...] | None = None,
    families: tuple[str, ...] | None = None,
    launch_overhead: float = FOREST_LAUNCH_OVERHEAD,
) -> Candidate:
    """Model-based forest family + variant choice (the no-cache fallback).

    The stacked families evaluate every tree at the *padded* common geometry
    — each tree pays the deepest tree's rounds — but launch once; the
    per-tree family pays each tree's own depth but launches T times.  With
    t(d) = the §3.6 winner's time at depth-profile point d:

        stacked  ≈ T · t(depth_max)                + γ
        per-tree ≈ T · (t(depth_min)+t(depth_max))/2 + T·γ

    (the midpoint is the depth-profile prior for the mean per-tree cost).
    A homogeneous profile therefore always picks a stacked family; a spread
    profile flips to per-tree once the padding waste outgrows the saved
    launches.  Within a stacked family, engine rules mirror
    :func:`heuristic_candidate`: fused Pallas on TPU, the vmap jnp path off
    it.

    Args:
      shape: the forest operating point (T, M, N_max, A, depth profile).
      cm / d_mu / p_group: §3.6 model inputs, as in :func:`predicted_times`.
      engines: permitted engines; default = :func:`default_engines`.
      families: permitted families; default = all three.
      launch_overhead: γ in node-evaluation units.

    Returns:
      A :class:`Candidate` — ``Candidate(PER_TREE_FAMILY)`` or a registered
      forest variant with its parameters filled in.
    """
    engines = default_engines() if engines is None else tuple(engines)
    families = ("per_tree", "vmap", "fused") if families is None else tuple(families)

    deep = WorkloadShape(m=shape.m, n_nodes=shape.n_nodes,
                         n_attrs=shape.n_attrs, depth=shape.depth_max)
    shallow = WorkloadShape(m=shape.m, n_nodes=shape.n_nodes,
                            n_attrs=shape.n_attrs, depth=shape.depth_min)

    def best_time(s: WorkloadShape, d: float | None) -> float:
        return min(predicted_times(s, cm=cm, d_mu=d, p_group=p_group).values())

    # d_µ scales with the profile point: a measured/maximum-depth d_µ maps
    # onto the shallow end proportionally (the prior does this implicitly).
    d_deep = d_mu
    d_shallow = None if d_mu is None else max(1.0, d_mu * shape.depth_min / max(shape.depth_max, 1))
    t_deep = best_time(deep, d_deep)
    t_shallow = best_time(shallow, d_shallow)

    stacked_cost = shape.t * t_deep + launch_overhead
    per_tree_cost = shape.t * (t_deep + t_shallow) / 2.0 + shape.t * launch_overhead

    # a stacked family is usable only when its engine is permitted: fused is
    # the Pallas path, vmap the jnp one (forest_search_space filters the
    # same way, so the heuristic never names a candidate the space excludes)
    stacked_ok = [
        f for f in ("fused", "vmap")
        if f in families and (("pallas" in engines) if f == "fused" else ("jnp" in engines))
    ]
    if not stacked_ok and PER_TREE_FAMILY not in families:
        # the caller forced stacked families whose engines they excluded:
        # honour the family request over the engine filter, native engine
        stacked_ok = [f for f in ("fused", "vmap") if f in families]
    want_stacked = bool(stacked_ok) and (
        PER_TREE_FAMILY not in families or stacked_cost <= per_tree_cost
    )
    if not want_stacked:
        return Candidate.make(PER_TREE_FAMILY)

    family = stacked_ok[0]
    engine = "pallas" if family == "fused" else "jnp"

    times = predicted_times(deep, cm=cm, d_mu=d_deep, p_group=p_group)
    algorithm = min(times, key=times.get)
    onehot_ok = shape.n_nodes <= MAX_ONEHOT_NODES
    if algorithm == "data_parallel":
        name, jump_mode = f"forest_{family}_data_parallel", "gather"
    else:
        jump_mode = "onehot" if (engine == "pallas" and on_tpu() and onehot_ok) else "gather"
        name = f"forest_{family}_speculative_{jump_mode}"

    if family == "fused":
        b = shape.bucket()
        bm = choose_block_m(b.n_nodes, b.n_attrs, jump_mode=jump_mode)
        return Candidate.make(name, block_m=bm)
    if algorithm == "speculative":
        # paper: 2 jumps per synchronisation round was the measured optimum
        return Candidate.make(name, jumps_per_round=2)
    return Candidate.make(name)


# ---------------------------------------------------------------------------
# Class-level heuristic: full majority vote vs early-exit cascade
# ---------------------------------------------------------------------------


def measured_survival_rate(
    forest,
    records,
    n_classes: int,
    *,
    plan=None,
    stages: int = 2,
    bound: float = 1.0,
    sample: int = 256,
) -> tuple[float, ...]:
    """Fraction of records entering each cascade stage, measured on a sample.

    Simulates the exit rule on the reference per-tree classes (host numpy,
    no kernels): accumulate votes stage by stage in the plan's tree order
    and retire records whose margin exceeds ``bound`` times the remaining
    tree count.  Element 0 is always 1.0; the tail elements are the
    survival-rate term the §3.6-style cascade model multiplies stage costs
    by.
    """
    import numpy as np

    import jax.numpy as jnp

    from repro.kernels.tree_eval.ref import forest_eval_ref

    rec = np.asarray(records, np.float32)[: max(1, int(sample))]
    if plan is None:
        plan = plan_cascade(forest, rec, n_classes=n_classes, stages=stages, bound=bound)
    per_tree = np.asarray(
        forest_eval_ref(
            jnp.asarray(rec),
            jnp.asarray(forest.attr_idx, jnp.int32),
            jnp.asarray(forest.threshold, jnp.float32),
            jnp.asarray(forest.child, jnp.int32),
            jnp.asarray(forest.class_val, jnp.int32),
            max_depth=int(forest.max_depth),
        )
    )
    m = rec.shape[0]
    t_total = plan.n_trees
    c = max(int(n_classes), int(per_tree.max(initial=0)) + 1, 2)
    votes = np.zeros((m, c), np.int32)
    alive = np.ones((m,), bool)
    out: list[float] = []
    done = 0
    for size in plan.stage_sizes:
        out.append(float(alive.mean()) if m else 0.0)
        for j in range(done, done + size):
            votes[np.arange(m), per_tree[plan.order[j]]] += 1
        done += size
        remaining = t_total - done
        if remaining > 0:
            top2 = np.partition(votes, -2, axis=1)[:, -2:]
            margin = top2[:, 1] - top2[:, 0]
            alive &= ~(margin > bound * remaining)
    return tuple(out)


def default_survival(n_stages: int) -> tuple[float, ...]:
    """Survival prior when no calibration batch is available.

    Everyone enters stage 0; each later stage keeps roughly half its
    predecessor's records — a deliberately conservative prior (measured
    easy-mix survivals are far lower) so the heuristic only picks a cascade
    when it wins even on middling workloads.
    """
    return tuple(min(1.0, 0.5**s) for s in range(max(1, int(n_stages))))


def cascade_heuristic_candidate(
    shape: ForestShape,
    n_classes: int,
    *,
    survival: tuple[float, ...] | None = None,
    cm: CostModel = CostModel(),
    d_mu: float | None = None,
    p_group: float | None = None,
    engines: tuple[str, ...] | None = None,
    launch_overhead: float = FOREST_LAUNCH_OVERHEAD,
) -> Candidate:
    """Model-based class-level choice: majority vote vs early-exit cascade.

    Extends the §3.6 forest model by the survival-rate term.  With t(d) the
    per-tree winner's model time, surv_s the fraction of records entering
    stage s and size_s the stage's tree count:

        full     ≈ T · t(d)                     + γ
        cascade  ≈ Σ_s size_s · surv_s · t(d)   + S · γ

    Each stage pays its launch overhead γ in full (the compacted tile still
    launches) but only its survivors' share of the compute.  The best stage
    count from :func:`cascade_stage_grid` competes against the full path;
    ties go to the full path (simpler, no compaction machinery).

    Args:
      survival: per-stage entering fractions from
        :func:`measured_survival_rate`; longer/shorter tuples than a
        candidate's stage count are resampled from the tail prior.  Default
        = :func:`default_survival`.
    """
    engines = default_engines() if engines is None else tuple(engines)
    deep = shape.tree_shape()
    t_tree = min(predicted_times(deep, cm=cm, d_mu=d_mu, p_group=p_group).values())
    full_cost = shape.t * t_tree + launch_overhead

    grid = cascade_stage_grid(shape)
    best: tuple[float, int] | None = None
    for s in grid:
        plan = plan_cascade(_ShapeForest(shape), n_classes=n_classes, stages=s, bound=1.0)
        surv = survival if survival is not None else default_survival(plan.n_stages)
        cost = plan.n_stages * launch_overhead
        for i, size in enumerate(plan.stage_sizes):
            f = surv[i] if i < len(surv) else default_survival(i + 1)[-1]
            cost += size * max(0.0, min(1.0, f)) * t_tree
        if best is None or cost < best[0]:
            best = (cost, s)

    if best is None or best[0] >= full_cost:
        return Candidate.make(MAJORITY_FAMILY)

    stages = best[1]
    engine = "pallas" if "pallas" in engines else "jnp"
    times = predicted_times(deep, cm=cm, d_mu=d_mu, p_group=p_group)
    algorithm = min(times, key=times.get)
    onehot_ok = shape.n_nodes <= MAX_ONEHOT_NODES
    family = "fused" if engine == "pallas" else "vmap"
    if algorithm == "data_parallel":
        name = f"forest_cascade_{family}_data_parallel"
    else:
        jump_mode = "onehot" if (engine == "pallas" and on_tpu() and onehot_ok) else "gather"
        name = f"forest_cascade_{family}_speculative_{jump_mode}"
    if engine == "pallas":
        b = shape.bucket()
        bm = choose_block_m(b.n_nodes, b.n_attrs, jump_mode="gather")
        return Candidate.make(name, stages=stages, block_m=bm)
    return Candidate.make(name, stages=stages)


class _ShapeForest:
    """Just enough forest surface for :func:`plan_cascade` stage sizing."""

    def __init__(self, shape: ForestShape):
        self.n_trees = int(shape.t)
