"""Candidate timing: warmup, synchronised runs, medians.

Timing on an async-dispatch runtime (JAX) needs the discipline the paper
applies to its CUDA timings: compile/warm the candidate outside the timed
region, then bracket each timed call with ``jax.block_until_ready`` so host
timestamps measure device completion, and take the *median* over several
iterations so one-off scheduling noise doesn't crown the wrong variant.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.kernels.tree_eval.ops import get_variant
from repro.tune.cache import TuneCache, TuneEntry
from repro.tune.space import Candidate, WorkloadShape, backend_tag, search_space


@dataclasses.dataclass(frozen=True)
class Measurement:
    candidate: Candidate
    median_ms: float
    samples_ms: tuple[float, ...]

    @property
    def failed(self) -> bool:
        return not self.samples_ms


def _median(xs) -> float:
    xs = sorted(xs)
    n = len(xs)
    mid = n // 2
    return xs[mid] if n % 2 else (xs[mid - 1] + xs[mid]) / 2.0


def time_callable(fn, *, warmup: int = 2, iters: int = 5) -> tuple[float, ...]:
    """Millisecond samples of ``fn()``; each run synchronised on its output."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e3)
    return tuple(samples)


def interleaved_samples(
    fns: dict[str, object], *, warmup: int = 2, iters: int = 7
) -> dict[str, list[float]]:
    """Millisecond samples per callable, interleaved round-robin.

    On hosts with drifting load, timing A's iterations and then B's lets the
    drift masquerade as a real difference; interleaving puts every
    contender in the same time window, and rotating the within-round order
    each iteration cancels the warm-cache advantage of running later in a
    round.  Sample i of each key comes from the same round, so per-round
    ratios (``a[i]/b[i]``) are drift-free paired statistics.
    """
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn())
    samples: dict[str, list[float]] = {k: [] for k in fns}
    keys = list(fns)
    for i in range(iters):
        for k in keys[i % len(keys):] + keys[: i % len(keys)]:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[k]())
            samples[k].append((time.perf_counter() - t0) * 1e3)
    return samples


def interleaved_medians(fns: dict[str, object], *, warmup: int = 2, iters: int = 7) -> dict[str, float]:
    """Median ms per callable over interleaved samples."""
    samples = interleaved_samples(fns, warmup=warmup, iters=iters)
    return {k: _median(v) for k, v in samples.items()}


def bucket_pad_records(records: jax.Array, bucket_m: int) -> jax.Array:
    """Zero-pad the record batch up to the bucket's M (rows past the real M
    cost the same as real rows, which is exactly what the bucket entry must
    price in)."""
    m = records.shape[0]
    if m == bucket_m:
        return records
    return jnp.zeros((bucket_m, records.shape[1]), records.dtype).at[:m].set(records)


def measure_candidate(
    candidate: Candidate,
    records,
    enc,
    *,
    max_depth: int,
    warmup: int = 2,
    iters: int = 5,
) -> Measurement:
    """Median wall time of one candidate; a raising candidate measures as ∞."""
    spec = get_variant(candidate.variant)
    params = candidate.param_dict

    def run():
        return spec.fn(records, enc, max_depth=max_depth, **params)

    try:
        samples = time_callable(run, warmup=warmup, iters=iters)
    except Exception:
        return Measurement(candidate, float("inf"), ())
    return Measurement(candidate, _median(samples), samples)


def tune_workload(
    records,
    enc,
    *,
    cache: TuneCache | None = None,
    engines: tuple[str, ...] | None = None,
    warmup: int = 2,
    iters: int = 5,
    backend: str | None = None,
    verbose: bool = False,
) -> tuple[TuneEntry, list[Measurement]]:
    """Time every valid candidate for this workload and record the winner.

    Records are zero-padded to the shape bucket's M before timing, so the
    stored median prices the bucket (what dispatch will actually run), not
    the un-padded call.  Returns the winning entry (written to ``cache``
    under the bucket key when a cache is given) plus all measurements.
    """
    from repro.core.tree import tree_depth

    backend = backend or backend_tag()
    rec = jnp.asarray(records, jnp.float32)
    shape = WorkloadShape.of(rec, enc)
    rec = bucket_pad_records(rec, shape.bucket().m)
    depth = max(shape.depth, 1)

    measurements = [
        measure_candidate(c, rec, enc, max_depth=depth, warmup=warmup, iters=iters)
        for c in search_space(shape, engines=engines)
    ]
    ok = [m for m in measurements if not m.failed]
    if not ok:
        raise RuntimeError(f"no candidate succeeded for shape {shape}")
    best = min(ok, key=lambda m: m.median_ms)
    if verbose:
        for m in sorted(ok, key=lambda m: m.median_ms):
            print(f"  {m.median_ms:10.3f} ms  {m.candidate.variant} {m.candidate.param_dict}")
    entry = TuneEntry(
        variant=best.candidate.variant,
        params=best.candidate.param_dict,
        median_ms=best.median_ms,
        shape=dataclasses.asdict(shape),
        backend=backend,
    )
    if cache is not None:
        cache.store(shape.key(backend), entry)
    return entry, measurements
