"""Candidate timing: warmup, synchronised runs, medians.

Timing on an async-dispatch runtime (JAX) needs the discipline the paper
applies to its CUDA timings: compile/warm the candidate outside the timed
region, then bracket each timed call with ``jax.block_until_ready`` so host
timestamps measure device completion, and take the *median* over several
iterations so one-off scheduling noise doesn't crown the wrong variant.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.tree_eval.cascade import MAJORITY_FAMILY, get_cascade_variant
from repro.kernels.tree_eval.ops import (
    PER_TREE_FAMILY,
    PackedForest,
    get_forest_variant,
    get_variant,
)
from repro.kernels.tree_eval.quant import QuantizedForest, forest_table_bytes
from repro.tune.cache import TuneCache, TuneEntry
from repro.tune.space import (
    Candidate,
    ForestShape,
    WorkloadShape,
    backend_tag,
    cascade_search_space,
    forest_search_space,
    search_space,
)


@dataclasses.dataclass(frozen=True)
class Measurement:
    candidate: Candidate
    median_ms: float
    samples_ms: tuple[float, ...]
    # Static cost of the candidate's compiled HLO — ``{"flops", "bytes",
    # "roofline_frac"}`` — or None when the candidate has no single compiled
    # program (host-loop cascades) or lowering failed.  See
    # :func:`candidate_cost`.
    cost: dict | None = None
    # Device-resident node-table bytes of the candidate's layout (the packed
    # tables it keeps in HBM), or None for candidates without a packed
    # target (per-tree family).  Sits next to the HLO-cost gauges so layout
    # sweeps can weigh latency against footprint.
    table_bytes: float | None = None

    @property
    def failed(self) -> bool:
        return not self.samples_ms

    @property
    def mad_ms(self) -> float:
        """Median absolute deviation of the samples — the noise floor the
        trajectory store records next to the median."""
        if not self.samples_ms:
            return 0.0
        med = _median(self.samples_ms)
        return _median([abs(s - med) for s in self.samples_ms])


def _median(xs) -> float:
    xs = sorted(xs)
    n = len(xs)
    mid = n // 2
    return xs[mid] if n % 2 else (xs[mid - 1] + xs[mid]) / 2.0


def roofline_fraction(flops: float, bytes_: float, median_ms: float) -> float:
    """Achieved fraction of the hardware bound for one measured candidate.

    ``max(flops/PEAK_FLOPS, bytes/HBM_BW)`` is the shortest time the chip
    could possibly take (the roofline floor); dividing by the measured time
    says how close the candidate got.  Peaks are the TPU v5e constants from
    :mod:`repro.launch.roofline` — on the CPU interpret path the fraction is
    honest but tiny (the point is the *trend* across candidates and PRs, not
    the absolute value off-TPU).
    """
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS

    if median_ms <= 0 or median_ms == float("inf"):
        return 0.0
    floor_s = max(flops / PEAK_FLOPS, bytes_ / HBM_BW)
    return floor_s / (median_ms / 1e3)


def candidate_cost(fn, records, *, median_ms: float | None = None) -> dict | None:
    """FLOPs / bytes / roofline fraction of ``fn(records)``'s compiled HLO.

    Lowers ``jax.jit(fn)`` with ``records`` as a real argument (a zero-arg
    closure would constant-fold the whole program to a literal) and runs the
    trip-count-aware :func:`repro.utils.hlo_cost.analyze` over the compiled
    text.  Tree kernels are compare/gather programs, so ``flops`` (dot/conv
    only) is typically ~0 and ``bytes`` carries the signal — they are
    memory-bound by construction.  Returns None when lowering or analysis
    fails; cost is decoration, never a reason to fail a sweep.
    """
    from repro.utils.hlo_cost import analyze

    try:
        compiled = jax.jit(fn).lower(records).compile()
        cost = analyze(compiled.as_text())
    except Exception:
        return None
    out = {"flops": float(cost.flops), "bytes": float(cost.bytes)}
    if median_ms is not None:
        out["roofline_frac"] = roofline_fraction(cost.flops, cost.bytes, median_ms)
    return out


def _note_measurements(registry, level: str, measurements) -> None:
    """Record one sweep's outcomes: per-candidate medians and failure count.

    Levels mirror the dispatch ladder (``tree`` / ``forest`` / ``classes``);
    without an explicit registry the sweep lands in the process default, so
    one-shot functional tuning is visible too.
    """
    r = registry if registry is not None else obs.default_registry()
    measured = r.counter(
        "tune.measurements", "candidates measured per sweep", ("level",))
    failed = r.counter(
        "tune.failed_candidates",
        "candidates that raised during measurement", ("level",))
    ms = r.histogram(
        "tune.measure_ms", "per-candidate median measurement time",
        ("level",)).labels(level=level)
    g_flops = r.gauge(
        "tune.candidate_flops", "compiled-HLO FLOPs of the measured candidate",
        ("level", "variant"))
    g_bytes = r.gauge(
        "tune.candidate_bytes", "compiled-HLO HBM bytes of the measured candidate",
        ("level", "variant"))
    g_roof = r.gauge(
        "tune.roofline_frac",
        "achieved fraction of the hardware roofline bound (see launch/roofline.py)",
        ("level", "variant"))
    g_tbytes = r.gauge(
        "tune.candidate_table_bytes",
        "node-table bytes the candidate's layout keeps device-resident",
        ("level", "variant"))
    for m in measurements:
        measured.labels(level=level).inc()
        if m.failed:
            failed.labels(level=level).inc()
        else:
            ms.observe(m.median_ms)
        if m.cost is not None:
            v = m.candidate.variant
            g_flops.labels(level=level, variant=v).set(m.cost["flops"])
            g_bytes.labels(level=level, variant=v).set(m.cost["bytes"])
            g_roof.labels(level=level, variant=v).set(m.cost.get("roofline_frac", 0.0))
        if m.table_bytes is not None:
            g_tbytes.labels(level=level, variant=m.candidate.variant).set(m.table_bytes)


def time_callable(fn, *, warmup: int = 2, iters: int = 5) -> tuple[float, ...]:
    """Millisecond samples of ``fn()``; each run synchronised on its output.

    Args:
      fn: zero-argument callable returning a jax array/pytree; called
        ``warmup`` times un-timed (compilation, cache warm) then ``iters``
        times with ``jax.block_until_ready`` bracketing each run.
      warmup/iters: the measurement discipline (see module docstring).

    Returns:
      ``iters`` wall-clock samples in milliseconds (device-completion
      times, not dispatch times).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e3)
    return tuple(samples)


def interleaved_samples(
    fns: dict[str, object], *, warmup: int = 2, iters: int = 7
) -> dict[str, list[float]]:
    """Millisecond samples per callable, interleaved round-robin.

    On hosts with drifting load, timing A's iterations and then B's lets the
    drift masquerade as a real difference; interleaving puts every
    contender in the same time window, and rotating the within-round order
    each iteration cancels the warm-cache advantage of running later in a
    round.  Sample i of each key comes from the same round, so per-round
    ratios (``a[i]/b[i]``) are drift-free paired statistics.

    Args:
      fns: {label: zero-argument callable} — every contender to time.
      warmup/iters: per-callable warmup runs and timed rounds.

    Returns:
      {label: [ms, ...]} with ``iters`` samples per label, index-aligned
      across labels (sample i of every label came from round i).
    """
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn())
    samples: dict[str, list[float]] = {k: [] for k in fns}
    keys = list(fns)
    for i in range(iters):
        for k in keys[i % len(keys):] + keys[: i % len(keys)]:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[k]())
            samples[k].append((time.perf_counter() - t0) * 1e3)
    return samples


def interleaved_medians(fns: dict[str, object], *, warmup: int = 2, iters: int = 7) -> dict[str, float]:
    """Median ms per callable over interleaved samples."""
    samples = interleaved_samples(fns, warmup=warmup, iters=iters)
    return {k: _median(v) for k, v in samples.items()}


def bucket_pad_records(records: jax.Array, bucket_m: int) -> jax.Array:
    """Zero-pad the record batch up to the bucket's M.

    Rows past the real M cost the same as real rows, which is exactly what
    the bucket entry must price in.

    Args:
      records: (M, A) float array with M ≤ ``bucket_m``.
      bucket_m: the shape bucket's record count (a power of two).

    Returns:
      (bucket_m, A) array — ``records`` above zero rows; returned as-is
      when M already equals ``bucket_m``.
    """
    m = records.shape[0]
    if m == bucket_m:
        return records
    return jnp.zeros((bucket_m, records.shape[1]), records.dtype).at[:m].set(records)


def measure_candidate(
    candidate: Candidate,
    records,
    enc,
    *,
    max_depth: int,
    warmup: int = 2,
    iters: int = 5,
) -> Measurement:
    """Median wall time of one candidate; a raising candidate measures as ∞.

    Args:
      candidate: the (variant, params) pair to time.
      records: (M, A) float32 batch, already bucket-padded by the caller.
      enc: the :class:`repro.core.tree.EncodedTree` under test.
      max_depth: static depth bound passed to the variant.
      warmup/iters: :func:`time_callable` discipline.

    Returns:
      A :class:`Measurement`; ``failed`` (empty samples, median ∞) when
      the candidate raised — invalid candidates lose, they don't crash the
      sweep.
    """
    spec = get_variant(candidate.variant)
    params = candidate.param_dict

    def fn(rec):
        return spec.fn(rec, enc, max_depth=max_depth, **params)

    try:
        samples = time_callable(lambda: fn(records), warmup=warmup, iters=iters)
    except Exception:
        return Measurement(candidate, float("inf"), ())
    median = _median(samples)
    return Measurement(candidate, median, samples,
                       candidate_cost(fn, records, median_ms=median))


def tune_workload(
    records,
    enc,
    *,
    cache: TuneCache | None = None,
    engines: tuple[str, ...] | None = None,
    warmup: int = 2,
    iters: int = 5,
    backend: str | None = None,
    verbose: bool = False,
    registry: obs.Registry | None = None,
) -> tuple[TuneEntry, list[Measurement]]:
    """Time every valid candidate for this workload and record the winner.

    Records are zero-padded to the shape bucket's M before timing, so the
    stored median prices the bucket (what dispatch will actually run), not
    the un-padded call.  Returns the winning entry (written to ``cache``
    under the bucket key when a cache is given) plus all measurements.
    """
    from repro.core.tree import tree_depth

    backend = backend or backend_tag()
    rec = jnp.asarray(records, jnp.float32)
    shape = WorkloadShape.of(rec, enc)
    rec = bucket_pad_records(rec, shape.bucket().m)
    depth = max(shape.depth, 1)

    measurements = [
        measure_candidate(c, rec, enc, max_depth=depth, warmup=warmup, iters=iters)
        for c in search_space(shape, engines=engines)
    ]
    _note_measurements(registry, "tree", measurements)
    ok = [m for m in measurements if not m.failed]
    if not ok:
        raise RuntimeError(f"no candidate succeeded for shape {shape}")
    best = min(ok, key=lambda m: m.median_ms)
    if verbose:
        for m in sorted(ok, key=lambda m: m.median_ms):
            print(f"  {m.median_ms:10.3f} ms  {m.candidate.variant} {m.candidate.param_dict}")
    entry = TuneEntry(
        variant=best.candidate.variant,
        params=best.candidate.param_dict,
        median_ms=best.median_ms,
        shape=dataclasses.asdict(shape),
        backend=backend,
    )
    if cache is not None:
        cache.store(shape.key(backend), entry)
    return entry, measurements


# ---------------------------------------------------------------------------
# Forest-level measurement
# ---------------------------------------------------------------------------


def _forest_candidate_fn(
    candidate: Candidate, rec, forest, *, depth: int, cache, engines,
    autotune_trees: bool = False, measure_kw: dict | None = None,
):
    """Build the timed callable for one forest candidate as a one-argument
    function of the record batch (warm state outside the timed region:
    per-tree winners resolved — autotuned when ``autotune_trees``, pricing
    the per-tree family at its tuned best — and fused tables packed).
    Taking the batch as an argument keeps the same callable usable for
    :func:`candidate_cost`, where a closed-over batch would constant-fold.

    Returns ``(fn, table_bytes)``: the callable plus the device-resident
    node-table footprint of the candidate's packed layout (None when the
    candidate has no single packed target, i.e. the per-tree family)."""
    if candidate.variant == PER_TREE_FAMILY:
        from repro.tune.dispatch import TunedEvaluator  # local: avoid cycle

        evs = [
            TunedEvaluator(forest.tree(i), cache=cache, engines=engines,
                           autotune=autotune_trees, measure_kw=measure_kw)
            for i in range(forest.n_trees)
        ]
        # Resolve every per-tree winner on the real batch before any jit
        # trace sees the evaluators (resolution itself measures, which must
        # not happen under a tracer).
        for ev in evs:
            ev(rec)
        return (lambda r: jnp.stack([ev(r) for ev in evs])), None
    spec = get_forest_variant(candidate.variant)
    params = candidate.param_dict
    if getattr(spec, "layout", "f32") == "quant":
        # Universal mode (no calibration): bit-exact for every input, so the
        # tuner may hand this layout to dispatch without changing results.
        target = QuantizedForest(
            forest, rec.shape[1],
            thr_dtype=params.get("thr_dtype", "bfloat16"))
    elif spec.family == "fused":
        target = PackedForest(forest, rec.shape[1])
    else:
        target = forest
    tbytes = forest_table_bytes(target) if target is not forest else None
    return (lambda r: spec.fn(r, target, max_depth=depth, **params)), tbytes


def measure_forest_candidate(
    candidate: Candidate,
    records,
    forest,
    *,
    cache: TuneCache | None = None,
    engines: tuple[str, ...] | None = None,
    warmup: int = 2,
    iters: int = 5,
    autotune_trees: bool = False,
) -> Measurement:
    """Median wall time of one forest candidate; a raising candidate is ∞.

    Args:
      candidate: a :func:`repro.tune.space.forest_search_space` candidate
        (``Candidate(PER_TREE_FAMILY)`` or a registered forest variant).
      records: (M, A) float32 batch, already bucket-padded by the caller.
      forest: the :class:`repro.core.forest.EncodedForest` under test.
      cache/engines: per-tree resolution inputs for the ``per_tree`` family.
      warmup/iters: :func:`time_callable` discipline.
      autotune_trees: measure the ``per_tree`` family with per-tree
        autotuning (winners measured during warmup, persisted to ``cache``)
        instead of the heuristic — the PR 3 tuned baseline.

    Returns:
      A :class:`Measurement` whose samples bracket device completion.
    """
    depth = max(int(forest.max_depth), 1)
    try:
        fn, table_bytes = _forest_candidate_fn(
            candidate, records, forest, depth=depth, cache=cache, engines=engines,
            autotune_trees=autotune_trees,
            measure_kw={"warmup": warmup, "iters": iters},
        )
        samples = time_callable(lambda: fn(records), warmup=warmup, iters=iters)
    except Exception:
        return Measurement(candidate, float("inf"), ())
    median = _median(samples)
    return Measurement(candidate, median, samples,
                       candidate_cost(fn, records, median_ms=median),
                       table_bytes=table_bytes)


def tune_forest_workload(
    records,
    forest,
    *,
    cache: TuneCache | None = None,
    engines: tuple[str, ...] | None = None,
    families: tuple[str, ...] | None = None,
    layouts: tuple[str, ...] | None = None,
    warmup: int = 2,
    iters: int = 5,
    backend: str | None = None,
    verbose: bool = False,
    autotune_trees: bool = False,
    store: bool = True,
    registry: obs.Registry | None = None,
) -> tuple[TuneEntry, list[Measurement]]:
    """Time every valid forest candidate and record the winning family.

    The forest analogue of :func:`tune_workload`: records are zero-padded to
    the :class:`ForestShape` bucket's M before timing (pricing what dispatch
    will actually run) and every candidate from the three families —
    per-tree variant vector, shared-variant vmap, fused stacked kernel — is
    measured with the same warmup/median discipline.  The winner is stored
    in ``cache`` under the forest bucket key.

    Args:
      records: (M, A) record batch.
      forest: the :class:`repro.core.forest.EncodedForest` to tune for.
      cache: winner store (also consulted by the ``per_tree`` family's
        per-tree resolutions).
      engines/families/layouts: restrict the candidate enumeration
        (``layouts`` defaults to the f32 tables; pass ``("f32", "quant")``
        to let the compact :class:`QuantizedForest` candidates compete).
      warmup/iters/backend/verbose: as in :func:`tune_workload`.
      autotune_trees: give the ``per_tree`` family its tuned best (per-tree
        winners measured and persisted) rather than the heuristic choice.
      store: persist the winner under the forest bucket key.  Callers
        measuring a *restricted* family set pass False — a family-filtered
        winner must not overwrite the bucket's unrestricted one.

    Returns:
      (winning entry, all measurements) — entry.variant is a forest variant
      name or ``"per_tree"``.
    """
    backend = backend or backend_tag()
    rec = jnp.asarray(records, jnp.float32)
    shape = ForestShape.of(rec, forest)
    rec = bucket_pad_records(rec, shape.bucket().m)

    measurements = [
        measure_forest_candidate(
            c, rec, forest, cache=cache, engines=engines, warmup=warmup, iters=iters,
            autotune_trees=autotune_trees,
        )
        for c in forest_search_space(
            shape, engines=engines, families=families, layouts=layouts)
    ]
    _note_measurements(registry, "forest", measurements)
    ok = [m for m in measurements if not m.failed]
    if not ok:
        raise RuntimeError(f"no forest candidate succeeded for shape {shape}")
    best = min(ok, key=lambda m: m.median_ms)
    if verbose:
        for m in sorted(ok, key=lambda m: m.median_ms):
            print(f"  {m.median_ms:10.3f} ms  {m.candidate.variant} {m.candidate.param_dict}")
    entry = TuneEntry(
        variant=best.candidate.variant,
        params=best.candidate.param_dict,
        median_ms=best.median_ms,
        shape=dataclasses.asdict(shape),
        backend=backend,
    )
    if cache is not None and store:
        cache.store(shape.key(backend), entry)
    return entry, measurements


# ---------------------------------------------------------------------------
# Class-level (majority vs cascade) measurement
# ---------------------------------------------------------------------------


def measure_cascade_candidate(
    candidate: Candidate,
    records,
    forest,
    n_classes: int,
    *,
    cache: TuneCache | None = None,
    engines: tuple[str, ...] | None = None,
    warmup: int = 2,
    iters: int = 5,
) -> Measurement:
    """Median wall time of one class-level candidate.

    ``Candidate(MAJORITY_FAMILY)`` prices the full path — the forest-level
    winner followed by ``majority_vote`` — through a warm
    :class:`repro.tune.dispatch.ForestTunedEvaluator`; cascade candidates
    price a warm :class:`CascadeEvaluator` built at bound 1.0 (the only
    bound the tuner may enumerate: every timed candidate must be exact so
    the class-level choice never changes results).  Cascade timings include
    the host-side compaction loop — that *is* the candidate's cost.
    """
    import numpy as np

    from repro.core.forest import majority_vote

    try:
        if candidate.variant == MAJORITY_FAMILY:
            from repro.tune.dispatch import ForestTunedEvaluator  # local: avoid cycle

            fte = ForestTunedEvaluator(forest, cache=cache, engines=engines)
            run = lambda: majority_vote(fte(records), n_classes)  # noqa: E731
        else:
            spec = get_cascade_variant(candidate.variant)
            params = candidate.param_dict
            ev = spec.build(
                forest,
                n_classes=n_classes,
                stages=int(params.get("stages", 2)),
                bound=1.0,
                block_m=params.get("block_m"),
                calibration=records,
            )
            rec_np = np.asarray(records, np.float32)
            run = lambda: ev(rec_np).classes  # noqa: E731
        samples = time_callable(run, warmup=warmup, iters=iters)
    except Exception:
        return Measurement(candidate, float("inf"), ())
    return Measurement(candidate, _median(samples), samples)


def tune_cascade_workload(
    records,
    forest,
    n_classes: int,
    *,
    cache: TuneCache | None = None,
    engines: tuple[str, ...] | None = None,
    warmup: int = 2,
    iters: int = 5,
    backend: str | None = None,
    verbose: bool = False,
    store: bool = True,
    registry: obs.Registry | None = None,
) -> tuple[TuneEntry, list[Measurement]]:
    """Time every class-level candidate and record the winner.

    The class-level analogue of :func:`tune_forest_workload`: the full
    majority-vote path competes against every registered cascade variant
    crossed with the stage grid (see
    :func:`repro.tune.space.cascade_search_space`).  Early-exit fractions —
    and therefore cascade timings — depend on the *actual* record mix, so
    candidates are timed on the un-bucketed batch and the winner is stored
    under the bucketed :meth:`ForestShape.classes_key`.
    """
    backend = backend or backend_tag()
    rec = jnp.asarray(records, jnp.float32)
    shape = ForestShape.of(rec, forest)

    measurements = [
        measure_cascade_candidate(
            c, rec, forest, n_classes,
            cache=cache, engines=engines, warmup=warmup, iters=iters,
        )
        for c in cascade_search_space(shape, n_classes, engines=engines)
    ]
    _note_measurements(registry, "classes", measurements)
    ok = [m for m in measurements if not m.failed]
    if not ok:
        raise RuntimeError(f"no class-level candidate succeeded for shape {shape}")
    best = min(ok, key=lambda m: m.median_ms)
    if verbose:
        for m in sorted(ok, key=lambda m: m.median_ms):
            print(f"  {m.median_ms:10.3f} ms  {m.candidate.variant} {m.candidate.param_dict}")
    entry = TuneEntry(
        variant=best.candidate.variant,
        params=best.candidate.param_dict,
        median_ms=best.median_ms,
        shape=dataclasses.asdict(shape),
        backend=backend,
    )
    if cache is not None and store:
        cache.store(shape.classes_key(n_classes, backend), entry)
    return entry, measurements
