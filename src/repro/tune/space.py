"""Workload shapes, shape bucketing, and the candidate search space.

A *workload shape* is the 4-tuple the paper's §4 runtime model is written
over: record count M, node count N, attribute count A and tree depth d.
Candidates are (variant, params) pairs drawn from the kernel variant
registry (:mod:`repro.kernels.tree_eval.ops`); :func:`search_space`
enumerates only the candidates that are *valid* for a given shape (e.g. the
one-hot MXU formulation is excluded when the N² one-hot would blow the
VMEM/FLOP budget).

Shapes are *bucketed* before they key the cache: M rounds up to a power of
two, N and A round up to the 128-lane tile the kernels pad to anyway, and
depth rounds up to the next power of two.  Bucketing trades a little
optimality near bucket edges for cache hits across the jitter of real
request sizes — the same reason the serve engine pads waves.

Forest-level tuning adds :class:`ForestShape` — the (T, M, N_max, A,
depth-profile) operating point of a whole forest call — and
:func:`forest_search_space`, which enumerates the three candidate families
(per-tree variant vectors, the shared-variant vmap path, and the fused
stacked Pallas kernel) that :class:`repro.tune.ForestTunedEvaluator` ranks.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterator

import jax

from repro.kernels.tree_eval.cascade import (
    MAJORITY_FAMILY,
    exit_enabling_prefix,
    list_cascade_variants,
)
from repro.kernels.tree_eval.ops import (
    LANE,
    PER_TREE_FAMILY,
    SUBLANE,
    ForestVariantSpec,
    VariantSpec,
    _round_up,
    choose_block_m,
    list_forest_variants,
    list_variants,
    on_tpu,
)

# One-hot speculative candidates materialise an (M, N) matmul against an
# (A, N) selection matrix; past this node count the matmul work dwarfs the
# gather it replaces on every backend we model.
MAX_ONEHOT_NODES = 2048

# Threshold dtypes the quantized-layout candidates sweep.  The dtype is a
# cache-identity parameter (consumed when the QuantizedForest packs), so
# winners tuned at different node dtypes never collide in the cache.
QUANT_THR_DTYPES = ("bfloat16", "float16")


def _next_pow2(x: int) -> int:
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


def backend_tag() -> str:
    """Backend + device kind + topology tag for cache keys.

    ``jax.default_backend()`` alone conflates machine classes that tune very
    differently (v5e vs v5p TPUs, laptop vs server CPUs), and a winner tuned
    on one topology may lose on another (device count changes the shard
    shapes the dist executor asks about).  Keying on
    ``backend:device_kind:xN`` lets one shared cache file serve a
    heterogeneous fleet: every machine class reads and writes its own rows.
    """
    devs = jax.devices()
    kind = str(getattr(devs[0], "device_kind", "") or jax.default_backend())
    kind = re.sub(r"[^0-9A-Za-z_.-]+", "_", kind).strip("_").lower()
    return f"{jax.default_backend()}:{kind}:x{len(devs)}"


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """The (M, N, A, depth) operating point of one tree-eval call."""

    m: int        # records
    n_nodes: int  # tree nodes (unpadded)
    n_attrs: int  # record attributes
    depth: int    # max root→leaf depth (edges)

    def bucket(self) -> "WorkloadShape":
        """Quantise to the cache-key granularity (idempotent)."""
        return WorkloadShape(
            m=_next_pow2(self.m),
            n_nodes=_round_up(max(self.n_nodes, 1), LANE),
            n_attrs=_round_up(max(self.n_attrs, 1), LANE),
            depth=_next_pow2(self.depth),
        )

    def key(self, backend: str | None = None) -> str:
        """Stable cache key: backend/topology tag + bucketed shape.

        ``backend`` defaults to :func:`backend_tag` (device kind + count),
        not the bare ``jax.default_backend()`` string.
        """
        b = self.bucket()
        tag = backend if backend is not None else backend_tag()
        return f"{tag}|M{b.m}|N{b.n_nodes}|A{b.n_attrs}|d{b.depth}"

    @classmethod
    def of(cls, records, enc, depth: int | None = None) -> "WorkloadShape":
        import numpy as np

        from repro.core.tree import tree_depth

        shape = np.asarray(records).shape if not hasattr(records, "shape") else records.shape
        return cls(
            m=int(shape[0]),
            n_nodes=int(enc.n_nodes),
            n_attrs=int(shape[1]),
            depth=int(depth if depth is not None else max(tree_depth(enc), 1)),
        )


@dataclasses.dataclass(frozen=True)
class Candidate:
    """A concrete (variant, parameter assignment) the tuner can time."""

    variant: str
    params: tuple[tuple[str, object], ...] = ()

    @property
    def param_dict(self) -> dict:
        return dict(self.params)

    @classmethod
    def make(cls, variant: str, **params) -> "Candidate":
        return cls(variant=variant, params=tuple(sorted(params.items())))


def _block_m_grid(shape: WorkloadShape, jump_mode: str) -> list[int]:
    """VMEM-model block size plus its power-of-two neighbours."""
    b = shape.bucket()
    base = choose_block_m(b.n_nodes, b.n_attrs, jump_mode=jump_mode)
    grid = {base, max(base // 2, SUBLANE), min(base * 2, 1024)}
    return sorted(x for x in grid if SUBLANE <= x <= 1024)


def _jumps_grid(shape: WorkloadShape) -> list[int]:
    """Procedure-5 multi-jump factors worth trying (paper found 2 optimal)."""
    if shape.depth <= 2:
        return [1]
    return [1, 2, 3]


def default_engines() -> tuple[str, ...]:
    """Engines worth timing on this backend.

    On TPU the Pallas kernels are the real contenders and the jnp paths are
    kept for reference; off-TPU the kernels run in interpret mode (orders of
    magnitude slow, and not what dispatch would ever pick), so only the
    XLA-compiled jnp variants enter the space.
    """
    return ("pallas", "jnp") if on_tpu() else ("jnp",)


def variant_valid(spec: VariantSpec, shape: WorkloadShape) -> bool:
    """Whether ``spec`` is worth timing at ``shape`` (see MAX_ONEHOT_NODES)."""
    if spec.jump_mode == "onehot" and shape.n_nodes > MAX_ONEHOT_NODES:
        return False
    return True


def search_space(
    shape: WorkloadShape,
    *,
    engines: tuple[str, ...] | None = None,
) -> Iterator[Candidate]:
    """Enumerate every candidate valid for ``shape``, cheapest-grid first.

    Args:
      shape: the (M, N, A, depth) operating point to tune for.
      engines: permitted engines ("pallas"/"jnp"); default =
        :func:`default_engines` for this backend.

    Yields:
      :class:`Candidate` values — each registered variant crossed with its
      tunable-parameter grid (block_m from the VMEM model ± a power of
      two, jumps_per_round from the Procedure-5 grid).
    """
    engines = default_engines() if engines is None else tuple(engines)
    for spec in list_variants():
        if spec.engine not in engines or not variant_valid(spec, shape):
            continue
        if "block_m" in spec.tunables:
            for bm in _block_m_grid(shape, spec.jump_mode):
                yield Candidate.make(spec.name, block_m=bm)
        elif "jumps_per_round" in spec.tunables:
            for j in _jumps_grid(shape):
                yield Candidate.make(spec.name, jumps_per_round=j)
        else:
            yield Candidate.make(spec.name)


# ---------------------------------------------------------------------------
# Forest-level shapes and candidates
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ForestShape:
    """The (T, M, N_max, A, depth profile) operating point of one forest call.

    The depth *profile* — (depth_min, depth_max) over the forest's trees —
    is what distinguishes forest buckets from a per-tree
    :class:`WorkloadShape`: a homogeneous profile favours the stacked
    families (padding every tree to the common geometry is free), a spread
    profile charges the stacked families ``depth_max`` rounds for trees that
    would finish in ``depth_min``.
    """

    t: int          # trees
    m: int          # records
    n_nodes: int    # common (padded) node count per tree — N_max
    n_attrs: int    # record attributes
    depth_min: int  # shallowest tree's max root→leaf depth (edges)
    depth_max: int  # deepest tree's max root→leaf depth (edges)

    def bucket(self) -> "ForestShape":
        """Quantise to the cache-key granularity (idempotent)."""
        return ForestShape(
            t=_next_pow2(self.t),
            m=_next_pow2(self.m),
            n_nodes=_round_up(max(self.n_nodes, 1), LANE),
            n_attrs=_round_up(max(self.n_attrs, 1), LANE),
            depth_min=_next_pow2(self.depth_min),
            depth_max=_next_pow2(self.depth_max),
        )

    def key(self, backend: str | None = None) -> str:
        """Stable cache key for the forest bucket.

        The ``T``/depth-profile components keep forest keys disjoint from
        the per-tree ``WorkloadShape`` keys in the same cache file.
        """
        b = self.bucket()
        tag = backend if backend is not None else backend_tag()
        return f"{tag}|T{b.t}|M{b.m}|N{b.n_nodes}|A{b.n_attrs}|d{b.depth_min}-{b.depth_max}"

    def tree_shape(self) -> WorkloadShape:
        """The padded common geometry as a per-tree shape (heuristic input)."""
        return WorkloadShape(
            m=self.m, n_nodes=self.n_nodes, n_attrs=self.n_attrs, depth=self.depth_max
        )

    def classes_key(self, n_classes: int, backend: str | None = None) -> str:
        """Cache key for the *class-level* (majority/cascade) bucket.

        Class-level winners answer a different question than forest winners
        — "what classes?" rather than "what per-tree matrix?" — and the
        candidate set depends on C (the vote tally width), so the key is the
        forest key suffixed with the class count.
        """
        return f"{self.key(backend)}|C{int(n_classes)}"

    @classmethod
    def of(
        cls,
        records,
        forest,
        *,
        depth_min: int | None = None,
        depth_max: int | None = None,
    ) -> "ForestShape":
        """Derive the shape from a record batch + EncodedForest.

        Per-tree depths cost an O(T·N) host pass; callers that hold a
        resolved evaluator (which computes them once) pass them in.
        """
        import numpy as np

        from repro.core.tree import tree_depth

        shape = np.asarray(records).shape if not hasattr(records, "shape") else records.shape
        if depth_min is None or depth_max is None:
            depths = [max(tree_depth(forest.tree(i)), 1) for i in range(forest.n_trees)]
            depth_min = min(depths) if depth_min is None else depth_min
            depth_max = max(depths) if depth_max is None else depth_max
        return cls(
            t=int(forest.n_trees),
            m=int(shape[0]),
            n_nodes=int(forest.n_nodes),
            n_attrs=int(shape[1]),
            depth_min=int(depth_min),
            depth_max=int(depth_max),
        )


def forest_variant_valid(spec: ForestVariantSpec, shape: ForestShape) -> bool:
    if spec.jump_mode == "onehot" and shape.n_nodes > MAX_ONEHOT_NODES:
        return False
    return True


def forest_search_space(
    shape: ForestShape,
    *,
    engines: tuple[str, ...] | None = None,
    families: tuple[str, ...] | None = None,
    layouts: tuple[str, ...] | None = None,
) -> Iterator[Candidate]:
    """Enumerate every forest candidate valid for ``shape``.

    Three families compete (issue/ROADMAP: forest-level tuning):

      * ``per_tree`` — the PR 3 path: each tree dispatches through its own
        per-tree winner (a variant *vector*, represented by the sentinel
        candidate ``Candidate(PER_TREE_FAMILY)``);
      * ``vmap``     — one shared variant, the stacked jnp formulation
        ``vmap``-ed over the tree axis;
      * ``fused``    — the stacked Pallas kernel: one launch, tree axis on
        the grid.

    ``families`` restricts the enumeration (the dist executor asks only for
    the shared families — a shard body needs a single kern).  ``layouts``
    gates the node-table layouts: the default ``("f32",)`` keeps the
    enumeration to the full-width tables; opting in with
    ``("f32", "quant")`` adds the compact :class:`QuantizedForest`
    candidates, crossed over :data:`QUANT_THR_DTYPES` (the threshold dtype
    is part of the candidate — and therefore cache — identity).
    """
    engines = default_engines() if engines is None else tuple(engines)
    families = ("per_tree", "vmap", "fused") if families is None else tuple(families)
    layouts = ("f32",) if layouts is None else tuple(layouts)
    if PER_TREE_FAMILY in families and "f32" in layouts:
        yield Candidate.make(PER_TREE_FAMILY)
    for spec in list_forest_variants():
        if (
            spec.family not in families
            or spec.engine not in engines
            or getattr(spec, "layout", "f32") not in layouts
            or not forest_variant_valid(spec, shape)
        ):
            continue
        tshape = shape.tree_shape()
        if "thr_dtype" in spec.tunables:
            for td in QUANT_THR_DTYPES:
                if "block_m" in spec.tunables:
                    for bm in _block_m_grid(tshape, spec.jump_mode):
                        yield Candidate.make(spec.name, block_m=bm, thr_dtype=td)
                else:
                    yield Candidate.make(spec.name, thr_dtype=td)
        elif "block_m" in spec.tunables:
            for bm in _block_m_grid(tshape, spec.jump_mode):
                yield Candidate.make(spec.name, block_m=bm)
        elif "jumps_per_round" in spec.tunables:
            for j in _jumps_grid(tshape):
                yield Candidate.make(spec.name, jumps_per_round=j)
        else:
            yield Candidate.make(spec.name)


# ---------------------------------------------------------------------------
# Class-level (majority / cascade) candidates
# ---------------------------------------------------------------------------


def cascade_stage_grid(shape: ForestShape) -> list[int]:
    """Stage counts worth timing for a ``shape.t``-tree forest.

    A cascade needs the exit-enabling first stage (``k_min`` trees at
    bound 1.0) *plus* at least one later stage the exits can skip, so
    forests with fewer than 3 trees admit no useful cascade.  The later
    stages partition the ``t - k_min`` remaining trees; stage counts whose
    tail stages would be empty are dropped.
    """
    t = int(shape.t)
    if t < 3:
        return []
    k_min = exit_enabling_prefix(t, 1.0)
    rest = t - k_min
    if rest < 1:
        return []
    return [s for s in (2, 3, 4) if s - 1 <= rest]


def cascade_search_space(
    shape: ForestShape,
    n_classes: int,
    *,
    engines: tuple[str, ...] | None = None,
) -> Iterator[Candidate]:
    """Enumerate class-level candidates: full majority vote vs cascades.

    The baseline sentinel ``Candidate(MAJORITY_FAMILY)`` routes through the
    forest-level winner (all T trees) followed by ``majority_vote``; the
    cascade candidates cross each registered cascade variant with the stage
    grid (× the block-size grid for the pallas engine).  Every candidate is
    exact at bound 1.0, so the class-level choice never changes results.
    """
    del n_classes  # shapes the tally width, not the candidate set (kept for keying)
    engines = default_engines() if engines is None else tuple(engines)
    yield Candidate.make(MAJORITY_FAMILY)
    stage_grid = cascade_stage_grid(shape)
    if not stage_grid:
        return
    tshape = shape.tree_shape()
    for spec in list_cascade_variants():
        if spec.engine not in engines:
            continue
        if spec.jump_mode == "onehot" and shape.n_nodes > MAX_ONEHOT_NODES:
            continue
        for s in stage_grid:
            if "block_m" in spec.tunables:
                for bm in _block_m_grid(tshape, spec.jump_mode):
                    yield Candidate.make(spec.name, stages=s, block_m=bm)
            else:
                yield Candidate.make(spec.name, stages=s)
