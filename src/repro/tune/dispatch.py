"""Transparent variant dispatch: ``tuned_eval(records, tree)``.

Resolution order for each (backend, shape-bucket):

  1. in-process memo (one dict probe on the hot path),
  2. persistent cache (:class:`repro.tune.cache.TuneCache`),
  3. optional on-miss autotune (``autotune=True`` — measures the search
     space once and persists the winner),
  4. the §3.6-model heuristic (:mod:`repro.tune.heuristic`).

Dispatch zero-pads the record batch up to the bucket's M before running the
variant and slices the padding back off, so every call inside a bucket hits
one jit specialisation and the timings stored by the tuner stay honest.
All variants are exact (bit-identical to the serial reference), so dispatch
never changes results — only which kernel produces them.

:class:`ForestTunedEvaluator` lifts the same contract to whole forests: the
resolution unit is the (T, M, N_max, A, depth-profile) bucket and the
candidate space spans three families (per-tree variant vectors, shared-
variant vmap, fused stacked kernel).  Both evaluators expose ``promote`` /
``invalidate`` — the atomic winner-swap hooks the serve engines' background
re-tune drives.
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.tree import EncodedTree, tree_depth
from repro.kernels.tree_eval.cascade import (
    CASCADE_VARIANTS,
    MAJORITY_FAMILY,
    get_cascade_variant,
)
from repro.kernels.tree_eval.ops import (
    FOREST_VARIANTS,
    PER_TREE_FAMILY,
    VARIANTS,
    PackedForest,
    get_forest_variant,
    get_variant,
)
from repro.kernels.tree_eval.quant import QuantizedForest
from repro.tune.cache import TuneCache, TuneEntry
from repro.tune.heuristic import (
    cascade_heuristic_candidate,
    default_d_mu,
    forest_heuristic_candidate,
    heuristic_candidate,
    measured_d_mu,
    measured_forest_d_mu,
    measured_survival_rate,
)
from repro.tune.measure import (
    bucket_pad_records,
    tune_cascade_workload,
    tune_forest_workload,
    tune_workload,
)
from repro.tune.space import Candidate, ForestShape, WorkloadShape, backend_tag


class _TuneObs:
    """The tuner's shared instrument set on one registry.

    Levels: ``tree`` (per-tree variant resolution), ``forest`` (family
    resolution), ``classes`` (majority-vote vs cascade).  The agreement
    counter compares each *measured* winner against what the §3.6 heuristic
    would have picked for the same bucket — the running answer to "is the
    model good enough to skip measuring?".
    """

    def __init__(self, registry: obs.Registry | None,
                 tracer: obs.Tracer | None):
        self.registry = registry if registry is not None else obs.default_registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        r = self.registry
        self.resolutions = r.counter(
            "tune.resolutions", "kernel resolutions by level and source",
            ("level", "source"))
        self.swaps = r.counter(
            "tune.winner_swaps", "atomic winner promotions (background re-tune)",
            ("level",))
        self.agreement = r.counter(
            "tune.heuristic_agreement",
            "measured winner vs §3.6-heuristic pick, per autotune resolution",
            ("level", "agree"))
        self.d_mu_gauge = r.gauge(
            "tune.d_mu", "d_µ the §3.6 heuristic evaluated at, by provenance",
            ("level", "source"))
        self.d_mu_provenance = r.counter(
            "tune.d_mu_provenance",
            "heuristic resolutions by d_µ provenance "
            "(measured=traversal profiler, sampled=host descent, prior=geometry)",
            ("level", "source"))
        self.d_mu_agreement = r.counter(
            "tune.d_mu_agreement",
            "measured-d_µ heuristic pick vs geometry-prior pick, per resolution",
            ("level", "agree"))
        self.survival_provenance = r.counter(
            "tune.survival_provenance",
            "cascade-survival provenance at class-level resolutions",
            ("source",))

    def note_resolution(self, level: str, source: str) -> None:
        self.resolutions.labels(level=level, source=source).inc()

    def note_d_mu(self, level: str, source: str, value: float) -> None:
        self.d_mu_provenance.labels(level=level, source=source).inc()
        self.d_mu_gauge.labels(level=level, source=source).set(value)

    def note_d_mu_agreement(self, level: str, cand: Candidate,
                            prior_pick) -> None:
        """Would the geometry prior have picked the same variant as the
        profiler-measured d_µ did?  Mirrors :meth:`note_agreement` — a
        running answer to "does measuring d_µ actually change decisions?"."""
        try:
            h = prior_pick()
            agree = "yes" if h.variant == cand.variant else "no"
        except Exception:
            agree = "error"
        self.d_mu_agreement.labels(level=level, agree=agree).inc()

    def note_swap(self, level: str, key: str) -> None:
        self.swaps.labels(level=level).inc()
        self.tracer.instant("tune.promote", cat="tune", level=level, bucket=key)

    def note_agreement(self, level: str, measured: Candidate,
                       heuristic_pick) -> None:
        try:
            h = heuristic_pick()
            agree = "yes" if h.variant == measured.variant else "no"
        except Exception:
            agree = "error"
        self.agreement.labels(level=level, agree=agree).inc()


def _resolve_d_mu(kw: dict, *, profiler, key: str, measure: bool, sample_fn):
    """Fill ``kw["d_mu"]`` through the provenance ladder; returns the source.

    caller-supplied ``heuristic_kw`` override > traversal-profiler
    measurement for this bucket > host-sampled descent on the batch >
    geometry prior (``kw`` left without d_mu — the heuristic defaults it).
    """
    if "d_mu" in kw:
        return "caller"
    if profiler is not None:
        measured = profiler.d_mu(key)
        if measured is not None:
            kw["d_mu"] = measured
            return "measured"
    if measure:
        kw["d_mu"] = sample_fn()
        return "sampled"
    return "prior"


class TunedEvaluator:
    """Reusable tuned dispatcher for one encoded tree.

    Prefer this over the functional :func:`tuned_eval` on hot paths (serving,
    forests): it owns the depth computation, the cache handle, and a
    per-bucket resolution memo, so steady-state calls do no lookup work.
    """

    def __init__(
        self,
        enc: EncodedTree,
        *,
        cache: TuneCache | None = None,
        autotune: bool = False,
        engines: tuple[str, ...] | None = None,
        measure_kw: dict | None = None,
        measure_d_mu: bool = True,
        d_mu_sample: int = 256,
        heuristic_kw: dict | None = None,
        registry: obs.Registry | None = None,
        tracer: obs.Tracer | None = None,
        profiler=None,
    ):
        self.enc = enc
        self.cache = cache if cache is not None else TuneCache()
        self.autotune = autotune
        self.engines = engines
        self._obs = _TuneObs(registry, tracer)
        # a TraversalProfiler (or anything with .d_mu(key)): measured d_µ
        # per bucket beats both the host sample and the geometry prior
        self.profiler = profiler
        self.measure_kw = dict(measure_kw or {})
        # heuristic fallback: measure d_µ on a sample of the actual batch
        # (paper: "measured on a significant sample") instead of trusting
        # the geometry prior; heuristic_kw forwards cm/p_group overrides.
        self.measure_d_mu = measure_d_mu
        self.d_mu_sample = d_mu_sample
        self.heuristic_kw = dict(heuristic_kw or {})
        self.depth = max(tree_depth(enc), 1)
        self._resolved: dict[str, tuple[Candidate, str]] = {}
        # (M, A) → (spec, params, bucket_m): the steady-state call path does
        # one dict probe and zero array ops beyond the kernel itself.
        self._fast: dict[tuple[int, int], tuple] = {}
        # guards promote()/invalidate() against the resolve path; the fast
        # path itself stays lock-free (GIL-atomic dict probes).  _gen counts
        # swaps so a runner built from a pre-swap resolution is never cached
        # over a fresh promotion.
        self._swap_lock = threading.Lock()
        self._gen = 0

    def promote(self, key: str, cand: Candidate) -> None:
        """Atomically swap the winner for bucket ``key`` (background re-tune).

        Callers observe either the old winner or the new one, never a torn
        state: the memo entry and the fast-path table swap under one lock,
        and every variant is exact, so results are identical either way.
        """
        with self._swap_lock:
            self._gen += 1
            self._resolved[key] = (cand, "retune")
            self._fast.clear()
        self._obs.note_swap("tree", key)

    def invalidate(self) -> None:
        """Drop all resolution memos so the next call re-reads the cache."""
        with self._swap_lock:
            self._gen += 1
            self._resolved.clear()
            self._fast.clear()

    def _stamp_d_mu_provenance(self, key: str, entry: TuneEntry) -> None:
        """Re-store an autotuned cache entry with the profiler's measured d_µ
        (cache provenance: a later reader can see what traffic the winner
        was tuned under, and whether d_µ was measured or assumed)."""
        measured = self.profiler.d_mu(key) if self.profiler is not None else None
        if measured is not None:
            self.cache.store(
                key,
                dataclasses.replace(entry, d_mu=measured, d_mu_source="measured"),
            )

    def resolve(self, records) -> tuple[Candidate, str]:
        """Pick the candidate for this batch; returns (candidate, source)
        with source ∈ {"memo", "cache", "autotune", "heuristic"}."""
        shape = WorkloadShape.of(records, self.enc, self.depth)
        backend = backend_tag()
        key = shape.key(backend)
        hit = self._resolved.get(key)
        if hit is not None:
            self._obs.note_resolution("tree", "memo")
            return hit[0], "memo"

        entry = self.cache.lookup(key)
        source = "cache"
        if entry is not None and entry.variant in VARIANTS:
            cand = Candidate.make(entry.variant, **entry.params)
        elif self.autotune:
            with self._obs.tracer.span("tune.measure", cat="tune",
                                       level="tree", bucket=key):
                entry, _ = tune_workload(
                    records,
                    self.enc,
                    cache=self.cache,
                    engines=self.engines,
                    backend=backend,
                    registry=self._obs.registry,
                    **self.measure_kw,
                )
            cand = Candidate.make(entry.variant, **entry.params)
            source = "autotune"
            self._obs.note_agreement(
                "tree", cand,
                lambda: heuristic_candidate(
                    shape, engines=self.engines, **self.heuristic_kw),
            )
            self._stamp_d_mu_provenance(key, entry)
        else:
            kw = dict(self.heuristic_kw)
            d_mu_source = _resolve_d_mu(
                kw, profiler=self.profiler, key=key, measure=self.measure_d_mu,
                sample_fn=lambda: measured_d_mu(
                    self.enc, records, sample=self.d_mu_sample),
            )
            cand = heuristic_candidate(shape, engines=self.engines, **kw)
            source = "heuristic"
            self._obs.note_d_mu(
                "tree", d_mu_source, kw.get("d_mu", default_d_mu(shape)))
            if d_mu_source == "measured":
                prior_kw = dict(self.heuristic_kw)
                prior_kw.pop("d_mu", None)
                self._obs.note_d_mu_agreement(
                    "tree", cand,
                    lambda: heuristic_candidate(
                        shape, engines=self.engines, **prior_kw),
                )
        self._obs.note_resolution("tree", source)
        # setdefault under the lock: if a background promote() landed while
        # we resolved, its winner must not be overwritten with ours (and the
        # returned value is read inside the same critical section — a
        # concurrent invalidate() may clear the dict right after)
        with self._swap_lock:
            resolved = self._resolved.setdefault(key, (cand, source))
        return resolved[0], source

    def __call__(self, records) -> jax.Array:
        """Evaluate the tree over ``records`` (M, A) → (M,) int32 classes,
        through the bucket's resolved variant (bucket-padded, unpadded on
        return); bit-identical to ``eval_serial`` for every resolution."""
        if not (isinstance(records, jax.Array) and records.dtype == jnp.float32):
            records = jnp.asarray(records, jnp.float32)
        m, a = records.shape
        fast = self._fast.get((m, a))
        if fast is None:
            gen = self._gen
            cand, _ = self.resolve(records)
            spec = get_variant(cand.variant)
            bucket_m = WorkloadShape(m, self.enc.n_nodes, a, self.depth).bucket().m
            fast = (spec, cand.param_dict, bucket_m)
            with self._swap_lock:
                if gen == self._gen:   # don't cache a pre-swap resolution
                    self._fast[(m, a)] = fast
        spec, params, bucket_m = fast
        out = spec.fn(
            bucket_pad_records(records, bucket_m),
            self.enc,
            max_depth=self.depth,
            **params,
        )
        return out if out.shape[0] == m else out[:m]


def tuned_eval(
    records,
    tree: EncodedTree,
    *,
    cache: TuneCache | None = None,
    autotune: bool = False,
    engines: tuple[str, ...] | None = None,
) -> jax.Array:
    """Evaluate ``tree`` over ``records`` with the cached-best variant.

    One-shot convenience wrapper around :class:`TunedEvaluator`; returns the
    (M,) int32 class assignments, bit-identical to ``eval_serial``.
    """
    return TunedEvaluator(tree, cache=cache, autotune=autotune, engines=engines)(records)


# ---------------------------------------------------------------------------
# Forest-level dispatch
# ---------------------------------------------------------------------------


class ForestTunedEvaluator:
    """Reusable tuned dispatcher for one encoded *forest*.

    The forest analogue of :class:`TunedEvaluator`, and the single selection
    point every forest call routes through (``eval_forest_tuned``, the
    ``repro.dist`` executor, ``ForestServeEngine``).  Resolution order per
    (backend, forest-bucket):

      1. in-process memo,
      2. persistent cache (forest bucket keys, see
         :meth:`repro.tune.space.ForestShape.key`),
      3. optional on-miss autotune (``autotune=True`` — measures all three
         candidate families via :func:`repro.tune.measure.tune_forest_workload`),
      4. the §3.6-model family heuristic
         (:func:`repro.tune.heuristic.forest_heuristic_candidate`).

    The winning candidate is one of three families: ``per_tree`` dispatches
    each tree through its own :class:`TunedEvaluator` (the PR 3 path — a
    per-tree variant *vector*); ``vmap`` runs one shared variant stacked
    over the tree axis; ``fused`` launches the stacked Pallas kernel once
    for the whole forest.  All families are exact, so the choice never
    changes results — bit-identical to evaluating tree by tree.
    """

    def __init__(
        self,
        forest,
        *,
        cache: TuneCache | None = None,
        autotune: bool = False,
        engines: tuple[str, ...] | None = None,
        families: tuple[str, ...] | None = None,
        layouts: tuple[str, ...] | None = None,
        measure_kw: dict | None = None,
        measure_d_mu: bool = True,
        d_mu_sample: int = 256,
        heuristic_kw: dict | None = None,
        registry: obs.Registry | None = None,
        tracer: obs.Tracer | None = None,
        profiler=None,
    ):
        from repro.core.forest import EncodedForest  # local: core ↔ tune layering

        self.forest = forest if isinstance(forest, EncodedForest) else EncodedForest(list(forest))
        self.cache = cache if cache is not None else TuneCache()
        self.autotune = autotune
        self.engines = engines
        self._obs = _TuneObs(registry, tracer)
        # a TraversalProfiler keyed by this evaluator's forest-bucket keys:
        # measured d_µ and cascade survival replace the sample/prior fallbacks
        self.profiler = profiler
        self.families = families
        # node-table layout opt-in: None ≡ ("f32",) — quantized layouts only
        # compete (and quant cached winners are only honoured) when a caller
        # passes layouts including "quant".  All quant layouts dispatch may
        # build are universal-mode (exact for every input), so the opt-in is
        # about footprint/latency trade-offs, never about correctness.
        self.layouts = layouts
        self.measure_kw = dict(measure_kw or {})
        self.measure_d_mu = measure_d_mu
        self.d_mu_sample = d_mu_sample
        self.heuristic_kw = dict(heuristic_kw or {})
        from repro.core.tree import tree_depth as _td

        depths = [max(_td(self.forest.tree(i)), 1) for i in range(self.forest.n_trees)]
        self.depth_min = min(depths)
        self.depth_max = max(depths)
        self._resolved: dict[str, tuple[Candidate, str]] = {}
        self._fast: dict[tuple[int, int], object] = {}   # (M, A) → runner
        self._per_tree: list[TunedEvaluator] | None = None
        self._packed: PackedForest | None = None
        self._quant: QuantizedForest | None = None
        self._quant_key: tuple | None = None   # (n_attrs, thr_dtype)
        self._swap_lock = threading.Lock()
        self._gen = 0

    # -- re-tune hooks ------------------------------------------------------

    def promote(self, key: str, cand: Candidate) -> None:
        """Atomically swap the winner for forest bucket ``key``.

        See :meth:`TunedEvaluator.promote` — same contract: in-flight calls
        finish on the old winner, subsequent calls run the new one, results
        are bit-identical throughout.
        """
        with self._swap_lock:
            self._gen += 1
            self._resolved[key] = (cand, "retune")
            self._fast.clear()
        self._obs.note_swap("forest", key)

    def invalidate(self) -> None:
        """Drop all resolution memos so the next call re-reads the cache."""
        with self._swap_lock:
            self._gen += 1
            self._resolved.clear()
            self._fast.clear()

    def _family_allowed(self, variant: str) -> bool:
        """Whether a cached winner's family is within this evaluator's
        ``families`` restriction (a family-restricted evaluator must never
        run another family just because a sibling cached it)."""
        if self.families is None:
            return True
        if variant == PER_TREE_FAMILY:
            return PER_TREE_FAMILY in self.families
        return FOREST_VARIANTS[variant].family in self.families

    def _layout_allowed(self, variant: str) -> bool:
        """Whether a cached winner's node-table layout is within this
        evaluator's ``layouts`` restriction — a default (f32-only) evaluator
        must never run a quantized layout just because a layout-opted-in
        sibling cached it, and vice versa."""
        if variant == PER_TREE_FAMILY:
            layout = "f32"
        else:
            layout = getattr(FOREST_VARIANTS[variant], "layout", "f32")
        allowed = ("f32",) if self.layouts is None else self.layouts
        return layout in allowed

    def _stamp_d_mu_provenance(self, key: str, entry: TuneEntry) -> None:
        """See :meth:`TunedEvaluator._stamp_d_mu_provenance`."""
        measured = self.profiler.d_mu(key) if self.profiler is not None else None
        if measured is not None:
            self.cache.store(
                key,
                dataclasses.replace(entry, d_mu=measured, d_mu_source="measured"),
            )

    # -- resolution ---------------------------------------------------------

    def shape_of(self, records) -> ForestShape:
        """The :class:`ForestShape` of this batch (depths precomputed)."""
        return ForestShape.of(
            records, self.forest, depth_min=self.depth_min, depth_max=self.depth_max
        )

    def resolve(self, records) -> tuple[Candidate, str]:
        """Pick the forest candidate for this batch.

        Returns:
          (candidate, source) with source ∈ {"memo", "cache", "autotune",
          "heuristic"}; after a background re-tune the memo carries the
          promoted winner.
        """
        shape = self.shape_of(records)
        backend = backend_tag()
        key = shape.key(backend)
        hit = self._resolved.get(key)
        if hit is not None:
            self._obs.note_resolution("forest", "memo")
            return hit[0], "memo"

        entry = self.cache.lookup(key)
        source = "cache"
        if (
            entry is not None
            and (entry.variant in FOREST_VARIANTS or entry.variant == PER_TREE_FAMILY)
            and self._family_allowed(entry.variant)
            and self._layout_allowed(entry.variant)
        ):
            cand = Candidate.make(entry.variant, **entry.params)
        elif self.autotune:
            with self._obs.tracer.span("tune.measure", cat="tune",
                                       level="forest", bucket=key):
                entry, _ = tune_forest_workload(
                    records,
                    self.forest,
                    cache=self.cache,
                    engines=self.engines,
                    families=self.families,
                    layouts=self.layouts,
                    backend=backend,
                    autotune_trees=True,   # per-tree family priced at its tuned best
                    # a restricted (family- or layout-filtered) winner must
                    # not overwrite the bucket's unrestricted one
                    store=self.families is None and self.layouts is None,
                    registry=self._obs.registry,
                    **self.measure_kw,
                )
            cand = Candidate.make(entry.variant, **entry.params)
            source = "autotune"
            self._obs.note_agreement(
                "forest", cand,
                lambda: forest_heuristic_candidate(
                    shape, engines=self.engines, families=self.families,
                    **self.heuristic_kw),
            )
            self._stamp_d_mu_provenance(key, entry)
        else:
            kw = dict(self.heuristic_kw)
            d_mu_source = _resolve_d_mu(
                kw, profiler=self.profiler, key=key, measure=self.measure_d_mu,
                sample_fn=lambda: measured_forest_d_mu(
                    self.forest, records, sample=self.d_mu_sample),
            )
            cand = forest_heuristic_candidate(
                shape, engines=self.engines, families=self.families, **kw
            )
            source = "heuristic"
            self._obs.note_d_mu(
                "forest", d_mu_source,
                kw.get("d_mu", default_d_mu(shape.tree_shape())))
            if d_mu_source == "measured":
                prior_kw = dict(self.heuristic_kw)
                prior_kw.pop("d_mu", None)
                self._obs.note_d_mu_agreement(
                    "forest", cand,
                    lambda: forest_heuristic_candidate(
                        shape, engines=self.engines, families=self.families,
                        **prior_kw),
                )
        self._obs.note_resolution("forest", source)
        # same critical-section discipline as TunedEvaluator.resolve: don't
        # clobber a concurrent promote(), don't re-read after unlocking
        with self._swap_lock:
            resolved = self._resolved.setdefault(key, (cand, source))
        return resolved[0], source

    # -- evaluation ---------------------------------------------------------

    def _tree_evaluators(self) -> list[TunedEvaluator]:
        if self._per_tree is None:
            self._per_tree = [
                TunedEvaluator(
                    self.forest.tree(i), cache=self.cache, engines=self.engines,
                    autotune=self.autotune, measure_kw=self.measure_kw,
                    registry=self._obs.registry, tracer=self._obs.tracer,
                )
                for i in range(self.forest.n_trees)
            ]
        return self._per_tree

    def _runner(self, cand: Candidate, m: int, a: int):
        """Build the steady-state callable for one resolved candidate."""
        if cand.variant == PER_TREE_FAMILY:
            evs = self._tree_evaluators()
            return lambda rec: jnp.stack([ev(rec) for ev in evs])
        spec = get_forest_variant(cand.variant)
        params = cand.param_dict
        depth = max(int(self.forest.max_depth), 1)
        bucket_m = ForestShape(
            t=self.forest.n_trees, m=m, n_nodes=self.forest.n_nodes,
            n_attrs=a, depth_min=self.depth_min, depth_max=self.depth_max,
        ).bucket().m
        if getattr(spec, "layout", "f32") == "quant":
            # Universal-mode quantization (no calibration): bit-exact for
            # every input, so a quant winner never changes results.  The
            # threshold dtype is part of the pack, so the memo keys on it.
            qkey = (a, params.get("thr_dtype", "bfloat16"))
            if self._quant is None or self._quant_key != qkey:
                self._quant = QuantizedForest(self.forest, a, thr_dtype=qkey[1])
                self._quant_key = qkey
            target = self._quant
        elif spec.family == "fused":
            if self._packed is None or self._packed.n_attrs != a:
                self._packed = PackedForest(self.forest, a)
            target = self._packed
        else:
            target = self.forest

        def run(rec):
            out = spec.fn(bucket_pad_records(rec, bucket_m), target, max_depth=depth, **params)
            return out if out.shape[1] == m else out[:, :m]

        return run

    def __call__(self, records) -> jax.Array:
        """Per-tree class assignments, shape (T, M) int32."""
        if not (isinstance(records, jax.Array) and records.dtype == jnp.float32):
            records = jnp.asarray(records, jnp.float32)
        m, a = records.shape
        run = self._fast.get((m, a))
        if run is None:
            gen = self._gen
            cand, _ = self.resolve(records)
            run = self._runner(cand, m, a)
            with self._swap_lock:
                if gen == self._gen:   # don't cache a pre-swap resolution
                    self._fast[(m, a)] = run
        return run(records)

    # -- class-level dispatch (majority vote vs early-exit cascade) ---------

    def resolve_classes(self, records, n_classes: int) -> tuple[Candidate, str]:
        """Pick the class-level candidate for this batch.

        Same resolution ladder as :meth:`resolve`, but over the *class*
        question — "which class wins the vote?" — whose candidate set is
        the full majority-vote path (``Candidate(MAJORITY_FAMILY)``) plus
        the early-exit cascades.  Keys carry the class count
        (:meth:`ForestShape.classes_key`), and the heuristic extends the
        §3.6 model with a survival-rate term measured on this batch.  Every
        candidate is exact at bound 1.0, so resolution never changes the
        predicted classes.
        """
        shape = self.shape_of(records)
        backend = backend_tag()
        key = shape.classes_key(n_classes, backend)
        hit = self._resolved.get(key)
        if hit is not None:
            self._obs.note_resolution("classes", "memo")
            return hit[0], "memo"

        entry = self.cache.lookup(key)
        source = "cache"
        if entry is not None and (
            entry.variant == MAJORITY_FAMILY or entry.variant in CASCADE_VARIANTS
        ):
            cand = Candidate.make(entry.variant, **entry.params)
        elif self.autotune:
            with self._obs.tracer.span("tune.measure", cat="tune",
                                       level="classes", bucket=key):
                entry, _ = tune_cascade_workload(
                    records,
                    self.forest,
                    n_classes,
                    cache=self.cache,
                    engines=self.engines,
                    backend=backend,
                    registry=self._obs.registry,
                    **self.measure_kw,
                )
            cand = Candidate.make(entry.variant, **entry.params)
            source = "autotune"
        else:
            kw = dict(self.heuristic_kw)
            # profiler measurements are keyed by the forest bucket (the
            # engine's wave key), not the |C-suffixed class key
            forest_key = shape.key(backend)
            d_mu_source = _resolve_d_mu(
                kw, profiler=self.profiler, key=forest_key,
                measure=self.measure_d_mu,
                sample_fn=lambda: measured_forest_d_mu(
                    self.forest, records, sample=self.d_mu_sample),
            )
            survival = kw.pop("survival", None)
            survival_source = "caller"
            if survival is None and self.profiler is not None:
                measured = self.profiler.survival(forest_key)
                if measured is not None:
                    # the profiler reports the mean per-stage survival rate;
                    # expand it geometrically over the deepest stage grid the
                    # heuristic may price (surv_s = rate^s, surv_0 = 1)
                    survival = tuple(
                        min(1.0, float(measured)) ** s for s in range(8))
                    survival_source = "measured"
            if survival is None:
                survival = measured_survival_rate(
                    self.forest, records, n_classes, sample=self.d_mu_sample
                )
                survival_source = "sampled"
            cand = cascade_heuristic_candidate(
                shape, n_classes, survival=survival, engines=self.engines, **kw
            )
            source = "heuristic"
            self._obs.note_d_mu(
                "classes", d_mu_source,
                kw.get("d_mu", default_d_mu(shape.tree_shape())))
            self._obs.survival_provenance.labels(source=survival_source).inc()
        self._obs.note_resolution("classes", source)
        with self._swap_lock:
            resolved = self._resolved.setdefault(key, (cand, source))
        return resolved[0], source

    def _class_runner(self, cand: Candidate, n_classes: int, records):
        """Build the steady-state classes callable for one resolution."""
        from repro.core.forest import majority_vote  # local: core ↔ tune layering

        if cand.variant == MAJORITY_FAMILY:
            return lambda rec: majority_vote(self(rec), n_classes)
        import numpy as np

        spec = get_cascade_variant(cand.variant)
        params = cand.param_dict
        # the evaluator is stateful (packed stage tables, latency EMAs):
        # build once per resolved bucket, calibrate the plan on this batch
        ev = spec.build(
            self.forest,
            n_classes=n_classes,
            stages=int(params.get("stages", 2)),
            bound=1.0,
            block_m=params.get("block_m"),
            calibration=records,
            registry=self._obs.registry,
            tracer=self._obs.tracer,
        )

        def run(rec):
            return jnp.asarray(ev(np.asarray(rec)).classes)

        run.cascade = ev  # exposed for introspection / serve-engine stats
        return run

    def predict(self, records, n_classes: int) -> jax.Array:
        """Majority-vote classes, shape (M,) int32, via class-level dispatch.

        Either the full forest path (``majority_vote`` over
        :meth:`__call__`) or a calibrated early-exit cascade — whichever the
        resolution picked.  Both are exact, so the output always equals
        ``majority_vote(self(records), n_classes)``.
        """
        if not (isinstance(records, jax.Array) and records.dtype == jnp.float32):
            records = jnp.asarray(records, jnp.float32)
        m, a = records.shape
        key = ("cls", m, a, int(n_classes))
        run = self._fast.get(key)
        if run is None:
            gen = self._gen
            cand, _ = self.resolve_classes(records, n_classes)
            run = self._class_runner(cand, n_classes, records)
            with self._swap_lock:
                if gen == self._gen:   # don't cache a pre-swap resolution
                    self._fast[key] = run
        return run(records)


def tuned_eval_forest(
    records,
    forest,
    *,
    cache: TuneCache | None = None,
    autotune: bool = False,
    engines: tuple[str, ...] | None = None,
) -> jax.Array:
    """Evaluate ``forest`` over ``records`` with the cached-best family.

    One-shot convenience wrapper around :class:`ForestTunedEvaluator`;
    returns the (T, M) int32 per-tree class assignments, bit-identical to
    evaluating each tree with ``eval_serial``.
    """
    return ForestTunedEvaluator(
        forest, cache=cache, autotune=autotune, engines=engines
    )(records)
