"""Transparent variant dispatch: ``tuned_eval(records, tree)``.

Resolution order for each (backend, shape-bucket):

  1. in-process memo (one dict probe on the hot path),
  2. persistent cache (:class:`repro.tune.cache.TuneCache`),
  3. optional on-miss autotune (``autotune=True`` — measures the search
     space once and persists the winner),
  4. the §3.6-model heuristic (:mod:`repro.tune.heuristic`).

Dispatch zero-pads the record batch up to the bucket's M before running the
variant and slices the padding back off, so every call inside a bucket hits
one jit specialisation and the timings stored by the tuner stay honest.
All variants are exact (bit-identical to the serial reference), so dispatch
never changes results — only which kernel produces them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tree import EncodedTree, tree_depth
from repro.kernels.tree_eval.ops import VARIANTS, get_variant
from repro.tune.cache import TuneCache, TuneEntry
from repro.tune.heuristic import heuristic_candidate, measured_d_mu
from repro.tune.measure import bucket_pad_records, tune_workload
from repro.tune.space import Candidate, WorkloadShape, backend_tag


class TunedEvaluator:
    """Reusable tuned dispatcher for one encoded tree.

    Prefer this over the functional :func:`tuned_eval` on hot paths (serving,
    forests): it owns the depth computation, the cache handle, and a
    per-bucket resolution memo, so steady-state calls do no lookup work.
    """

    def __init__(
        self,
        enc: EncodedTree,
        *,
        cache: TuneCache | None = None,
        autotune: bool = False,
        engines: tuple[str, ...] | None = None,
        measure_kw: dict | None = None,
        measure_d_mu: bool = True,
        d_mu_sample: int = 256,
        heuristic_kw: dict | None = None,
    ):
        self.enc = enc
        self.cache = cache if cache is not None else TuneCache()
        self.autotune = autotune
        self.engines = engines
        self.measure_kw = dict(measure_kw or {})
        # heuristic fallback: measure d_µ on a sample of the actual batch
        # (paper: "measured on a significant sample") instead of trusting
        # the geometry prior; heuristic_kw forwards cm/p_group overrides.
        self.measure_d_mu = measure_d_mu
        self.d_mu_sample = d_mu_sample
        self.heuristic_kw = dict(heuristic_kw or {})
        self.depth = max(tree_depth(enc), 1)
        self._resolved: dict[str, tuple[Candidate, str]] = {}
        # (M, A) → (spec, params, bucket_m): the steady-state call path does
        # one dict probe and zero array ops beyond the kernel itself.
        self._fast: dict[tuple[int, int], tuple] = {}

    def resolve(self, records) -> tuple[Candidate, str]:
        """Pick the candidate for this batch; returns (candidate, source)
        with source ∈ {"memo", "cache", "autotune", "heuristic"}."""
        shape = WorkloadShape.of(records, self.enc, self.depth)
        backend = backend_tag()
        key = shape.key(backend)
        hit = self._resolved.get(key)
        if hit is not None:
            return hit[0], "memo"

        entry = self.cache.lookup(key)
        source = "cache"
        if entry is not None and entry.variant in VARIANTS:
            cand = Candidate.make(entry.variant, **entry.params)
        elif self.autotune:
            entry, _ = tune_workload(
                records,
                self.enc,
                cache=self.cache,
                engines=self.engines,
                backend=backend,
                **self.measure_kw,
            )
            cand = Candidate.make(entry.variant, **entry.params)
            source = "autotune"
        else:
            kw = dict(self.heuristic_kw)
            if self.measure_d_mu and "d_mu" not in kw:
                kw["d_mu"] = measured_d_mu(self.enc, records, sample=self.d_mu_sample)
            cand = heuristic_candidate(shape, engines=self.engines, **kw)
            source = "heuristic"
        self._resolved[key] = (cand, source)
        return cand, source

    def __call__(self, records) -> jax.Array:
        if not (isinstance(records, jax.Array) and records.dtype == jnp.float32):
            records = jnp.asarray(records, jnp.float32)
        m, a = records.shape
        fast = self._fast.get((m, a))
        if fast is None:
            cand, _ = self.resolve(records)
            spec = get_variant(cand.variant)
            bucket_m = WorkloadShape(m, self.enc.n_nodes, a, self.depth).bucket().m
            fast = (spec, cand.param_dict, bucket_m)
            self._fast[(m, a)] = fast
        spec, params, bucket_m = fast
        out = spec.fn(
            bucket_pad_records(records, bucket_m),
            self.enc,
            max_depth=self.depth,
            **params,
        )
        return out if out.shape[0] == m else out[:m]


def tuned_eval(
    records,
    tree: EncodedTree,
    *,
    cache: TuneCache | None = None,
    autotune: bool = False,
    engines: tuple[str, ...] | None = None,
) -> jax.Array:
    """Evaluate ``tree`` over ``records`` with the cached-best variant.

    One-shot convenience wrapper around :class:`TunedEvaluator`; returns the
    (M,) int32 class assignments, bit-identical to ``eval_serial``.
    """
    return TunedEvaluator(tree, cache=cache, autotune=autotune, engines=engines)(records)
