"""Persistent best-variant cache: JSON on disk, LRU dict in front.

One JSON file holds every tuning result this fleet has produced, keyed by
``backend:kind:xN|M…|N…|A…|d…`` bucket strings (see
:meth:`repro.tune.space.WorkloadShape.key`).  Lookups go through a bounded
in-process LRU so the hot dispatch path never touches the filesystem;
writes go straight through to disk (atomic rename) so concurrent processes
at worst lose a race, never corrupt the file.

Staleness: the file carries a fingerprint of the kernel variant registry
(variant names + metadata + function sources, :func:`registry_fingerprint`).
A kernel rewrite changes the fingerprint, so every stored winner — timings of
code that no longer exists — is discarded on load and the affected buckets
re-tune on next sight instead of replaying a stale decision.

Default location: ``$REPRO_TUNE_CACHE`` or ``~/.cache/repro_tune/cache.json``.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import tempfile
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Optional

CACHE_ENV = "REPRO_TUNE_CACHE"
CACHE_VERSION = 4  # v4: quantized node-table layouts join the registry


@functools.lru_cache(maxsize=1)
def registry_fingerprint() -> str:
    """Hash of the kernel variant registry: names, metadata, sources.

    Any change to a variant's implementation (or to the shared kernel/ops
    modules they lower through) must invalidate stored winners, since the
    cached medians priced code that no longer runs.
    """
    import hashlib
    import inspect

    from repro.core import eval_dataparallel as _dp
    from repro.core import eval_speculative as _spec
    from repro.kernels.tree_eval import cascade as _cascade
    from repro.kernels.tree_eval import kernel as _kernel
    from repro.kernels.tree_eval import ops as _ops
    from repro.kernels.tree_eval import quant as _quant

    h = hashlib.sha256()
    registries = [
        ("tree", _ops.VARIANTS),
        ("forest", _ops.FOREST_VARIANTS),
        ("cascade", _cascade.CASCADE_VARIANTS),
    ]
    for tag, registry in registries:
        for name in sorted(registry):
            spec = registry[name]
            h.update(f"{tag}:{name}".encode())
            h.update(
                f"|{spec.algorithm}|{spec.engine}|{spec.jump_mode}|{spec.tunables}".encode()
            )
            h.update(f"|{getattr(spec, 'family', '')}".encode())
            h.update(f"|{getattr(spec, 'layout', '')}".encode())
            fn = getattr(spec, "fn", None) or getattr(spec, "build", None)
            try:
                h.update(inspect.getsource(fn).encode())
            except (OSError, TypeError):
                h.update(repr(fn).encode())
    # the registered fns are thin wrappers: hash the modules the variants
    # actually lower through (Pallas kernels + the jnp evaluators)
    for mod in (_ops, _kernel, _cascade, _spec, _dp, _quant):
        try:
            h.update(inspect.getsource(mod).encode())
        except (OSError, TypeError):
            pass
    return h.hexdigest()[:16]


def default_cache_path() -> Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env).expanduser()
    return Path("~/.cache/repro_tune/cache.json").expanduser()


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    """The winning candidate for one shape bucket."""

    variant: str
    params: dict
    median_ms: float
    # provenance, for reports / staleness checks
    shape: dict | None = None
    backend: str = ""
    # d_µ the resolution saw, and where it came from ("measured" = traversal
    # profiler, "sampled" = host descent on the batch, "prior" = geometry,
    # "caller" = heuristic_kw override, "" = unrecorded pre-profiler entry)
    d_mu: float | None = None
    d_mu_source: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TuneEntry":
        return cls(
            variant=str(d["variant"]),
            params=dict(d.get("params", {})),
            median_ms=float(d.get("median_ms", 0.0)),
            shape=d.get("shape"),
            backend=str(d.get("backend", "")),
            d_mu=(None if d.get("d_mu") is None else float(d["d_mu"])),
            d_mu_source=str(d.get("d_mu_source", "")),
        )


class TuneCache:
    """JSON-backed best-variant store with a bounded LRU front.

    The LRU only caches *hits*; misses always re-check the loaded table so a
    concurrent tuner's writes show up after :meth:`reload`.  In-process
    state is guarded by a lock: the serve engines' background re-tune
    stores winners from a worker thread while the request path looks up.
    """

    def __init__(
        self,
        path: os.PathLike | str | None = None,
        *,
        lru_size: int = 128,
        registry: str | None = None,
    ):
        self.path = Path(path) if path is not None else default_cache_path()
        self.lru_size = lru_size
        # injectable for tests; None = fingerprint of the live registry
        self._registry = registry
        self._lru: OrderedDict[str, TuneEntry] = OrderedDict()
        self._table: dict[str, dict] = {}
        self._lock = threading.Lock()      # in-memory state (lookup hot path)
        self._io_lock = threading.Lock()   # file writes — never held with _lock
        self._seq = 0                      # snapshot order, so a slow writer
        self._written_seq = 0              # can't clobber a newer flush
        self.reload()

    @property
    def registry(self) -> str:
        return self._registry if self._registry is not None else registry_fingerprint()

    # -- persistence --------------------------------------------------------

    def reload(self) -> None:
        """(Re)read the on-disk table; tolerates a missing/corrupt file.

        Entries written under a different schema version or a different
        kernel-registry fingerprint are discarded wholesale: a stale winner
        names timings of code that no longer exists, so re-tuning is the
        only honest recovery.
        """
        table = {}
        try:
            raw = json.loads(self.path.read_text())
            if (
                isinstance(raw, dict)
                and raw.get("version") == CACHE_VERSION
                and raw.get("registry") == self.registry
            ):
                table = dict(raw.get("entries", {}))
        except (OSError, ValueError):
            pass
        with self._lock:
            self._table = table
            self._lru.clear()

    def _flush(self, payload: dict, seq: int) -> None:
        """Write a table snapshot (atomic rename), skipping stale snapshots.

        Runs *outside* ``_lock`` so lookups on the serving request path
        never block on disk I/O; ``_io_lock`` + the sequence number keep a
        slow writer from replacing a newer snapshot with an older one.
        """
        with self._io_lock:
            if seq <= self._written_seq:
                return
            self._written_seq = seq
            self.path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.path.parent, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

    # -- access -------------------------------------------------------------

    def lookup(self, key: str) -> Optional[TuneEntry]:
        with self._lock:
            hit = self._lru.get(key)
            if hit is not None:
                self._lru.move_to_end(key)
                return hit
            raw = self._table.get(key)
            if raw is None:
                return None
            entry = TuneEntry.from_json(raw)
            self._lru[key] = entry
            if len(self._lru) > self.lru_size:
                self._lru.popitem(last=False)
            return entry

    def store(self, key: str, entry: TuneEntry) -> None:
        with self._lock:
            self._table[key] = entry.to_json()
            self._lru[key] = entry
            self._lru.move_to_end(key)
            if len(self._lru) > self.lru_size:
                self._lru.popitem(last=False)
            self._seq += 1
            seq = self._seq
            payload = {
                "version": CACHE_VERSION,
                "registry": self.registry,
                "entries": dict(self._table),
            }
        self._flush(payload, seq)

    def __len__(self) -> int:
        with self._lock:
            return len(self._table)

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._table)
