"""Persistent best-variant cache: JSON on disk, LRU dict in front.

One JSON file holds every tuning result this machine has produced, keyed by
``backend|M…|N…|A…|d…`` bucket strings (see
:meth:`repro.tune.space.WorkloadShape.key`).  Lookups go through a bounded
in-process LRU so the hot dispatch path never touches the filesystem;
writes go straight through to disk (atomic rename) so concurrent processes
at worst lose a race, never corrupt the file.

Default location: ``$REPRO_TUNE_CACHE`` or ``~/.cache/repro_tune/cache.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from collections import OrderedDict
from pathlib import Path
from typing import Optional

CACHE_ENV = "REPRO_TUNE_CACHE"
CACHE_VERSION = 1


def default_cache_path() -> Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env).expanduser()
    return Path("~/.cache/repro_tune/cache.json").expanduser()


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    """The winning candidate for one shape bucket."""

    variant: str
    params: dict
    median_ms: float
    # provenance, for reports / staleness checks
    shape: dict | None = None
    backend: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TuneEntry":
        return cls(
            variant=str(d["variant"]),
            params=dict(d.get("params", {})),
            median_ms=float(d.get("median_ms", 0.0)),
            shape=d.get("shape"),
            backend=str(d.get("backend", "")),
        )


class TuneCache:
    """JSON-backed best-variant store with a bounded LRU front.

    The LRU only caches *hits*; misses always re-check the loaded table so a
    concurrent tuner's writes show up after :meth:`reload`.
    """

    def __init__(self, path: os.PathLike | str | None = None, *, lru_size: int = 128):
        self.path = Path(path) if path is not None else default_cache_path()
        self.lru_size = lru_size
        self._lru: OrderedDict[str, TuneEntry] = OrderedDict()
        self._table: dict[str, dict] = {}
        self.reload()

    # -- persistence --------------------------------------------------------

    def reload(self) -> None:
        """(Re)read the on-disk table; tolerates a missing/corrupt file."""
        self._table = {}
        try:
            raw = json.loads(self.path.read_text())
            if isinstance(raw, dict) and raw.get("version") == CACHE_VERSION:
                self._table = dict(raw.get("entries", {}))
        except (OSError, ValueError):
            pass
        self._lru.clear()

    def _flush(self) -> None:
        payload = {"version": CACHE_VERSION, "entries": self._table}
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- access -------------------------------------------------------------

    def lookup(self, key: str) -> Optional[TuneEntry]:
        hit = self._lru.get(key)
        if hit is not None:
            self._lru.move_to_end(key)
            return hit
        raw = self._table.get(key)
        if raw is None:
            return None
        entry = TuneEntry.from_json(raw)
        self._lru[key] = entry
        if len(self._lru) > self.lru_size:
            self._lru.popitem(last=False)
        return entry

    def store(self, key: str, entry: TuneEntry) -> None:
        self._table[key] = entry.to_json()
        self._lru[key] = entry
        self._lru.move_to_end(key)
        if len(self._lru) > self.lru_size:
            self._lru.popitem(last=False)
        self._flush()

    def __len__(self) -> int:
        return len(self._table)

    def keys(self) -> list[str]:
        return sorted(self._table)
