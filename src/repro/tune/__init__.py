"""repro.tune — autotuning & variant dispatch for the tree-eval kernels.

The paper's result is an *operating-point* result: speculative evaluation
(Procedure 5) beats data decomposition (Procedure 3) only where its runtime
model says it should.  §4's analysis writes both runtimes over the workload
shape — record count M, tree nodes N (p processors per record group), mean
traversal depth d_µ — and equation (1) gives the crossover
``p < 2·d_µ/(1 + log₂ d_µ)``.  This package operationalises that analysis:
instead of hardcoding one evaluator per call site, callers say
``tuned_eval(records, tree)`` and the subsystem picks the variant that wins
*at this shape on this backend*.

Tuning happens at two granularities:

  * **tree** — :class:`TunedEvaluator` / :func:`tuned_eval` pick one kernel
    variant per (backend, shape-bucket);
  * **forest** — :class:`ForestTunedEvaluator` / :func:`tuned_eval_forest`
    pick a *family* per (backend, forest-bucket): per-tree variant vectors,
    a shared-variant vmap path, or the fused stacked Pallas kernel that
    evaluates the whole forest in one launch.

Module map (→ paper concept):

  space.py      the workload shape (M, N, A, d) the §4 model is written
                over, plus the forest shape (T, M, N_max, A, depth profile);
                shape bucketing; enumeration of valid (variant, parameter)
                candidates from the kernel registries.
  measure.py    the paper's measurement discipline (warmup, synchronised
                timing, medians over repeats) applied to each candidate —
                per-tree and forest-level.
  cache.py      persistent JSON store of per-(backend, shape-bucket)
                winners with an in-process LRU front.
  heuristic.py  the §4 closed forms (T₃ vs T₅, equation (1) crossover) as
                the no-cache fallback policy, lifted to the family choice
                for forests (launch savings vs depth-padding waste).
  dispatch.py   ``tuned_eval`` / ``TunedEvaluator`` and
                ``tuned_eval_forest`` / ``ForestTunedEvaluator``: memo →
                cache → optional autotune → heuristic, with bucket-padded
                batches and atomic ``promote``/``invalidate`` re-tune hooks.

Every variant is exact, so tuning is purely a performance decision: results
are bit-identical to the serial branchless reference (Procedure 2).
"""

from repro.tune.cache import TuneCache, TuneEntry, default_cache_path, registry_fingerprint
from repro.tune.dispatch import (
    ForestTunedEvaluator,
    TunedEvaluator,
    tuned_eval,
    tuned_eval_forest,
)
from repro.tune.heuristic import (
    cascade_heuristic_candidate,
    default_survival,
    forest_heuristic_candidate,
    heuristic_candidate,
    measured_d_mu,
    measured_forest_d_mu,
    measured_survival_rate,
    predicted_times,
)
from repro.tune.measure import (
    Measurement,
    measure_candidate,
    measure_cascade_candidate,
    measure_forest_candidate,
    time_callable,
    tune_cascade_workload,
    tune_forest_workload,
    tune_workload,
)
from repro.tune.space import (
    Candidate,
    ForestShape,
    WorkloadShape,
    backend_tag,
    cascade_search_space,
    cascade_stage_grid,
    forest_search_space,
    search_space,
)

__all__ = [
    "Candidate",
    "ForestShape",
    "ForestTunedEvaluator",
    "Measurement",
    "TuneCache",
    "TuneEntry",
    "TunedEvaluator",
    "WorkloadShape",
    "backend_tag",
    "cascade_heuristic_candidate",
    "cascade_search_space",
    "cascade_stage_grid",
    "default_cache_path",
    "default_survival",
    "forest_heuristic_candidate",
    "forest_search_space",
    "heuristic_candidate",
    "measure_candidate",
    "measure_cascade_candidate",
    "measure_forest_candidate",
    "measured_d_mu",
    "measured_forest_d_mu",
    "measured_survival_rate",
    "predicted_times",
    "registry_fingerprint",
    "search_space",
    "time_callable",
    "tune_cascade_workload",
    "tune_forest_workload",
    "tune_workload",
    "tuned_eval",
    "tuned_eval_forest",
]
