"""Synthetic twin of the UCI Image Segmentation dataset (paper §4.1).

The offline container cannot download UCI, so we generate a statistically
matched stand-in with identical shapes and cardinalities: 19 continuous
attributes, 7 classes, 2310 training + 2099 test records.  Classes are
class-conditional Gaussian mixtures over correlated attribute groups (the
real set's attributes are pixel-window statistics, strongly correlated within
groups), which yields CART trees of the same geometry class as the paper's
(N ≈ 31 nodes, depth ≈ 11 with default CartConfig).

``replicated_dataset`` reproduces the paper's timing workload: the combined
train+test table randomized and tiled out to 65 536 records (a 256×256
"image").
"""

from __future__ import annotations

import dataclasses

import numpy as np

N_ATTRS = 19
N_CLASSES = 7
N_TRAIN = 2310
N_TEST = 2099


@dataclasses.dataclass(frozen=True)
class SegmentationData:
    x_train: np.ndarray   # (2310, 19) float32
    y_train: np.ndarray   # (2310,) int32
    x_test: np.ndarray    # (2099, 19) float32
    y_test: np.ndarray    # (2099,) int32


def make_segmentation(seed: int = 0) -> SegmentationData:
    rng = np.random.default_rng(seed)
    # class-conditional structure: 5 correlated attribute groups
    groups = [slice(0, 4), slice(4, 8), slice(8, 12), slice(12, 16), slice(16, 19)]
    total = N_TRAIN + N_TEST
    per = np.full((N_CLASSES,), total // N_CLASSES)
    per[: total % N_CLASSES] += 1
    xs, ys = [], []
    for c in range(N_CLASSES):
        n = per[c]
        x = np.zeros((n, N_ATTRS))
        for g in groups:
            width = g.stop - g.start
            mean = rng.normal(0, 2.0, size=(width,))
            base = rng.normal(size=(n, 1))
            x[:, g] = mean + base + 0.6 * rng.normal(size=(n, width))
        xs.append(x)
        ys.append(np.full((n,), c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    perm = rng.permutation(total)
    x, y = x[perm], y[perm]
    return SegmentationData(
        x_train=x[:N_TRAIN], y_train=y[:N_TRAIN],
        x_test=x[N_TRAIN:], y_test=y[N_TRAIN:],
    )


def replicated_dataset(data: SegmentationData, n_records: int = 65_536, seed: int = 1):
    """Paper §4.1: combine train+test, randomize repeatedly, tile to 65 536."""
    rng = np.random.default_rng(seed)
    x = np.concatenate([data.x_train, data.x_test])
    y = np.concatenate([data.y_train, data.y_test])
    out_x = np.empty((n_records, N_ATTRS), np.float32)
    out_y = np.empty((n_records,), np.int32)
    filled = 0
    while filled < n_records:
        perm = rng.permutation(x.shape[0])
        take = min(x.shape[0], n_records - filled)
        out_x[filled:filled + take] = x[perm[:take]]
        out_y[filled:filled + take] = y[perm[:take]]
        filled += take
    return out_x, out_y
