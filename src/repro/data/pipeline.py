"""Deterministic sharded synthetic LM data pipeline.

Every batch is a pure function of ``(seed, step)`` — the property that makes
checkpoint/restart replay exact and lets any host regenerate any shard
without coordination (the scalable analogue of a deterministic tf.data
pipeline keyed by step).

Tokens follow a Zipfian unigram draw with a short Markov mixing term so the
loss actually decreases during the example runs (pure-uniform tokens give a
flat loss).  ``labels`` are next-token targets with the final position
masked (-1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 0      # >0 adds deterministic structure for learnability


class SyntheticLM:
    """Callable pipeline: ``pipeline(step) -> {"tokens", "labels"}``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf over an effective vocab (cap avoids numerical tail issues)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.probs = p / p.sum()

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, 0xD5EC])
        )

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        tok = rng.choice(cfg.vocab_size, size=(cfg.global_batch, cfg.seq_len + 1),
                         p=self.probs).astype(np.int32)
        if cfg.markov_order > 0:
            # deterministic mixing: token_t depends on token_{t-1} half the time
            mix = rng.random((cfg.global_batch, cfg.seq_len + 1)) < 0.5
            shifted = np.roll((tok * 7 + 3) % cfg.vocab_size, 1, axis=1)
            tok = np.where(mix, shifted, tok).astype(np.int32)
        tokens = tok[:, :-1]
        labels = tok[:, 1:].copy()
        labels[:, -1] = -1
        return {"tokens": tokens, "labels": labels}

    def __call__(self, step: int) -> dict:
        return self.batch(step)

    def shard(self, step: int, rank: int, world: int) -> dict:
        """Per-host slice of the global batch (layout identical on any host)."""
        b = self.batch(step)
        n = self.cfg.global_batch
        assert n % world == 0, (n, world)
        k = n // world
        return {k2: v[rank * k:(rank + 1) * k] for k2, v in b.items()}


class SyntheticEncDec(SyntheticLM):
    """Adds precomputed encoder frame embeddings (the audio-frontend stub)."""

    def __init__(self, cfg: DataConfig, n_frames: int, d_model: int):
        super().__init__(cfg)
        self.n_frames = n_frames
        self.d_model = d_model

    def batch(self, step: int) -> dict:
        out = super().batch(step)
        rng = self._rng(step + 1_000_003)
        out["embeds"] = rng.standard_normal(
            (self.cfg.global_batch, self.n_frames, self.d_model)
        ).astype(np.float32) * 0.02
        return out


class SyntheticVLM(SyntheticLM):
    """Precomputed patch/text embeddings + (B, 3, S) M-RoPE position streams."""

    def __init__(self, cfg: DataConfig, d_model: int):
        super().__init__(cfg)
        self.d_model = d_model

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        out = super().batch(step)
        rng = self._rng(step + 2_000_003)
        out["embeds"] = rng.standard_normal(
            (cfg.global_batch, cfg.seq_len, self.d_model)
        ).astype(np.float32) * 0.02
        pos = np.broadcast_to(
            np.arange(cfg.seq_len, dtype=np.int32)[None, None, :],
            (cfg.global_batch, 3, cfg.seq_len),
        ).copy()
        out["positions"] = pos
        del out["tokens"]
        return out


def pipeline_for(cfg_model, shape, *, seed: int = 0, markov: bool = True):
    """Pick the right pipeline family for an arch."""
    dcfg = DataConfig(
        vocab_size=cfg_model.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        markov_order=1 if markov else 0,
    )
    if cfg_model.family == "audio":
        return SyntheticEncDec(dcfg, cfg_model.encoder.n_frames, cfg_model.d_model)
    if cfg_model.embeds_input:
        return SyntheticVLM(dcfg, cfg_model.d_model)
    return SyntheticLM(dcfg)
