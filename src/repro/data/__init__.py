from repro.data.pipeline import (
    DataConfig, SyntheticEncDec, SyntheticLM, SyntheticVLM, pipeline_for,
)
from repro.data.segmentation import SegmentationData, make_segmentation, replicated_dataset
