from repro.optim.adamw import (
    AdamWState, adamw_apply, adamw_init, adamw_state_shapes, adamw_state_specs,
    clip_by_global_norm, global_norm, lr_at,
)
