"""AdamW with ZeRO-1 sharded state, global-norm clipping and LR schedules.

Pure-function optimizer (no optax dependency in this container):

    state = adamw_init(params)
    new_params, new_state, stats = adamw_apply(params, grads, state, cfg, step)

State sharding: ``m``/``v`` follow each parameter's PartitionSpec, then any
still-unsharded dim is additionally sliced over the 'data' axis
(``sharding.zero1_spec``) — classic optimizer-state sharding so 70 B-param
archs keep Adam moments under HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.parallel import sharding as shd


class AdamWState(NamedTuple):
    m: Any           # pytree like params
    v: Any
    count: jax.Array # scalar int32


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros, v=jax.tree.map(jnp.copy, zeros), count=jnp.zeros((), jnp.int32))


def adamw_state_shapes(param_shapes) -> AdamWState:
    zl = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_shapes)
    return AdamWState(m=zl, v=zl, count=jax.ShapeDtypeStruct((), jnp.int32))


def adamw_state_specs(param_specs, param_shapes, axes: shd.MeshAxes, *, zero1: bool = True) -> AdamWState:
    """m/v follow the param spec, plus a ZeRO-1 'data' slice when enabled."""
    if zero1:
        spec_tree = jax.tree.map(
            lambda sp, sh: shd.zero1_spec(sp, sh.shape, axes),
            param_specs,
            param_shapes,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
        )
    else:
        spec_tree = param_specs
    from jax.sharding import PartitionSpec as P

    return AdamWState(m=spec_tree, v=jax.tree.map(lambda s: s, spec_tree,
                      is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)),
                      count=P())


def lr_at(cfg: TrainConfig, step) -> jax.Array:
    """Linear warmup → cosine decay to 10%."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_apply(params, grads, state: AdamWState, cfg: TrainConfig, *, decay_mask=None):
    """One AdamW step.  ``decay_mask`` (pytree of bool) selects weight-decayed
    leaves; default = every tensor with ndim ≥ 2 (norm scales & biases skip)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    cf = count.astype(jnp.float32)
    lr = lr_at(cfg, count)
    b1c = 1.0 - cfg.b1 ** cf
    b2c = 1.0 - cfg.b2 ** cf

    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    def upd(p, g, m, v, wd):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + 1e-8)
        if wd:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_d = jax.tree.leaves(decay_mask)
    outs = [upd(p, g, m, v, wd) for p, g, m, v, wd in zip(flat_p, flat_g, flat_m, flat_v, flat_d)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(m=new_m, v=new_v, count=count), stats
