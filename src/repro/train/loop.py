"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog,
elastic re-mesh.

Designed for thousands of nodes, validated in-container on one:

  * **checkpoint/restart** — async atomic checkpoints every ``ckpt_every``
    steps; on any step failure the loop restores the latest checkpoint and
    replays (at-least-once step semantics; data pipeline is keyed by step so
    replays are deterministic).
  * **straggler mitigation** — an EWMA step-time watchdog flags steps slower
    than ``straggler_factor``× the running median; the hook receives the
    event so a cluster controller can evict/re-shard (in-container we log and
    count).  This is the launch-layer analogue of the paper's observation
    that asymmetric per-processor work leaves "lucky" processors idle.
  * **elastic scaling** — ``resize_mesh`` restores the newest checkpoint onto
    a different mesh (device_put with the new NamedShardings); the loop can
    be re-entered with the new step function.
  * **simulated failures** — ``failure_injector`` lets tests kill arbitrary
    steps to exercise the restart path.
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from typing import Any, Callable, Iterator, Optional

import jax

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import TrainConfig

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopState:
    params: Any
    opt_state: Any
    step: int = 0


@dataclasses.dataclass
class LoopReport:
    final_step: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: list = dataclasses.field(default_factory=list)
    step_times: list = dataclasses.field(default_factory=list)


class StragglerWatchdog:
    """Flags steps slower than ``factor`` × running median of recent steps."""

    def __init__(self, factor: float = 3.0, window: int = 32, warmup: int = 3):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.times: list[float] = []
        self.events = 0

    def observe(self, dt: float) -> bool:
        slow = False
        if len(self.times) >= self.warmup:
            med = statistics.median(self.times[-self.window:])
            slow = dt > self.factor * med
        self.times.append(dt)
        if slow:
            self.events += 1
        return slow


def train_loop(
    state: LoopState,
    train_step: Callable,
    batches: Iterator,
    tcfg: TrainConfig,
    *,
    max_steps: Optional[int] = None,
    failure_injector: Optional[Callable[[int], None]] = None,
    straggler_hook: Optional[Callable[[int, float], None]] = None,
    restore_fn: Optional[Callable[[int], LoopState]] = None,
    max_restarts: int = 3,
) -> tuple[LoopState, LoopReport]:
    """Run the fault-tolerant loop.

    ``batches`` must be resumable by step (``batches.at(step)`` or a fresh
    iterator keyed deterministically); here we require a callable
    ``batches(step) -> batch`` for exact replay after restart.
    """
    total = max_steps if max_steps is not None else tcfg.total_steps
    saver = ckpt.AsyncSaver()
    watchdog = StragglerWatchdog()
    report = LoopReport()
    restarts = 0

    step = state.step
    while step < total:
        batch = batches(step)
        t0 = time.perf_counter()
        try:
            if failure_injector is not None:
                failure_injector(step)
            params, opt_state, metrics = train_step(state.params, state.opt_state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            state = LoopState(params=params, opt_state=opt_state, step=step + 1)
        except ckpt_restartable_errors() as e:
            restarts += 1
            report.restarts = restarts
            if restarts > max_restarts:
                raise RuntimeError(f"exceeded {max_restarts} restarts") from e
            log.warning("step %d failed (%s); restoring latest checkpoint", step, e)
            saver.wait()
            last = ckpt.latest_step(tcfg.ckpt_dir)
            if last is None or restore_fn is None:
                log.warning("no checkpoint found; replaying step %d in place", step)
                continue
            state = restore_fn(last)
            step = state.step
            continue
        dt = time.perf_counter() - t0
        report.losses.append(loss)
        report.step_times.append(dt)
        if watchdog.observe(dt):
            report.stragglers = watchdog.events
            log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt,
                        statistics.median(watchdog.times[-watchdog.window:]))
            if straggler_hook is not None:
                straggler_hook(step, dt)
        step += 1
        if tcfg.ckpt_every and step % tcfg.ckpt_every == 0:
            saver.submit(tcfg.ckpt_dir, step,
                         {"params": state.params, "opt": state.opt_state},
                         extra={"loss": loss})
    saver.wait()
    report.final_step = step
    return state, report


class SimulatedFailure(RuntimeError):
    """Raised by tests' failure injectors to exercise the restart path."""


def ckpt_restartable_errors():
    return (SimulatedFailure,)


def resize_mesh(old_state_tree, target_shardings):
    """Elastic re-mesh: re-place every leaf with the new mesh's shardings."""
    flat_s = jax.tree.leaves(
        target_shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
    )
    flat_x, tdef = jax.tree.flatten(old_state_tree)
    out = [jax.device_put(x, s) for x, s in zip(flat_x, flat_s)]
    return jax.tree.unflatten(tdef, out)
