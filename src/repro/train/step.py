"""Train-step construction: value_and_grad → (optional) compressed cross-pod
reduction → AdamW, with optional microbatch gradient accumulation.

The returned function is pure and jit/pjit-friendly:

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

Microbatching reshapes the global batch (B, S) → (k, B/k, S) and accumulates
gradients with a ``lax.scan`` (one live microbatch of activations at a time).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim.adamw import AdamWState, adamw_apply, adamw_init


def make_loss_fn(model):
    def loss_fn(params, batch):
        return model.loss(params, batch)

    return loss_fn


def make_train_step(
    model,
    tcfg: TrainConfig,
    *,
    donate: bool = True,
) -> Callable:
    """Build the canonical train step for a model object."""
    loss_fn = make_loss_fn(model)

    def compute_grads(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            k = tcfg.microbatch

            def split(x):
                b = x.shape[0]
                assert b % k == 0, (b, k)
                return x.reshape(k, b // k, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), micro)
            g = jax.tree.map(lambda x: x / k, g)
            return loss / k, {}, g
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, g

    def train_step(params, opt_state: AdamWState, batch):
        loss, aux, grads = compute_grads(params, batch)
        new_params, new_state, stats = adamw_apply(params, grads, opt_state, tcfg)
        metrics = {"loss": loss, **stats}
        if isinstance(aux, dict):
            metrics.update({k: v for k, v in aux.items()})
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model) -> Callable:
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        loss, aux = loss_fn(params, batch)
        return {"loss": loss, **(aux if isinstance(aux, dict) else {})}

    return eval_step
