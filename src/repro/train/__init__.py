from repro.train.step import make_eval_step, make_loss_fn, make_train_step
from repro.train.loop import (
    LoopReport, LoopState, SimulatedFailure, StragglerWatchdog, resize_mesh, train_loop,
)
