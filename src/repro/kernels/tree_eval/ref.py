"""Pure-jnp oracle for the tree-evaluation kernels.

The reference semantics for every kernel variant: branchless descent of the
breadth-first encoded tree, ``max_depth`` rounds (leaves self-loop, so extra
rounds are no-ops).  Deliberately written with the simplest possible jnp ops —
no Pallas, no explicit tiling — and used by tests/benchmarks as ground truth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_eval_ref(
    records: jax.Array,   # (M, A) float
    attr_idx: jax.Array,  # (N,) int32
    threshold: jax.Array, # (N,) float32
    child: jax.Array,     # (N,) int32
    class_val: jax.Array, # (N,) int32
    *,
    max_depth: int,
) -> jax.Array:
    """Ground-truth class assignment, shape (M,) int32."""
    records = records.astype(jnp.float32)
    m = records.shape[0]
    idx = jnp.zeros((m,), jnp.int32)
    for _ in range(max_depth):
        a = attr_idx[idx]
        t = threshold[idx]
        v = jnp.take_along_axis(records, a[:, None], axis=1)[:, 0]
        idx = child[idx] + (v > t).astype(jnp.int32)
    return class_val[idx]


def cascade_eval_ref(
    records,
    attr_idx,
    threshold,
    child,
    class_val,
    *,
    max_depth: int,
    order,
    stage_sizes,
    n_classes: int,
    bound: float | None,
):
    """Serial oracle for the staged early-exit cascade.

    Evaluates every tree up front with :func:`forest_eval_ref`, then replays
    the stage loop per record in plain numpy: accumulate votes stage by
    stage and stop once ``top1 - top2 > bound * remaining``.  Returns
    ``(classes, exit_stage, trees_evaluated)`` numpy arrays matching
    :class:`repro.kernels.tree_eval.cascade.CascadeEvaluator` semantics
    (without deadlines).
    """
    import numpy as np

    per_tree = np.asarray(
        forest_eval_ref(
            records, attr_idx, threshold, child, class_val, max_depth=max_depth
        )
    )  # (T, M)
    t_total, m = per_tree.shape
    c = max(int(n_classes), 2)
    classes = np.zeros((m,), np.int32)
    exit_stage = np.full((m,), -1, np.int32)
    trees_evaluated = np.zeros((m,), np.int32)
    for r in range(m):
        votes = np.zeros((c,), np.int64)
        done = 0
        for s, size in enumerate(stage_sizes):
            for j in range(done, done + size):
                votes[per_tree[order[j], r]] += 1
            done += size
            trees_evaluated[r] = done
            remaining = t_total - done
            if bound is not None and remaining > 0:
                top2 = np.sort(votes)[-2:]
                if top2[1] - top2[0] > bound * remaining:
                    exit_stage[r] = s
                    break
        classes[r] = int(votes.argmax())
    return classes, exit_stage, trees_evaluated


def forest_eval_ref(
    records: jax.Array,    # (M, A)
    attr_idx: jax.Array,   # (T, N)
    threshold: jax.Array,  # (T, N)
    child: jax.Array,      # (T, N)
    class_val: jax.Array,  # (T, N)
    *,
    max_depth: int,
) -> jax.Array:
    """Per-tree ground truth, shape (T, M) int32."""
    def one(a, t, c, k):
        return tree_eval_ref(records, a, t, c, k, max_depth=max_depth)

    return jax.vmap(one)(attr_idx, threshold, child, class_val)
