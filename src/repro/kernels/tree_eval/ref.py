"""Pure-jnp oracle for the tree-evaluation kernels.

The reference semantics for every kernel variant: branchless descent of the
breadth-first encoded tree, ``max_depth`` rounds (leaves self-loop, so extra
rounds are no-ops).  Deliberately written with the simplest possible jnp ops —
no Pallas, no explicit tiling — and used by tests/benchmarks as ground truth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_eval_ref(
    records: jax.Array,   # (M, A) float
    attr_idx: jax.Array,  # (N,) int32
    threshold: jax.Array, # (N,) float32
    child: jax.Array,     # (N,) int32
    class_val: jax.Array, # (N,) int32
    *,
    max_depth: int,
) -> jax.Array:
    """Ground-truth class assignment, shape (M,) int32."""
    records = records.astype(jnp.float32)
    m = records.shape[0]
    idx = jnp.zeros((m,), jnp.int32)
    for _ in range(max_depth):
        a = attr_idx[idx]
        t = threshold[idx]
        v = jnp.take_along_axis(records, a[:, None], axis=1)[:, 0]
        idx = child[idx] + (v > t).astype(jnp.int32)
    return class_val[idx]


def forest_eval_ref(
    records: jax.Array,    # (M, A)
    attr_idx: jax.Array,   # (T, N)
    threshold: jax.Array,  # (T, N)
    child: jax.Array,      # (T, N)
    class_val: jax.Array,  # (T, N)
    *,
    max_depth: int,
) -> jax.Array:
    """Per-tree ground truth, shape (T, M) int32."""
    def one(a, t, c, k):
        return tree_eval_ref(records, a, t, c, k, max_depth=max_depth)

    return jax.vmap(one)(attr_idx, threshold, child, class_val)
