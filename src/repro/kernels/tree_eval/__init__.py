"""TPU Pallas kernels for the paper's tree-evaluation hot spot."""

from repro.kernels.tree_eval.ops import (
    VARIANTS,
    PackedTree,
    VariantSpec,
    forest_eval,
    get_variant,
    list_variants,
    register_variant,
    tree_eval,
)
from repro.kernels.tree_eval.ref import forest_eval_ref, tree_eval_ref

__all__ = [
    "PackedTree",
    "VARIANTS",
    "VariantSpec",
    "forest_eval",
    "forest_eval_ref",
    "get_variant",
    "list_variants",
    "register_variant",
    "tree_eval",
    "tree_eval_ref",
]
