"""TPU Pallas kernels for the paper's tree-evaluation hot spot."""

from repro.kernels.tree_eval.ops import (
    FOREST_VARIANTS,
    PER_TREE_FAMILY,
    VARIANTS,
    ForestVariantSpec,
    PackedForest,
    PackedTree,
    VariantSpec,
    forest_eval,
    forest_eval_fused,
    get_forest_variant,
    get_variant,
    list_forest_variants,
    list_variants,
    register_forest_variant,
    register_variant,
    tree_eval,
)
from repro.kernels.tree_eval.ref import forest_eval_ref, tree_eval_ref

__all__ = [
    "FOREST_VARIANTS",
    "ForestVariantSpec",
    "PER_TREE_FAMILY",
    "PackedForest",
    "PackedTree",
    "VARIANTS",
    "VariantSpec",
    "forest_eval",
    "forest_eval_fused",
    "forest_eval_ref",
    "get_forest_variant",
    "get_variant",
    "list_forest_variants",
    "list_variants",
    "register_forest_variant",
    "register_variant",
    "tree_eval",
    "tree_eval_ref",
]
