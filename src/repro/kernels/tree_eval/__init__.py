"""TPU Pallas kernels for the paper's tree-evaluation hot spot."""

from repro.kernels.tree_eval.ops import PackedTree, forest_eval, tree_eval
from repro.kernels.tree_eval.ref import forest_eval_ref, tree_eval_ref

__all__ = ["PackedTree", "forest_eval", "tree_eval", "forest_eval_ref", "tree_eval_ref"]
