"""Pallas TPU kernels for classification-tree evaluation.

Two kernels mirror the paper's two parallel decompositions, re-tiled for the
TPU memory hierarchy (HBM → VMEM → VREG) and compute units (MXU/VPU):

``speculative_kernel``  (paper Procedure 4/5, EvalTreeByNode)
    Records ride the sublane axis, tree nodes ride the 128-lane axis.
    Node evaluation is a single MXU matmul ``vals = records @ attr_select``
    (the one-hot selection matrix replaces the CUDA shared-memory gather),
    followed by a branch-free successor computation and ``⌈log₂ d⌉`` pointer
    jumps.  Jumps come in two flavours:
      * ``gather``  — ``jnp.take_along_axis`` along lanes (Mosaic dynamic
        gather; cheapest when supported),
      * ``onehot``  — batched permutation matmul ``pathᵢ₊₁ = P · pathᵢ``,
        all-MXU, no cross-lane gathers at all (the fully systolic variant).

``data_parallel_kernel`` (paper Procedure 3, EvalTreeBySample)
    One record per sublane; ``max_depth`` dependent rounds of table gathers.
    This is the faithful TPU port of the data decomposition and exists to
    reproduce the paper's comparison: its inner loop is *serially dependent*
    (length d) whereas the speculative kernel needs only log₂ d dependent
    steps after one matmul.

Both kernels tile records into ``block_m`` chunks over a 1-D grid; the tree
tables use broadcast BlockSpecs (index_map → block 0) so they are DMA'd into
VMEM once and reused across grid steps — the analogue of the paper's constant
memory.  All shapes are padded by ``ops.py`` so that M % block_m == 0,
N % 128 == 0 and A % 128 == 0 (MXU alignment).

``fused_speculative_pallas`` / ``fused_data_parallel_pallas`` lift the same
bodies to a whole *forest* in one launch: tree tables are stacked to (T, N)
(attr-select to (T, A, N)) and the grid gains a tree axis —
``(M/block_m, T)`` with trees innermost, so each record tile stays resident
in VMEM while the T tree tables stream past it.  One launch replaces the T
separate launches of the per-tree path, which is where the fused forest
variant wins: the per-launch overhead is paid once and the record DMA is
amortised across the forest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _lane_gather(table_row: jax.Array, idx: jax.Array) -> jax.Array:
    """``table_row`` (1, N) gathered at ``idx`` (BM, K) → (BM, K)."""
    bm = idx.shape[0]
    table = jnp.broadcast_to(table_row, (bm, table_row.shape[-1]))
    return jnp.take_along_axis(table, idx, axis=1)


def _onehot_matvec(idx: jax.Array, table_row: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Gather-free table lookup: ``onehot(idx) @ table`` on the MXU.

    idx (BM, K) int32, table_row (1, N) → (BM, K) values of table[idx].
    Built for the TPU path where cross-lane dynamic gathers are slow or
    unsupported; numerically exact for int32 tables ≤ 2^24 (float32 mantissa).
    """
    n = table_row.shape[-1]
    oh = jax.nn.one_hot(idx, n, dtype=dtype)             # (BM, K, N)
    return jnp.einsum("bkn,n->bk", oh, table_row[0].astype(dtype))


# ---------------------------------------------------------------------------
# speculative kernel (Procedure 4/5)
# ---------------------------------------------------------------------------


def _speculative_compute(
    rec,        # (BM, A) f32
    sel,        # (A, N) f32 one-hot attribute selection
    thr,        # (1, N) f32
    child,      # (1, N) i32
    class_val,  # (1, N) i32
    *,
    total_jumps: int,
    jump_mode: str,
):
    """Procedure 4/5 core on VMEM-resident arrays; returns (BM, 1) int32."""
    # --- node evaluation: every node, every record, one MXU matmul ---
    vals = jnp.dot(rec, sel, preferred_element_type=jnp.float32)   # (BM, N)
    pred = (vals > thr).astype(jnp.int32)
    path = child + pred                                            # (BM, N)

    # --- pointer jumping: path[i] ← path[path[i]] ---
    if jump_mode == "gather":
        for _ in range(total_jumps):
            path = jnp.take_along_axis(path, path, axis=1)
    elif jump_mode == "onehot":
        n = path.shape[-1]
        pathf = path.astype(jnp.float32)
        for _ in range(total_jumps):
            onehot = jax.nn.one_hot(path, n, dtype=jnp.float32)    # (BM, N, N)
            pathf = jnp.einsum("bin,bn->bi", onehot, pathf)        # MXU
            path = pathf.astype(jnp.int32)
    else:
        raise ValueError(f"unknown jump_mode {jump_mode!r}")

    # --- root's eventual successor is the terminal leaf; read its class ---
    root_leaf = path[:, 0:1]                                       # (BM, 1)
    if jump_mode == "gather":
        return _lane_gather(class_val, root_leaf)
    return _onehot_matvec(root_leaf, class_val).astype(jnp.int32)


def _speculative_body(
    records_ref,      # (BM, A) VMEM
    attr_sel_ref,     # (A, N) VMEM — one-hot attribute selection
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (BM, 1) VMEM
    *,
    total_jumps: int,
    jump_mode: str,
):
    out_ref[...] = _speculative_compute(
        records_ref[...].astype(jnp.float32),
        attr_sel_ref[...].astype(jnp.float32),
        threshold_ref[...],
        child_ref[...],
        class_val_ref[...],
        total_jumps=total_jumps,
        jump_mode=jump_mode,
    )


def speculative_pallas(
    records: jax.Array,     # (M, A) — padded
    attr_select: jax.Array, # (A, N) — padded one-hot
    threshold: jax.Array,   # (1, N)
    child: jax.Array,       # (1, N)
    class_val: jax.Array,   # (1, N)
    *,
    total_jumps: int,
    block_m: int,
    jump_mode: str = "gather",
    interpret: bool = True,
) -> jax.Array:
    """Launch the speculative kernel over a 1-D record grid. Returns (M, 1)."""
    m, a = records.shape
    n = threshold.shape[-1]
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    kernel = functools.partial(
        _speculative_body, total_jumps=total_jumps, jump_mode=jump_mode
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i: (i, 0)),  # records: stream tiles
            pl.BlockSpec((a, n), lambda i: (0, 0)),        # tree tables: broadcast
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(records, attr_select, threshold, child, class_val)


# ---------------------------------------------------------------------------
# data-parallel kernel (Procedure 3)
# ---------------------------------------------------------------------------


def _data_parallel_compute(
    rec,        # (BM, A) f32
    attr_idx,   # (1, N) i32
    thr,        # (1, N) f32
    child,      # (1, N) i32
    class_val,  # (1, N) i32
    *,
    max_depth: int,
):
    """Procedure 3 core on VMEM-resident arrays; returns (BM, 1) int32."""
    bm = rec.shape[0]
    idx = jnp.zeros((bm, 1), jnp.int32)
    for _ in range(max_depth):
        a = _lane_gather(attr_idx, idx)                   # (BM, 1)
        t = _lane_gather(thr, idx)
        c = _lane_gather(child, idx)
        v = jnp.take_along_axis(rec, a, axis=1)           # per-record attr
        idx = c + (v > t).astype(jnp.int32)
    return _lane_gather(class_val, idx)


def _data_parallel_body(
    records_ref,      # (BM, A) VMEM
    attr_idx_ref,     # (1, N) VMEM (int32)
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (BM, 1)
    *,
    max_depth: int,
):
    out_ref[...] = _data_parallel_compute(
        records_ref[...].astype(jnp.float32),
        attr_idx_ref[...],
        threshold_ref[...],
        child_ref[...],
        class_val_ref[...],
        max_depth=max_depth,
    )


def data_parallel_pallas(
    records: jax.Array,    # (M, A) padded
    attr_idx: jax.Array,   # (1, N)
    threshold: jax.Array,  # (1, N)
    child: jax.Array,      # (1, N)
    class_val: jax.Array,  # (1, N)
    *,
    max_depth: int,
    block_m: int,
    interpret: bool = True,
) -> jax.Array:
    m, a = records.shape
    n = threshold.shape[-1]
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    kernel = functools.partial(_data_parallel_body, max_depth=max_depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(records, attr_idx, threshold, child, class_val)


# ---------------------------------------------------------------------------
# fused stacked-forest kernels (one launch for T trees)
# ---------------------------------------------------------------------------
#
# Grid (M/block_m, T): the record-tile axis is outer and the tree axis inner,
# so consecutive grid steps revisit the same record block (no re-DMA) while
# the (1, N)-blocked tree tables stream through VMEM one tree at a time.
# Output lands as (T, M, 1) blocks of (1, BM, 1) — the trailing singleton
# keeps the write a pure leading-axis expand of the per-tree (BM, 1) result,
# no cross-lane relayout.


def _fused_speculative_body(
    records_ref,      # (BM, A) VMEM — shared across the tree axis
    attr_sel_ref,     # (1, A, N) VMEM — tree t's one-hot selection
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (1, BM, 1) VMEM
    *,
    total_jumps: int,
    jump_mode: str,
):
    out_ref[...] = _speculative_compute(
        records_ref[...].astype(jnp.float32),
        attr_sel_ref[0].astype(jnp.float32),
        threshold_ref[...],
        child_ref[...],
        class_val_ref[...],
        total_jumps=total_jumps,
        jump_mode=jump_mode,
    )[None]


def fused_speculative_pallas(
    records: jax.Array,     # (M, A) — padded
    attr_select: jax.Array, # (T, A, N) — per-tree padded one-hot
    threshold: jax.Array,   # (T, N)
    child: jax.Array,       # (T, N)
    class_val: jax.Array,   # (T, N)
    *,
    total_jumps: int,
    block_m: int,
    jump_mode: str = "gather",
    interpret: bool = True,
) -> jax.Array:
    """One speculative launch over the whole forest. Returns (T, M, 1)."""
    m, a = records.shape
    t, n = threshold.shape
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m, t)
    kernel = functools.partial(
        _fused_speculative_body, total_jumps=total_jumps, jump_mode=jump_mode
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i, j: (i, 0)),   # record tile: VMEM-resident per i
            pl.BlockSpec((1, a, n), lambda i, j: (j, 0, 0)),   # tree tables: stream over j
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, 1), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, 1), jnp.int32),
        interpret=interpret,
    )(records, attr_select, threshold, child, class_val)


# ---------------------------------------------------------------------------
# fused vote-accumulating kernels (cascade stages)
# ---------------------------------------------------------------------------
#
# Same grid as the fused class kernels — (M/block_m, T) with trees innermost —
# but instead of materialising the (T, M) per-tree class matrix the output is
# the (M, C) per-record *vote histogram*: the output BlockSpec's index map
# ignores the tree axis, so every tree-step of one record tile revisits the
# same (BM, C) VMEM block and accumulates its one-hot vote into it
# (initialised at j == 0).  The per-tree classes never leave VMEM, which is
# what makes the cascade's margin bookkeeping free of a (T, M) round trip.


def _accumulate_votes(out_ref, cls):
    """Add one tree's one-hot votes for ``cls`` (BM, 1) into ``out_ref``."""
    bm, c = out_ref.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bm, c), 1)
    votes = (lanes == cls).astype(jnp.int32)                       # (BM, C)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = votes

    @pl.when(j != 0)
    def _add():
        out_ref[...] += votes


def _fused_votes_speculative_body(
    records_ref,      # (BM, A) VMEM — shared across the tree axis
    attr_sel_ref,     # (1, A, N) VMEM
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (BM, C) VMEM — revisited across the tree axis
    *,
    total_jumps: int,
    jump_mode: str,
):
    cls = _speculative_compute(
        records_ref[...].astype(jnp.float32),
        attr_sel_ref[0].astype(jnp.float32),
        threshold_ref[...],
        child_ref[...],
        class_val_ref[...],
        total_jumps=total_jumps,
        jump_mode=jump_mode,
    )
    _accumulate_votes(out_ref, cls)


def fused_votes_speculative_pallas(
    records: jax.Array,     # (M, A) — padded
    attr_select: jax.Array, # (T, A, N) — per-tree padded one-hot
    threshold: jax.Array,   # (T, N)
    child: jax.Array,       # (T, N)
    class_val: jax.Array,   # (T, N)
    *,
    n_classes: int,         # padded class-lane count C
    total_jumps: int,
    block_m: int,
    jump_mode: str = "gather",
    interpret: bool = True,
) -> jax.Array:
    """One speculative launch accumulating forest votes. Returns (M, C)."""
    m, a = records.shape
    t, n = threshold.shape
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m, t)
    kernel = functools.partial(
        _fused_votes_speculative_body, total_jumps=total_jumps, jump_mode=jump_mode
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i, j: (i, 0)),
            pl.BlockSpec((1, a, n), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n_classes), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_classes), jnp.int32),
        interpret=interpret,
    )(records, attr_select, threshold, child, class_val)


def _fused_votes_data_parallel_body(
    records_ref,      # (BM, A) VMEM
    attr_idx_ref,     # (1, N) VMEM (int32)
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (BM, C) VMEM — revisited across the tree axis
    *,
    max_depth: int,
):
    cls = _data_parallel_compute(
        records_ref[...].astype(jnp.float32),
        attr_idx_ref[...],
        threshold_ref[...],
        child_ref[...],
        class_val_ref[...],
        max_depth=max_depth,
    )
    _accumulate_votes(out_ref, cls)


def fused_votes_data_parallel_pallas(
    records: jax.Array,    # (M, A) padded
    attr_idx: jax.Array,   # (T, N)
    threshold: jax.Array,  # (T, N)
    child: jax.Array,      # (T, N)
    class_val: jax.Array,  # (T, N)
    *,
    n_classes: int,        # padded class-lane count C
    max_depth: int,
    block_m: int,
    interpret: bool = True,
) -> jax.Array:
    """One data-parallel launch accumulating forest votes. Returns (M, C)."""
    m, a = records.shape
    t, n = threshold.shape
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m, t)
    kernel = functools.partial(_fused_votes_data_parallel_body, max_depth=max_depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n_classes), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_classes), jnp.int32),
        interpret=interpret,
    )(records, attr_idx, threshold, child, class_val)


# ---------------------------------------------------------------------------
# quantized fused kernels (compact SoA layouts, §4 memory optimizations)
# ---------------------------------------------------------------------------
#
# Same grid as the f32 fused kernels — (M/block_m, T), trees innermost — but
# the tables arrive at their quantized storage dtypes (int8/int16 indices,
# bf16/f16/f32 thresholds) and there is **no attr_select matrix**: node
# evaluation gathers each record's attribute directly,
# ``vals[b, n] = rec[b, attr_idx[n]]``, which is what makes the quantized
# node table 1–2 orders of magnitude smaller than the one-hot layout.  All
# arithmetic upcasts at the register level (int → int32, float → f32), so
# results are bit-identical to the f32 kernels running on the same
# (possibly quantized) threshold values.


def _quant_speculative_compute(
    rec,        # (BM, A) f32
    attr_idx,   # (1, N) int8/int16/int32
    thr,        # (1, N) bf16/f16/f32
    child,      # (1, N) int16/int32
    class_val,  # (1, N) int8/int16/int32
    *,
    total_jumps: int,
):
    """Procedure 4/5 core on quantized tables; returns (BM, 1) int32."""
    bm = rec.shape[0]
    n = attr_idx.shape[-1]
    idx = jnp.broadcast_to(attr_idx.astype(jnp.int32), (bm, n))
    vals = jnp.take_along_axis(rec, idx, axis=1)              # (BM, N) gather
    pred = (vals > thr.astype(jnp.float32)).astype(jnp.int32)
    path = child.astype(jnp.int32) + pred                      # (BM, N)
    for _ in range(total_jumps):
        path = jnp.take_along_axis(path, path, axis=1)
    return _lane_gather(class_val.astype(jnp.int32), path[:, 0:1])


def _fused_speculative_q_body(
    records_ref,      # (BM, A) VMEM — shared across the tree axis
    attr_idx_ref,     # (1, N) VMEM (int8/int16)
    threshold_ref,    # (1, N) VMEM (bf16/f16/f32)
    child_ref,        # (1, N) VMEM (int16/int32)
    class_val_ref,    # (1, N) VMEM (int8/int16)
    out_ref,          # (1, BM, 1) VMEM
    *,
    total_jumps: int,
):
    out_ref[...] = _quant_speculative_compute(
        records_ref[...].astype(jnp.float32),
        attr_idx_ref[...],
        threshold_ref[...],
        child_ref[...],
        class_val_ref[...],
        total_jumps=total_jumps,
    )[None]


def _fused_data_parallel_q_body(
    records_ref,      # (BM, A) VMEM
    attr_idx_ref,     # (1, N) VMEM (int8/int16)
    threshold_ref,    # (1, N) VMEM (bf16/f16/f32)
    child_ref,        # (1, N) VMEM (int16/int32)
    class_val_ref,    # (1, N) VMEM (int8/int16)
    out_ref,          # (1, BM, 1)
    *,
    max_depth: int,
):
    out_ref[...] = _data_parallel_compute(
        records_ref[...].astype(jnp.float32),
        attr_idx_ref[...].astype(jnp.int32),
        threshold_ref[...].astype(jnp.float32),
        child_ref[...].astype(jnp.int32),
        class_val_ref[...].astype(jnp.int32),
        max_depth=max_depth,
    )[None]


def _fused_q_pallas(kernel, records, attr_idx, threshold, child, class_val,
                    *, block_m, interpret):
    """Shared launch plumbing for the quantized fused kernels."""
    m, a = records.shape
    t, n = threshold.shape
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m, t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i, j: (i, 0)),  # record tile resident
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),        # quant tables stream
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, 1), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, 1), jnp.int32),
        interpret=interpret,
    )(records, attr_idx, threshold, child, class_val)


def fused_speculative_q_pallas(
    records: jax.Array,    # (M, A) padded f32
    attr_idx: jax.Array,   # (T, N) int8/int16
    threshold: jax.Array,  # (T, N) bf16/f16/f32
    child: jax.Array,      # (T, N) int16/int32
    class_val: jax.Array,  # (T, N) int8/int16
    *,
    total_jumps: int,
    block_m: int,
    interpret: bool = True,
) -> jax.Array:
    """Quantized speculative launch over the whole forest. Returns (T, M, 1)."""
    kernel = functools.partial(_fused_speculative_q_body, total_jumps=total_jumps)
    return _fused_q_pallas(kernel, records, attr_idx, threshold, child, class_val,
                           block_m=block_m, interpret=interpret)


def fused_data_parallel_q_pallas(
    records: jax.Array,    # (M, A) padded f32
    attr_idx: jax.Array,   # (T, N) int8/int16
    threshold: jax.Array,  # (T, N) bf16/f16/f32
    child: jax.Array,      # (T, N) int16/int32
    class_val: jax.Array,  # (T, N) int8/int16
    *,
    max_depth: int,
    block_m: int,
    interpret: bool = True,
) -> jax.Array:
    """Quantized data-parallel launch over the whole forest. Returns (T, M, 1)."""
    kernel = functools.partial(_fused_data_parallel_q_body, max_depth=max_depth)
    return _fused_q_pallas(kernel, records, attr_idx, threshold, child, class_val,
                           block_m=block_m, interpret=interpret)


def _fused_data_parallel_body(
    records_ref,      # (BM, A) VMEM
    attr_idx_ref,     # (1, N) VMEM (int32)
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (1, BM, 1)
    *,
    max_depth: int,
):
    out_ref[...] = _data_parallel_compute(
        records_ref[...].astype(jnp.float32),
        attr_idx_ref[...],
        threshold_ref[...],
        child_ref[...],
        class_val_ref[...],
        max_depth=max_depth,
    )[None]


def fused_data_parallel_pallas(
    records: jax.Array,    # (M, A) padded
    attr_idx: jax.Array,   # (T, N)
    threshold: jax.Array,  # (T, N)
    child: jax.Array,      # (T, N)
    class_val: jax.Array,  # (T, N)
    *,
    max_depth: int,
    block_m: int,
    interpret: bool = True,
) -> jax.Array:
    """One data-parallel launch over the whole forest. Returns (T, M, 1)."""
    m, a = records.shape
    t, n = threshold.shape
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m, t)
    kernel = functools.partial(_fused_data_parallel_body, max_depth=max_depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, 1), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, 1), jnp.int32),
        interpret=interpret,
    )(records, attr_idx, threshold, child, class_val)
