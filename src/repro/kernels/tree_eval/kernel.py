"""Pallas TPU kernels for classification-tree evaluation.

Two kernels mirror the paper's two parallel decompositions, re-tiled for the
TPU memory hierarchy (HBM → VMEM → VREG) and compute units (MXU/VPU):

``speculative_kernel``  (paper Procedure 4/5, EvalTreeByNode)
    Records ride the sublane axis, tree nodes ride the 128-lane axis.
    Node evaluation is a single MXU matmul ``vals = records @ attr_select``
    (the one-hot selection matrix replaces the CUDA shared-memory gather),
    followed by a branch-free successor computation and ``⌈log₂ d⌉`` pointer
    jumps.  Jumps come in two flavours:
      * ``gather``  — ``jnp.take_along_axis`` along lanes (Mosaic dynamic
        gather; cheapest when supported),
      * ``onehot``  — batched permutation matmul ``pathᵢ₊₁ = P · pathᵢ``,
        all-MXU, no cross-lane gathers at all (the fully systolic variant).

``data_parallel_kernel`` (paper Procedure 3, EvalTreeBySample)
    One record per sublane; ``max_depth`` dependent rounds of table gathers.
    This is the faithful TPU port of the data decomposition and exists to
    reproduce the paper's comparison: its inner loop is *serially dependent*
    (length d) whereas the speculative kernel needs only log₂ d dependent
    steps after one matmul.

Both kernels tile records into ``block_m`` chunks over a 1-D grid; the tree
tables use broadcast BlockSpecs (index_map → block 0) so they are DMA'd into
VMEM once and reused across grid steps — the analogue of the paper's constant
memory.  All shapes are padded by ``ops.py`` so that M % block_m == 0,
N % 128 == 0 and A % 128 == 0 (MXU alignment).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _lane_gather(table_row: jax.Array, idx: jax.Array) -> jax.Array:
    """``table_row`` (1, N) gathered at ``idx`` (BM, K) → (BM, K)."""
    bm = idx.shape[0]
    table = jnp.broadcast_to(table_row, (bm, table_row.shape[-1]))
    return jnp.take_along_axis(table, idx, axis=1)


def _onehot_matvec(idx: jax.Array, table_row: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Gather-free table lookup: ``onehot(idx) @ table`` on the MXU.

    idx (BM, K) int32, table_row (1, N) → (BM, K) values of table[idx].
    Built for the TPU path where cross-lane dynamic gathers are slow or
    unsupported; numerically exact for int32 tables ≤ 2^24 (float32 mantissa).
    """
    n = table_row.shape[-1]
    oh = jax.nn.one_hot(idx, n, dtype=dtype)             # (BM, K, N)
    return jnp.einsum("bkn,n->bk", oh, table_row[0].astype(dtype))


# ---------------------------------------------------------------------------
# speculative kernel (Procedure 4/5)
# ---------------------------------------------------------------------------


def _speculative_body(
    records_ref,      # (BM, A) VMEM
    attr_sel_ref,     # (A, N) VMEM — one-hot attribute selection
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (BM, 1) VMEM
    *,
    total_jumps: int,
    jump_mode: str,
):
    rec = records_ref[...].astype(jnp.float32)
    sel = attr_sel_ref[...].astype(jnp.float32)
    # --- node evaluation: every node, every record, one MXU matmul ---
    vals = jnp.dot(rec, sel, preferred_element_type=jnp.float32)   # (BM, N)
    thr = threshold_ref[...]                                       # (1, N)
    child = child_ref[...]                                         # (1, N)
    pred = (vals > thr).astype(jnp.int32)
    path = child + pred                                            # (BM, N)

    # --- pointer jumping: path[i] ← path[path[i]] ---
    if jump_mode == "gather":
        for _ in range(total_jumps):
            path = jnp.take_along_axis(path, path, axis=1)
    elif jump_mode == "onehot":
        n = path.shape[-1]
        pathf = path.astype(jnp.float32)
        for _ in range(total_jumps):
            onehot = jax.nn.one_hot(path, n, dtype=jnp.float32)    # (BM, N, N)
            pathf = jnp.einsum("bin,bn->bi", onehot, pathf)        # MXU
            path = pathf.astype(jnp.int32)
    else:
        raise ValueError(f"unknown jump_mode {jump_mode!r}")

    # --- root's eventual successor is the terminal leaf; read its class ---
    root_leaf = path[:, 0:1]                                       # (BM, 1)
    out_ref[...] = _lane_gather(class_val_ref[...], root_leaf) if jump_mode == "gather" else (
        _onehot_matvec(root_leaf, class_val_ref[...]).astype(jnp.int32)
    )


def speculative_pallas(
    records: jax.Array,     # (M, A) — padded
    attr_select: jax.Array, # (A, N) — padded one-hot
    threshold: jax.Array,   # (1, N)
    child: jax.Array,       # (1, N)
    class_val: jax.Array,   # (1, N)
    *,
    total_jumps: int,
    block_m: int,
    jump_mode: str = "gather",
    interpret: bool = True,
) -> jax.Array:
    """Launch the speculative kernel over a 1-D record grid. Returns (M, 1)."""
    m, a = records.shape
    n = threshold.shape[-1]
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    kernel = functools.partial(
        _speculative_body, total_jumps=total_jumps, jump_mode=jump_mode
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i: (i, 0)),  # records: stream tiles
            pl.BlockSpec((a, n), lambda i: (0, 0)),        # tree tables: broadcast
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(records, attr_select, threshold, child, class_val)


# ---------------------------------------------------------------------------
# data-parallel kernel (Procedure 3)
# ---------------------------------------------------------------------------


def _data_parallel_body(
    records_ref,      # (BM, A) VMEM
    attr_idx_ref,     # (1, N) VMEM (int32)
    threshold_ref,    # (1, N) VMEM
    child_ref,        # (1, N) VMEM
    class_val_ref,    # (1, N) VMEM
    out_ref,          # (BM, 1)
    *,
    max_depth: int,
):
    rec = records_ref[...].astype(jnp.float32)
    bm = rec.shape[0]
    idx = jnp.zeros((bm, 1), jnp.int32)
    for _ in range(max_depth):
        a = _lane_gather(attr_idx_ref[...], idx)          # (BM, 1)
        t = _lane_gather(threshold_ref[...], idx)
        c = _lane_gather(child_ref[...], idx)
        v = jnp.take_along_axis(rec, a, axis=1)           # per-record attr
        idx = c + (v > t).astype(jnp.int32)
    out_ref[...] = _lane_gather(class_val_ref[...], idx)


def data_parallel_pallas(
    records: jax.Array,    # (M, A) padded
    attr_idx: jax.Array,   # (1, N)
    threshold: jax.Array,  # (1, N)
    child: jax.Array,      # (1, N)
    class_val: jax.Array,  # (1, N)
    *,
    max_depth: int,
    block_m: int,
    interpret: bool = True,
) -> jax.Array:
    m, a = records.shape
    n = threshold.shape[-1]
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    kernel = functools.partial(_data_parallel_body, max_depth=max_depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, a), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(records, attr_idx, threshold, child, class_val)
