"""Quantized struct-of-arrays forest layouts (the §4 memory-optimization analogue).

The paper's CUDA wins came from shrinking and re-laying-out the node table so
tree data stays resident close to the SIMD lanes (§4 texture/constant-memory
optimizations).  :class:`repro.kernels.tree_eval.ops.PackedForest` carries
full-width f32/int32 arrays — and, for the speculative kernel, a one-hot
``attr_select`` matrix of A_pad·N_pad floats per tree that dwarfs the scalar
tables.  :class:`QuantizedForest` is the compact dual: per-record attribute
*gathers* replace the selection matmul (no ``attr_select`` at all), attribute
indices shrink to int8/int16, child pointers to int16, classes to int8/int16,
leaf flags bit-pack 8-to-a-byte, and thresholds drop to bf16/f16 under a
**split-safe rounding rule** that provably never changes a routing decision.

Split-safe rounding
-------------------
The branchless predicate is strict: ``next = child + (v > t)``.  Replacing
``t`` with a low-precision ``t'`` is routing-preserving for a value ``v``
exactly when ``(v > t') == (v > t)``.  Two regimes:

* **universal** (``calibration=None``): ``t'`` must preserve the predicate
  for *every possible* ``v`` — only exact round-trips qualify
  (``f32(cast(t)) == t``); every other node keeps its exact f32 threshold.
  The resulting layout is bit-exact for arbitrary inputs (including ±inf
  and NaN attributes), which is what the tuner and dispatch paths build.
* **split-safe** (``calibration=(M, A)`` records): per node, the observed
  values of its attribute define a routing interval
  ``v_lo = max{v : v <= t}``, ``v_hi = min{v : v > t}``; any representable
  ``t'`` with ``v_lo <= t' < v_hi`` preserves every calibration record's
  branch — including the paper's ``<=``/``>`` tie-break when a value sits
  exactly on the split.  Nodes whose interval contains no representable
  value fall back to exact f32 (counted in ``fallback_nodes``).

When any node falls back the threshold table is stored as f32 — safe nodes
still hold their quantized-then-upcast value so per-node routing is
identical whichever storage dtype the forest ends up with — and ``nbytes``
accounts the table at its *stored* width, never the requested one.
"""

from __future__ import annotations

import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.tree import BOTTOM, EncodedTree, node_depths, pad_tree, tree_depth

# Mirrors ops.LANE — quant.py stays import-free of ops (ops imports us).
LANE = 128

THR_DTYPES: dict[str, np.dtype] = {
    "bfloat16": np.dtype(ml_dtypes.bfloat16),
    "float16": np.dtype(np.float16),
}


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# 16-bit float neighbours (shared IEEE-style bit layout of f16 and bf16)
# ---------------------------------------------------------------------------


def _ordered_from_bits(bits: np.ndarray) -> np.ndarray:
    """Map 16-bit float bit patterns to integers monotone in float value."""
    b = bits.astype(np.int64)
    return np.where(b & 0x8000, 0x7FFF - (b & 0x7FFF), b + 0x8000)


def _bits_from_ordered(keys: np.ndarray) -> np.ndarray:
    k = np.asarray(keys, np.int64)
    return np.where(k >= 0x8000, k - 0x8000, 0x8000 | (0x7FFF - k)).astype(np.uint16)


def _neighbors(q: np.ndarray, dtype: np.dtype) -> tuple[np.ndarray, np.ndarray]:
    """Element-wise (previous, next) representable values of ``q`` in ``dtype``.

    Saturates at the ordered-key range ends, so ±inf's outward neighbour is
    itself (never a NaN pattern).
    """
    keys = _ordered_from_bits(np.ascontiguousarray(q).view(np.uint16))
    fin = _ordered_from_bits(
        np.array([0x7C00 if dtype == np.float16 else 0x7F80], np.uint16))[0]
    prev = _bits_from_ordered(np.clip(keys - 1, 0xFFFF - fin, fin)).view(dtype)
    nxt = _bits_from_ordered(np.clip(keys + 1, 0xFFFF - fin, fin)).view(dtype)
    return prev, nxt


# ---------------------------------------------------------------------------
# split-safe threshold quantization
# ---------------------------------------------------------------------------


def routing_interval(sorted_vals: np.ndarray, t: float) -> tuple[float, float]:
    """The (v_lo, v_hi) routing interval of threshold ``t`` over observed values.

    Any ``t'`` with ``v_lo <= t' < v_hi`` preserves ``v > t'`` for every
    value in ``sorted_vals`` (finite, ascending).  Empty side → ∓inf.
    """
    i = int(np.searchsorted(sorted_vals, t, side="right"))
    v_lo = float(sorted_vals[i - 1]) if i > 0 else -np.inf
    v_hi = float(sorted_vals[i]) if i < len(sorted_vals) else np.inf
    return v_lo, v_hi


def quantize_thresholds(
    threshold: np.ndarray,
    leaf_mask: np.ndarray,
    attr_idx: np.ndarray,
    *,
    thr_dtype: str = "bfloat16",
    attr_values: dict[int, np.ndarray] | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Quantize one tree's thresholds under the split-safe rounding rule.

    Args:
      threshold/leaf_mask/attr_idx: the encoded tree's (N,) tables.
      thr_dtype: "bfloat16" | "float16" target.
      attr_values: {attr → sorted finite calibration values}; None selects
        the universal regime (quantize only exact round-trips).

    Returns:
      (qthr, safe): the (N,) quantized table in ``thr_dtype`` and the
      boolean mask of nodes whose quantized threshold is routing-safe.
      Leaves (``+inf`` round-trips exactly) are always safe.
    """
    dt = THR_DTYPES[thr_dtype]
    thr = np.asarray(threshold, np.float32)
    leaf = np.asarray(leaf_mask, bool)
    q = thr.astype(dt)
    up = q.astype(np.float32)
    if attr_values is None:
        return q, leaf | (up == thr)
    safe = leaf.copy()
    prev, nxt = _neighbors(q, dt)
    for i in np.nonzero(~leaf)[0]:
        vals = attr_values.get(int(attr_idx[i]))
        if vals is None or not len(vals):
            safe[i] = True  # attribute never observed: any t' routes nothing
            continue
        v_lo, v_hi = routing_interval(vals, float(thr[i]))
        t = float(thr[i])
        # nearest-first candidate order; NaN/out-of-interval casts rejected
        cands = sorted({q[i], prev[i], nxt[i]},
                       key=lambda c: abs(float(np.float32(c)) - t))
        for c in cands:
            cu = float(np.float32(c))
            if v_lo <= cu < v_hi:
                q[i] = c
                safe[i] = True
                break
    return q, safe


# ---------------------------------------------------------------------------
# bit-packed leaf flags
# ---------------------------------------------------------------------------


def pack_leaf_bits(leaf_mask: np.ndarray) -> np.ndarray:
    """(N,) bool → (⌈N/8⌉,) uint8, LSB-first within each byte."""
    return np.packbits(np.asarray(leaf_mask, bool), bitorder="little")


def unpack_leaf_bits(bits: np.ndarray, n_nodes: int) -> np.ndarray:
    """Inverse of :func:`pack_leaf_bits`."""
    return np.unpackbits(np.asarray(bits, np.uint8), count=n_nodes,
                         bitorder="little").astype(bool)


# ---------------------------------------------------------------------------
# level-synchronous breadth-first renumbering
# ---------------------------------------------------------------------------


def level_sync_renumber(
    enc: EncodedTree, *, lane: int = 1
) -> tuple[EncodedTree, np.ndarray]:
    """Renumber nodes level-contiguously, each level start ``lane``-aligned.

    BFS encoding is already level-ordered; this makes the level boundaries
    *addressable* — gaps introduced by the alignment are filled with phantom
    self-loop leaves (class 0, unreachable), exactly like
    :func:`repro.core.tree.pad_tree` — so a level-synchronous kernel can DMA
    level ``l`` as the aligned slab ``[offsets[l], offsets[l+1])``.

    Returns:
      (renumbered tree, offsets): ``offsets`` has length ``levels + 1``;
      ``offsets[-1]`` is the new node count.  With ``lane=1`` the
      renumbering is the identity for a freshly BFS-encoded tree.
    """
    depth = node_depths(enc)
    order = np.argsort(depth, kind="stable")  # stable: keeps BFS order per level
    levels = depth[order]
    n = enc.n_nodes
    new_pos = np.empty((n,), np.int64)
    offsets = []
    pos = 0
    for lvl in range(int(levels.max()) + 1 if n else 1):
        pos = _round_up(pos, lane)
        offsets.append(pos)
        members = order[levels == lvl]
        new_pos[members] = pos + np.arange(len(members))
        pos += len(members)
    n_new = _round_up(pos, lane)
    offsets.append(n_new)

    attr_idx = np.zeros((n_new,), np.int32)
    threshold = np.full((n_new,), np.inf, np.float32)
    child = np.arange(n_new, dtype=np.int32)  # phantoms self-loop
    class_val = np.zeros((n_new,), np.int32)
    leaf = enc.is_leaf_mask
    for i in range(n):
        p = int(new_pos[i])
        attr_idx[p] = enc.attr_idx[i]
        if leaf[i]:
            class_val[p] = enc.class_val[i]
        else:
            c = int(enc.child[i])
            assert new_pos[c + 1] == new_pos[c] + 1, "siblings split by renumber"
            threshold[p] = enc.threshold[i]
            child[p] = new_pos[c]
            class_val[p] = BOTTOM
    return EncodedTree(attr_idx, threshold, child, class_val), np.asarray(offsets, np.int64)


# ---------------------------------------------------------------------------
# the quantized stacked-forest container
# ---------------------------------------------------------------------------


def _int_dtype(max_value: int) -> np.dtype:
    if max_value <= np.iinfo(np.int8).max:
        return np.dtype(np.int8)
    if max_value <= np.iinfo(np.int16).max:
        return np.dtype(np.int16)
    return np.dtype(np.int32)


def calibration_attr_values(calibration, n_attrs: int) -> dict[int, np.ndarray]:
    """Per-attribute sorted finite value sets from an (M, A) calibration batch."""
    cal = np.asarray(calibration, np.float32)
    out = {}
    for a in range(min(n_attrs, cal.shape[1])):
        v = cal[:, a]
        out[a] = np.sort(np.unique(v[np.isfinite(v)]))
    return out


class QuantizedForest:
    """Compact device-ready stacked tables for the quantized fused kernels.

    The quantized dual of :class:`repro.kernels.tree_eval.ops.PackedForest`:
    same tree padding (phantom self-loop leaves to a lane-aligned common N)
    and the same (T, N) stacking, but no ``attr_select`` matrix — the
    quantized kernels gather each record's attribute directly — and every
    table stored at the narrowest dtype that holds it:

      ======================  =====================================
      table                   dtype
      ======================  =====================================
      ``attr_idx``  (T, N)    int8 (A ≤ 128) else int16
      ``threshold`` (T, N)    bf16/f16, f32 when any node falls back
      ``child``     (T, N)    int16 (N ≤ 32768) else int32
      ``class_val`` (T, N)    int8 (classes ≤ 127) else int16
      ``leaf_bits`` (T, N/8)  uint8 bit-packed leaf flags
      ======================  =====================================

    Args:
      forest: an ``EncodedForest`` (or anything exposing ``n_trees`` /
        ``n_nodes`` / ``max_depth`` / ``tree(i)``).
      n_attrs: record attribute count A (pre-padding).
      thr_dtype: threshold target, "bfloat16" | "float16".
      calibration: optional (M, A) records enabling split-safe threshold
        rounding (see module docstring); None = universal (always-exact).
      renumber: apply :func:`level_sync_renumber` per tree before packing
        (``level_offsets`` records the per-tree level slabs).
      max_depth: depth bound over the forest; default ``forest.max_depth``.
    """

    layout = "quant"

    def __init__(
        self,
        forest,
        n_attrs: int,
        *,
        thr_dtype: str = "bfloat16",
        calibration=None,
        renumber: bool = False,
        max_depth: int | None = None,
    ):
        if thr_dtype not in THR_DTYPES:
            raise ValueError(f"thr_dtype must be one of {sorted(THR_DTYPES)}")
        self.n_trees = int(forest.n_trees)
        self.n_attrs = int(n_attrs)
        self.thr_dtype = thr_dtype
        self.renumbered = bool(renumber)
        trees = [forest.tree(i) for i in range(self.n_trees)]
        self.level_offsets: list[np.ndarray] | None = None
        if renumber:
            pairs = [level_sync_renumber(t) for t in trees]
            trees = [t for t, _ in pairs]
            self.level_offsets = [off for _, off in pairs]
        self.logical_nodes = max(t.n_nodes for t in trees)
        self.max_depth = int(
            max_depth if max_depth is not None else max(tree_depth(t) for t in trees)
        )
        n_pad = _round_up(self.logical_nodes, LANE)
        a_pad = _round_up(self.n_attrs, LANE)
        penc = [pad_tree(t, n_pad) for t in trees]
        self.n_nodes = n_pad
        self.n_attrs_padded = a_pad

        attr_values = (
            calibration_attr_values(calibration, self.n_attrs)
            if calibration is not None else None
        )
        qthrs, safes = [], []
        for p in penc:
            q, safe = quantize_thresholds(
                p.threshold, p.is_leaf_mask, p.attr_idx,
                thr_dtype=thr_dtype, attr_values=attr_values,
            )
            qthrs.append(q)
            safes.append(safe)
        safe_all = np.stack(safes)
        self.fallback_nodes = int((~safe_all).sum())
        thr_f32 = np.stack([p.threshold for p in penc]).astype(np.float32)
        if self.fallback_nodes:
            # mixed storage: safe nodes keep their quantized-then-upcast
            # value (routing identical to the pure-quantized table), tight
            # nodes their exact f32 threshold
            thr = np.where(safe_all, np.stack(qthrs).astype(np.float32), thr_f32)
            self.thr_stored = "float32"
        else:
            thr = np.stack(qthrs)
            self.thr_stored = thr_dtype

        idx_dt = _int_dtype(max(self.n_attrs - 1, 1))
        child_dt = _int_dtype(n_pad - 1)
        cls_dt = _int_dtype(max(int(np.stack([p.class_val for p in penc]).max()), 1))
        self.attr_idx = jnp.asarray(np.stack([p.attr_idx for p in penc]).astype(idx_dt))
        self.threshold = jnp.asarray(thr)
        self.child = jnp.asarray(np.stack([p.child for p in penc]).astype(child_dt))
        self.class_val = jnp.asarray(
            np.stack([p.class_val for p in penc]).astype(cls_dt))
        self.leaf_bits = jnp.asarray(
            np.stack([pack_leaf_bits(p.is_leaf_mask) for p in penc]))

    @property
    def nbytes(self) -> int:
        """Total node-table bytes at *stored* widths (the honest footprint)."""
        return sum(
            int(x.size) * int(x.dtype.itemsize)
            for x in (self.attr_idx, self.threshold, self.child,
                      self.class_val, self.leaf_bits)
        )

    def bytes_report(self) -> dict:
        """Per-table byte/dtype breakdown for benchmarks and gauges."""
        tables = {
            "attr_idx": self.attr_idx, "threshold": self.threshold,
            "child": self.child, "class_val": self.class_val,
            "leaf_bits": self.leaf_bits,
        }
        return {
            "total_bytes": self.nbytes,
            "bytes_per_node": self.nbytes / (self.n_trees * self.n_nodes),
            "thr_requested": self.thr_dtype,
            "thr_stored": self.thr_stored,
            "fallback_nodes": self.fallback_nodes,
            "tables": {
                k: {"dtype": str(v.dtype), "bytes": int(v.size) * int(v.dtype.itemsize)}
                for k, v in tables.items()
            },
        }


def packed_forest_nbytes(pf) -> int:
    """Node-table bytes of a :class:`ops.PackedForest` (incl. ``attr_select``)."""
    return sum(
        int(x.size) * int(x.dtype.itemsize)
        for x in (pf.attr_select, pf.attr_idx, pf.threshold, pf.child, pf.class_val)
    )


def forest_table_bytes(target) -> int | None:
    """Node-table bytes of whatever a forest variant actually runs against."""
    nb = getattr(target, "nbytes", None)
    if nb is not None:
        return int(nb)
    tables = [getattr(target, k, None)
              for k in ("attr_idx", "threshold", "child", "class_val")]
    if any(t is None for t in tables):
        return None
    if hasattr(target, "attr_select"):
        tables.append(target.attr_select)
    return sum(int(np.asarray(t).nbytes) for t in tables)
