"""Public jit'd wrappers for the tree-evaluation Pallas kernels.

Handles everything the raw kernels assume away: lane/sublane padding of the
tree and record arrays, VMEM-budget-driven block-size selection, phantom-node
padding (the paper's half-warp phantom generalised to 128-lane tiles),
interpret-mode fallback off-TPU, and unpadding of results.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eval_speculative import sanitize_records
from repro.core.tree import EncodedTree, attr_select_matrix, pad_tree, tree_depth
from repro.kernels.tree_eval import kernel as _k
from repro.kernels.tree_eval.quant import QuantizedForest, packed_forest_nbytes

LANE = 128          # TPU vector lane count / MXU edge
SUBLANE = 8
VMEM_BUDGET = 8 * 2**20  # conservative half of a v5e core's ~16 MiB VMEM


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def choose_block_m(n_nodes: int, n_attrs: int, *, jump_mode: str = "gather") -> int:
    """Pick the record-tile height from a VMEM footprint model.

    Per-tile VMEM ≈ records (BM·A·4) + path copies (≈3·BM·N·4) + tables
    (A·N·4 + 3·N·4); the onehot jump additionally materialises a
    (BM, N, N) one-hot → dominate by BM·N²·4.  We take the largest power-of-
    two BM ≤ 1024 that fits the budget (≥ SUBLANE).
    """
    tables = n_attrs * n_nodes * 4 + 3 * n_nodes * 4
    bm = 1024
    while bm > SUBLANE:
        per_tile = bm * n_attrs * 4 + 3 * bm * n_nodes * 4
        if jump_mode == "onehot":
            per_tile += bm * n_nodes * n_nodes * 4
        if tables + per_tile <= VMEM_BUDGET:
            return bm
        bm //= 2
    return SUBLANE


class PackedTree:
    """Device-ready padded tree tables for the kernels."""

    def __init__(self, enc: EncodedTree, n_attrs: int, *, max_depth: int | None = None):
        self.logical_nodes = enc.n_nodes
        self.n_attrs = n_attrs
        self.max_depth = max_depth if max_depth is not None else tree_depth(enc)
        n_pad = _round_up(enc.n_nodes, LANE)
        a_pad = _round_up(n_attrs, LANE)
        penc = pad_tree(enc, n_pad)
        sel = np.zeros((a_pad, n_pad), np.float32)
        sel[:n_attrs] = attr_select_matrix(penc, n_attrs)
        self.n_nodes = n_pad
        self.n_attrs_padded = a_pad
        self.attr_select = jnp.asarray(sel)
        self.attr_idx = jnp.asarray(penc.attr_idx[None, :], jnp.int32)
        self.threshold = jnp.asarray(penc.threshold[None, :], jnp.float32)
        self.child = jnp.asarray(penc.child[None, :], jnp.int32)
        self.class_val = jnp.asarray(penc.class_val[None, :], jnp.int32)


def _pad_records(records: jax.Array, block_m: int, a_pad: int) -> tuple[jax.Array, int]:
    m, a = records.shape
    m_pad = _round_up(max(m, 1), block_m)
    out = jnp.zeros((m_pad, a_pad), records.dtype)
    out = out.at[:m, :a].set(records)
    return out, m


@functools.partial(
    jax.jit,
    static_argnames=("algorithm", "block_m", "jump_mode", "jumps", "max_depth", "interpret"),
)
def _tree_eval_padded(
    records,
    attr_select,
    attr_idx,
    threshold,
    child,
    class_val,
    *,
    algorithm: str,
    block_m: int,
    jump_mode: str,
    jumps: int,
    max_depth: int,
    interpret: bool,
):
    if algorithm == "speculative":
        out = _k.speculative_pallas(
            records,
            attr_select,
            threshold,
            child,
            class_val,
            total_jumps=jumps,
            block_m=block_m,
            jump_mode=jump_mode,
            interpret=interpret,
        )
    elif algorithm == "data_parallel":
        out = _k.data_parallel_pallas(
            records,
            attr_idx,
            threshold,
            child,
            class_val,
            max_depth=max_depth,
            block_m=block_m,
            interpret=interpret,
        )
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return out[:, 0]


def tree_eval(
    records,
    tree: PackedTree | EncodedTree,
    *,
    n_attrs: int | None = None,
    algorithm: str = "speculative",
    jump_mode: str = "gather",
    block_m: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate a classification tree over a record batch with a TPU kernel.

    Args:
      records: (M, A) float array (any float dtype; compared in f32).
      tree: an :class:`EncodedTree` (padded internally) or prebuilt
        :class:`PackedTree`.
      algorithm: "speculative" (Procedure 4/5) or "data_parallel" (Procedure 3).
      jump_mode: "gather" | "onehot" pointer-jump implementation.
      block_m: records per tile; default = VMEM-model choice.
      interpret: force Pallas interpret mode; default = auto (True off-TPU).

    Returns:
      (M,) int32 class assignments.
    """
    if isinstance(tree, EncodedTree):
        if n_attrs is None:
            n_attrs = int(np.asarray(records).shape[-1])
        tree = PackedTree(tree, n_attrs)
    if interpret is None:
        interpret = not on_tpu()
    if block_m is None:
        block_m = choose_block_m(tree.n_nodes, tree.n_attrs_padded, jump_mode=jump_mode)
    records = jnp.asarray(records)
    if algorithm == "speculative":
        # The speculative kernel evaluates every node with a records@S matmul;
        # non-finite attributes would poison whole rows (inf*0 = NaN).
        records = sanitize_records(records)
    padded, m = _pad_records(records, block_m, tree.n_attrs_padded)
    jumps = max(1, math.ceil(math.log2(max(tree.max_depth, 2))))
    out = _tree_eval_padded(
        padded,
        tree.attr_select,
        tree.attr_idx,
        tree.threshold,
        tree.child,
        tree.class_val,
        algorithm=algorithm,
        block_m=block_m,
        jump_mode=jump_mode,
        jumps=jumps,
        max_depth=tree.max_depth,
        interpret=interpret,
    )
    return out[:m]


def forest_eval(
    records,
    trees: list[PackedTree],
    **kw,
) -> jax.Array:
    """Per-tree kernel evaluation, (T, M). Trees may have different sizes."""
    return jnp.stack([tree_eval(records, t, **kw) for t in trees])


class PackedForest:
    """Device-ready stacked padded tables for the fused forest kernels.

    All T trees are padded to one lane-aligned node count (phantom self-loop
    leaves, §3.2) and their tables stacked along a leading tree axis:
    ``attr_select`` (T, A_pad, N_pad), the scalar tables (T, N_pad).  The
    fused kernels then evaluate the whole forest in one launch with the tree
    axis on the grid.

    Args:
      forest: an :class:`repro.core.forest.EncodedForest` (trees already
        stacked at a common logical node count) — or anything exposing its
        ``n_trees`` / ``n_nodes`` / ``max_depth`` / ``tree(i)`` surface.
      n_attrs: record attribute count A (pre-padding).
      max_depth: depth bound over the forest; default ``forest.max_depth``.
    """

    def __init__(self, forest, n_attrs: int, *, max_depth: int | None = None):
        self.n_trees = int(forest.n_trees)
        self.logical_nodes = int(forest.n_nodes)
        self.n_attrs = n_attrs
        self.max_depth = int(max_depth if max_depth is not None else forest.max_depth)
        n_pad = _round_up(self.logical_nodes, LANE)
        a_pad = _round_up(n_attrs, LANE)
        penc = [pad_tree(forest.tree(i), n_pad) for i in range(self.n_trees)]
        sel = np.zeros((self.n_trees, a_pad, n_pad), np.float32)
        for i, p in enumerate(penc):
            sel[i, :n_attrs] = attr_select_matrix(p, n_attrs)
        self.n_nodes = n_pad
        self.n_attrs_padded = a_pad
        self.attr_select = jnp.asarray(sel)
        self.attr_idx = jnp.asarray(np.stack([p.attr_idx for p in penc]), jnp.int32)
        self.threshold = jnp.asarray(np.stack([p.threshold for p in penc]), jnp.float32)
        self.child = jnp.asarray(np.stack([p.child for p in penc]), jnp.int32)
        self.class_val = jnp.asarray(np.stack([p.class_val for p in penc]), jnp.int32)

    @property
    def nbytes(self) -> int:
        """Total node-table bytes (incl. ``attr_select`` — the f32 baseline
        the quantized layouts are benchmarked against)."""
        return packed_forest_nbytes(self)


@functools.partial(
    jax.jit,
    static_argnames=("algorithm", "block_m", "jump_mode", "jumps", "max_depth", "interpret"),
)
def _forest_eval_padded(
    records,
    attr_select,
    attr_idx,
    threshold,
    child,
    class_val,
    *,
    algorithm: str,
    block_m: int,
    jump_mode: str,
    jumps: int,
    max_depth: int,
    interpret: bool,
):
    if algorithm == "speculative":
        out = _k.fused_speculative_pallas(
            records,
            attr_select,
            threshold,
            child,
            class_val,
            total_jumps=jumps,
            block_m=block_m,
            jump_mode=jump_mode,
            interpret=interpret,
        )
    elif algorithm == "data_parallel":
        out = _k.fused_data_parallel_pallas(
            records,
            attr_idx,
            threshold,
            child,
            class_val,
            max_depth=max_depth,
            block_m=block_m,
            interpret=interpret,
        )
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return out[:, :, 0]


def forest_eval_fused(
    records,
    forest: "PackedForest | object",
    *,
    n_attrs: int | None = None,
    algorithm: str = "speculative",
    jump_mode: str = "gather",
    block_m: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate a whole forest with one fused Pallas launch.

    Args:
      records: (M, A) float array (any float dtype; compared in f32).
      forest: an ``EncodedForest`` (packed internally) or prebuilt
        :class:`PackedForest`.
      algorithm: "speculative" (Procedure 4/5) or "data_parallel" (Procedure 3).
      jump_mode: "gather" | "onehot" pointer-jump implementation.
      block_m: records per tile; default = VMEM-model choice.
      interpret: force Pallas interpret mode; default = auto (True off-TPU).

    Returns:
      (T, M) int32 per-tree class assignments, bit-identical to running
      :func:`tree_eval` tree by tree.
    """
    if not isinstance(forest, PackedForest):
        if n_attrs is None:
            n_attrs = int(np.asarray(records).shape[-1])
        forest = PackedForest(forest, n_attrs)
    if interpret is None:
        interpret = not on_tpu()
    if block_m is None:
        block_m = choose_block_m(forest.n_nodes, forest.n_attrs_padded, jump_mode=jump_mode)
    records = jnp.asarray(records)
    if algorithm == "speculative":
        # The fused speculative kernel evaluates every node with a per-tree
        # records@S matmul; non-finite attributes poison rows (inf*0 = NaN).
        records = sanitize_records(records)
    padded, m = _pad_records(records, block_m, forest.n_attrs_padded)
    jumps = max(1, math.ceil(math.log2(max(forest.max_depth, 2))))
    out = _forest_eval_padded(
        padded,
        forest.attr_select,
        forest.attr_idx,
        forest.threshold,
        forest.child,
        forest.class_val,
        algorithm=algorithm,
        block_m=block_m,
        jump_mode=jump_mode,
        jumps=jumps,
        max_depth=forest.max_depth,
        interpret=interpret,
    )
    return out[:, :m]


@functools.partial(
    jax.jit,
    static_argnames=("algorithm", "block_m", "jumps", "max_depth", "interpret"),
)
def _quant_forest_eval_padded(
    records,
    attr_idx,
    threshold,
    child,
    class_val,
    *,
    algorithm: str,
    block_m: int,
    jumps: int,
    max_depth: int,
    interpret: bool,
):
    if algorithm == "speculative":
        out = _k.fused_speculative_q_pallas(
            records, attr_idx, threshold, child, class_val,
            total_jumps=jumps, block_m=block_m, interpret=interpret,
        )
    elif algorithm == "data_parallel":
        out = _k.fused_data_parallel_q_pallas(
            records, attr_idx, threshold, child, class_val,
            max_depth=max_depth, block_m=block_m, interpret=interpret,
        )
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return out[:, :, 0]


def forest_eval_fused_q(
    records,
    forest: "QuantizedForest | object",
    *,
    n_attrs: int | None = None,
    algorithm: str = "speculative",
    thr_dtype: str = "bfloat16",
    calibration=None,
    block_m: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate a whole forest with one fused launch over *quantized* tables.

    The compact-layout dual of :func:`forest_eval_fused`: node tables arrive
    as int8/int16 indices and bf16/f16 split-safe thresholds (see
    :mod:`repro.kernels.tree_eval.quant`) and node evaluation gathers each
    record's attribute directly instead of multiplying by ``attr_select``.

    Args:
      records: (M, A) float array (compared in f32 after upcast).
      forest: a prebuilt :class:`QuantizedForest`, or an ``EncodedForest``
        quantized here (``thr_dtype``/``calibration`` control the rounding;
        ``calibration=None`` — the default — quantizes only thresholds whose
        cast round-trips exactly, so results are bit-exact for *any* input).
      algorithm: "speculative" (Procedure 4/5) or "data_parallel" (Procedure 3).
      block_m: records per tile; default = VMEM-model choice.
      interpret: force Pallas interpret mode; default = auto (True off-TPU).

    Returns:
      (T, M) int32 per-tree class assignments.
    """
    if not isinstance(forest, QuantizedForest):
        if n_attrs is None:
            n_attrs = int(np.asarray(records).shape[-1])
        forest = QuantizedForest(
            forest, n_attrs, thr_dtype=thr_dtype, calibration=calibration
        )
    if interpret is None:
        interpret = not on_tpu()
    if block_m is None:
        block_m = choose_block_m(forest.n_nodes, forest.n_attrs_padded, jump_mode="gather")
    records = jnp.asarray(records)
    padded, m = _pad_records(records, block_m, forest.n_attrs_padded)
    jumps = max(1, math.ceil(math.log2(max(forest.max_depth, 2))))
    out = _quant_forest_eval_padded(
        padded,
        forest.attr_idx,
        forest.threshold,
        forest.child,
        forest.class_val,
        algorithm=algorithm,
        block_m=block_m,
        jumps=jumps,
        max_depth=forest.max_depth,
        interpret=interpret,
    )
    return out[:, :m]


@functools.partial(
    jax.jit,
    static_argnames=(
        "algorithm", "block_m", "jump_mode", "jumps", "max_depth", "c_pad", "interpret",
    ),
)
def _forest_votes_padded(
    records,
    attr_select,
    attr_idx,
    threshold,
    child,
    class_val,
    *,
    algorithm: str,
    block_m: int,
    jump_mode: str,
    jumps: int,
    max_depth: int,
    c_pad: int,
    interpret: bool,
):
    if algorithm == "speculative":
        return _k.fused_votes_speculative_pallas(
            records,
            attr_select,
            threshold,
            child,
            class_val,
            n_classes=c_pad,
            total_jumps=jumps,
            block_m=block_m,
            jump_mode=jump_mode,
            interpret=interpret,
        )
    if algorithm == "data_parallel":
        return _k.fused_votes_data_parallel_pallas(
            records,
            attr_idx,
            threshold,
            child,
            class_val,
            n_classes=c_pad,
            max_depth=max_depth,
            block_m=block_m,
            interpret=interpret,
        )
    raise ValueError(f"unknown algorithm {algorithm!r}")


def forest_votes_fused(
    records,
    forest: "PackedForest | object",
    *,
    n_classes: int,
    n_attrs: int | None = None,
    algorithm: str = "speculative",
    jump_mode: str = "gather",
    block_m: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Accumulate the forest's class votes in one fused Pallas launch.

    The per-tree classes stay inside VMEM: each tree grid-step adds its
    one-hot vote into a persistent (block_m, C_pad) output tile, so the
    (T, M) class matrix is never materialised in HBM.  This is the stage
    primitive of the cascade evaluator.

    Returns:
      (M, n_classes) int32 vote counts; ``argmax`` along the last axis
      reproduces :func:`repro.core.forest.majority_vote` exactly.
    """
    if not isinstance(forest, PackedForest):
        if n_attrs is None:
            n_attrs = int(np.asarray(records).shape[-1])
        forest = PackedForest(forest, n_attrs)
    if interpret is None:
        interpret = not on_tpu()
    if block_m is None:
        block_m = choose_block_m(forest.n_nodes, forest.n_attrs_padded, jump_mode=jump_mode)
    c_pad = _round_up(max(int(n_classes), 2), LANE)
    records = jnp.asarray(records)
    if algorithm == "speculative":
        # Same records@S contract as forest_eval_fused (inf*0 = NaN).
        records = sanitize_records(records)
    padded, m = _pad_records(records, block_m, forest.n_attrs_padded)
    jumps = max(1, math.ceil(math.log2(max(forest.max_depth, 2))))
    out = _forest_votes_padded(
        padded,
        forest.attr_select,
        forest.attr_idx,
        forest.threshold,
        forest.child,
        forest.class_val,
        algorithm=algorithm,
        block_m=block_m,
        jump_mode=jump_mode,
        jumps=jumps,
        max_depth=forest.max_depth,
        c_pad=c_pad,
        interpret=interpret,
    )
    return out[:m, :n_classes]


# ---------------------------------------------------------------------------
# Variant registry (consumed by repro.tune)
# ---------------------------------------------------------------------------
#
# Every registered variant is a semantically identical evaluator of the
# branchless encoded tree with a uniform calling convention:
#
#     fn(records, enc: EncodedTree, *, max_depth: int, **params) -> (M,) int32
#
# ``params`` only ever contains keys named in ``tunables``; the tuner
# enumerates (variant × parameter grid) candidates from this table and the
# dispatch layer replays the winning entry.


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """One evaluator implementation plus the knobs the tuner may sweep.

    Attributes:
      name: registry key, e.g. ``"pallas_speculative_onehot"``.
      algorithm: "speculative" (Procedure 4/5) or "data_parallel" (Procedure 3)
        — links the variant to the §3.6 runtime model (T₅ vs T₃).
      engine: "pallas" (TPU kernel path) or "jnp" (XLA-compiled host/TPU path).
      jump_mode: node-evaluation formulation, "gather" or "onehot" (MXU).
      tunables: names of the free parameters, e.g. ("block_m",).
      fn: the evaluator callable (uniform signature above).
    """

    name: str
    algorithm: str
    engine: str
    jump_mode: str
    tunables: tuple[str, ...]
    fn: Callable


VARIANTS: dict[str, VariantSpec] = {}


def register_variant(spec: VariantSpec) -> VariantSpec:
    if spec.name in VARIANTS:
        raise ValueError(f"variant {spec.name!r} already registered")
    VARIANTS[spec.name] = spec
    return spec


def get_variant(name: str) -> VariantSpec:
    try:
        return VARIANTS[name]
    except KeyError:
        raise KeyError(
            f"unknown variant {name!r}; registered: {sorted(VARIANTS)}"
        ) from None


def list_variants(*, engine: str | None = None, algorithm: str | None = None) -> list[VariantSpec]:
    out = [
        s
        for s in VARIANTS.values()
        if (engine is None or s.engine == engine)
        and (algorithm is None or s.algorithm == algorithm)
    ]
    return sorted(out, key=lambda s: s.name)


def _pallas_fn(algorithm: str, jump_mode: str) -> Callable:
    def fn(records, enc, *, max_depth=None, **params):
        del max_depth  # PackedTree derives it from the encoding
        return tree_eval(
            records,
            enc,
            algorithm=algorithm,
            jump_mode=jump_mode,
            block_m=params.get("block_m"),
        )

    return fn


def _jnp_speculative_fn(jump_mode: str) -> Callable:
    from repro.core.eval_speculative import eval_speculative_tree

    def fn(records, enc, *, max_depth, **params):
        return eval_speculative_tree(
            enc,
            records,
            max_depth=max_depth,
            jumps_per_round=int(params.get("jumps_per_round", 2)),
            use_onehot_matmul=(jump_mode == "onehot"),
        )

    return fn


def _jnp_data_parallel_fn(records, enc, *, max_depth, **params):
    from repro.core.eval_dataparallel import eval_data_parallel_tree

    del params
    return eval_data_parallel_tree(enc, records, max_depth=max_depth)


for _alg, _jm in (("speculative", "gather"), ("speculative", "onehot"), ("data_parallel", "gather")):
    register_variant(
        VariantSpec(
            name=f"pallas_{_alg}" + (f"_{_jm}" if _alg == "speculative" else ""),
            algorithm=_alg,
            engine="pallas",
            jump_mode=_jm,
            tunables=("block_m",),
            fn=_pallas_fn(_alg, _jm),
        )
    )

for _jm in ("gather", "onehot"):
    register_variant(
        VariantSpec(
            name=f"jnp_speculative_{_jm}",
            algorithm="speculative",
            engine="jnp",
            jump_mode=_jm,
            tunables=("jumps_per_round",),
            fn=_jnp_speculative_fn(_jm),
        )
    )

register_variant(
    VariantSpec(
        name="jnp_data_parallel",
        algorithm="data_parallel",
        engine="jnp",
        jump_mode="gather",
        tunables=(),
        fn=_jnp_data_parallel_fn,
    )
)


# ---------------------------------------------------------------------------
# Forest variant registry (consumed by repro.tune's forest-level tuner)
# ---------------------------------------------------------------------------
#
# A *forest* variant evaluates all T trees of a stacked forest at once with a
# uniform calling convention:
#
#     fn(records, forest, *, max_depth: int, **params) -> (T, M) int32
#
# where ``forest`` is an EncodedForest (or PackedForest for the fused
# family).  Two families are registered here; the third family the forest
# tuner considers — ``per_tree``, a vector of per-tree winners — is not a
# single callable and lives in ``repro.tune.dispatch.ForestTunedEvaluator``.

# Family name the forest tuner uses for the per-tree-variant-vector path;
# kept here so the cache vocabulary is defined next to the registry.
PER_TREE_FAMILY = "per_tree"


@dataclasses.dataclass(frozen=True)
class ForestVariantSpec:
    """One whole-forest evaluator plus the knobs the tuner may sweep.

    Attributes:
      name: registry key, e.g. ``"forest_fused_speculative_onehot"``.
      family: "fused" (one Pallas launch, tree axis on the grid) or "vmap"
        (the stacked jnp formulation ``vmap``-ed over the tree axis).
      algorithm: "speculative" or "data_parallel" (§3.6 T₅ vs T₃ per shard).
      engine: "pallas" or "jnp" (same meaning as :class:`VariantSpec`).
      jump_mode: "gather" | "onehot" node-evaluation/jump formulation.
      tunables: names of the free parameters, e.g. ("block_m",).
      fn: the evaluator callable (uniform signature above).
      layout: node-table layout family — "f32" (the full-width
        :class:`PackedForest` tables) or "quant" (the compact
        :class:`QuantizedForest` SoA layout).  Quantized layouts only enter
        the search space when a caller opts in
        (``forest_search_space(..., layouts=...)``), and the ``thr_dtype``
        tunable is consumed at *packing* time, not passed to the kernel.
    """

    name: str
    family: str
    algorithm: str
    engine: str
    jump_mode: str
    tunables: tuple[str, ...]
    fn: Callable
    layout: str = "f32"


FOREST_VARIANTS: dict[str, ForestVariantSpec] = {}


def register_forest_variant(spec: ForestVariantSpec) -> ForestVariantSpec:
    if spec.name in FOREST_VARIANTS:
        raise ValueError(f"forest variant {spec.name!r} already registered")
    FOREST_VARIANTS[spec.name] = spec
    return spec


def get_forest_variant(name: str) -> ForestVariantSpec:
    try:
        return FOREST_VARIANTS[name]
    except KeyError:
        raise KeyError(
            f"unknown forest variant {name!r}; registered: {sorted(FOREST_VARIANTS)}"
        ) from None


def list_forest_variants(
    *, engine: str | None = None, family: str | None = None
) -> list[ForestVariantSpec]:
    out = [
        s
        for s in FOREST_VARIANTS.values()
        if (engine is None or s.engine == engine)
        and (family is None or s.family == family)
    ]
    return sorted(out, key=lambda s: s.name)


def _forest_tables(forest):
    return (
        jnp.asarray(forest.attr_idx, jnp.int32),
        jnp.asarray(forest.threshold, jnp.float32),
        jnp.asarray(forest.child, jnp.int32),
        jnp.asarray(forest.class_val, jnp.int32),
    )


def _vmap_speculative_fn(jump_mode: str) -> Callable:
    def fn(records, forest, *, max_depth, **params):
        from repro.core.eval_speculative import eval_speculative

        rec = jnp.asarray(records, jnp.float32)
        jumps = int(params.get("jumps_per_round", 2))

        def one(a, t, c, k):
            return eval_speculative(
                rec, a, t, c, k,
                max_depth=max_depth,
                jumps_per_round=jumps,
                use_onehot_matmul=(jump_mode == "onehot"),
            )

        return jax.vmap(one)(*_forest_tables(forest))

    return fn


def _vmap_data_parallel_fn(records, forest, *, max_depth, **params):
    from repro.core.eval_dataparallel import eval_data_parallel

    del params
    rec = jnp.asarray(records, jnp.float32)

    def one(a, t, c, k):
        return eval_data_parallel(rec, a, t, c, k, max_depth=max_depth)

    return jax.vmap(one)(*_forest_tables(forest))


def _fused_fn(algorithm: str, jump_mode: str) -> Callable:
    def fn(records, forest, *, max_depth=None, **params):
        del max_depth  # PackedForest derives it from the encodings
        return forest_eval_fused(
            records,
            forest,
            algorithm=algorithm,
            jump_mode=jump_mode,
            block_m=params.get("block_m"),
        )

    return fn


def _fused_q_fn(algorithm: str) -> Callable:
    def fn(records, forest, *, max_depth=None, **params):
        del max_depth  # QuantizedForest derives it from the encodings
        return forest_eval_fused_q(
            records,
            forest,
            algorithm=algorithm,
            thr_dtype=params.get("thr_dtype", "bfloat16"),
            block_m=params.get("block_m"),
        )

    return fn


for _alg in ("speculative", "data_parallel"):
    register_forest_variant(
        ForestVariantSpec(
            name=f"forest_fused_{_alg}_q",
            family="fused",
            algorithm=_alg,
            engine="pallas",
            jump_mode="gather",
            tunables=("block_m", "thr_dtype"),
            fn=_fused_q_fn(_alg),
            layout="quant",
        )
    )


for _alg, _jm in (("speculative", "gather"), ("speculative", "onehot"), ("data_parallel", "gather")):
    _suffix = f"_{_jm}" if _alg == "speculative" else ""
    register_forest_variant(
        ForestVariantSpec(
            name=f"forest_fused_{_alg}" + _suffix,
            family="fused",
            algorithm=_alg,
            engine="pallas",
            jump_mode=_jm,
            tunables=("block_m",),
            fn=_fused_fn(_alg, _jm),
        )
    )
    register_forest_variant(
        ForestVariantSpec(
            name=f"forest_vmap_{_alg}" + _suffix,
            family="vmap",
            algorithm=_alg,
            engine="jnp",
            jump_mode=_jm,
            tunables=("jumps_per_round",) if _alg == "speculative" else (),
            fn=(
                _vmap_speculative_fn(_jm)
                if _alg == "speculative"
                else _vmap_data_parallel_fn
            ),
        )
    )
