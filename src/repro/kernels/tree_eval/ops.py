"""Public jit'd wrappers for the tree-evaluation Pallas kernels.

Handles everything the raw kernels assume away: lane/sublane padding of the
tree and record arrays, VMEM-budget-driven block-size selection, phantom-node
padding (the paper's half-warp phantom generalised to 128-lane tiles),
interpret-mode fallback off-TPU, and unpadding of results.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import EncodedTree, attr_select_matrix, pad_tree, tree_depth
from repro.kernels.tree_eval import kernel as _k

LANE = 128          # TPU vector lane count / MXU edge
SUBLANE = 8
VMEM_BUDGET = 8 * 2**20  # conservative half of a v5e core's ~16 MiB VMEM


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def choose_block_m(n_nodes: int, n_attrs: int, *, jump_mode: str = "gather") -> int:
    """Pick the record-tile height from a VMEM footprint model.

    Per-tile VMEM ≈ records (BM·A·4) + path copies (≈3·BM·N·4) + tables
    (A·N·4 + 3·N·4); the onehot jump additionally materialises a
    (BM, N, N) one-hot → dominate by BM·N²·4.  We take the largest power-of-
    two BM ≤ 1024 that fits the budget (≥ SUBLANE).
    """
    tables = n_attrs * n_nodes * 4 + 3 * n_nodes * 4
    bm = 1024
    while bm > SUBLANE:
        per_tile = bm * n_attrs * 4 + 3 * bm * n_nodes * 4
        if jump_mode == "onehot":
            per_tile += bm * n_nodes * n_nodes * 4
        if tables + per_tile <= VMEM_BUDGET:
            return bm
        bm //= 2
    return SUBLANE


class PackedTree:
    """Device-ready padded tree tables for the kernels."""

    def __init__(self, enc: EncodedTree, n_attrs: int, *, max_depth: int | None = None):
        self.logical_nodes = enc.n_nodes
        self.n_attrs = n_attrs
        self.max_depth = max_depth if max_depth is not None else tree_depth(enc)
        n_pad = _round_up(enc.n_nodes, LANE)
        a_pad = _round_up(n_attrs, LANE)
        penc = pad_tree(enc, n_pad)
        sel = np.zeros((a_pad, n_pad), np.float32)
        sel[:n_attrs] = attr_select_matrix(penc, n_attrs)
        self.n_nodes = n_pad
        self.n_attrs_padded = a_pad
        self.attr_select = jnp.asarray(sel)
        self.attr_idx = jnp.asarray(penc.attr_idx[None, :], jnp.int32)
        self.threshold = jnp.asarray(penc.threshold[None, :], jnp.float32)
        self.child = jnp.asarray(penc.child[None, :], jnp.int32)
        self.class_val = jnp.asarray(penc.class_val[None, :], jnp.int32)


def _pad_records(records: jax.Array, block_m: int, a_pad: int) -> tuple[jax.Array, int]:
    m, a = records.shape
    m_pad = _round_up(max(m, 1), block_m)
    out = jnp.zeros((m_pad, a_pad), records.dtype)
    out = out.at[:m, :a].set(records)
    return out, m


@functools.partial(
    jax.jit,
    static_argnames=("algorithm", "block_m", "jump_mode", "jumps", "max_depth", "interpret"),
)
def _tree_eval_padded(
    records,
    attr_select,
    attr_idx,
    threshold,
    child,
    class_val,
    *,
    algorithm: str,
    block_m: int,
    jump_mode: str,
    jumps: int,
    max_depth: int,
    interpret: bool,
):
    if algorithm == "speculative":
        out = _k.speculative_pallas(
            records,
            attr_select,
            threshold,
            child,
            class_val,
            total_jumps=jumps,
            block_m=block_m,
            jump_mode=jump_mode,
            interpret=interpret,
        )
    elif algorithm == "data_parallel":
        out = _k.data_parallel_pallas(
            records,
            attr_idx,
            threshold,
            child,
            class_val,
            max_depth=max_depth,
            block_m=block_m,
            interpret=interpret,
        )
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return out[:, 0]


def tree_eval(
    records,
    tree: PackedTree | EncodedTree,
    *,
    n_attrs: int | None = None,
    algorithm: str = "speculative",
    jump_mode: str = "gather",
    block_m: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate a classification tree over a record batch with a TPU kernel.

    Args:
      records: (M, A) float array (any float dtype; compared in f32).
      tree: an :class:`EncodedTree` (padded internally) or prebuilt
        :class:`PackedTree`.
      algorithm: "speculative" (Procedure 4/5) or "data_parallel" (Procedure 3).
      jump_mode: "gather" | "onehot" pointer-jump implementation.
      block_m: records per tile; default = VMEM-model choice.
      interpret: force Pallas interpret mode; default = auto (True off-TPU).

    Returns:
      (M,) int32 class assignments.
    """
    if isinstance(tree, EncodedTree):
        if n_attrs is None:
            n_attrs = int(np.asarray(records).shape[-1])
        tree = PackedTree(tree, n_attrs)
    if interpret is None:
        interpret = not on_tpu()
    if block_m is None:
        block_m = choose_block_m(tree.n_nodes, tree.n_attrs_padded, jump_mode=jump_mode)
    records = jnp.asarray(records)
    padded, m = _pad_records(records, block_m, tree.n_attrs_padded)
    jumps = max(1, math.ceil(math.log2(max(tree.max_depth, 2))))
    out = _tree_eval_padded(
        padded,
        tree.attr_select,
        tree.attr_idx,
        tree.threshold,
        tree.child,
        tree.class_val,
        algorithm=algorithm,
        block_m=block_m,
        jump_mode=jump_mode,
        jumps=jumps,
        max_depth=tree.max_depth,
        interpret=interpret,
    )
    return out[:m]


def forest_eval(
    records,
    trees: list[PackedTree],
    **kw,
) -> jax.Array:
    """Per-tree kernel evaluation, (T, M). Trees may have different sizes."""
    return jnp.stack([tree_eval(records, t, **kw) for t in trees])


# ---------------------------------------------------------------------------
# Variant registry (consumed by repro.tune)
# ---------------------------------------------------------------------------
#
# Every registered variant is a semantically identical evaluator of the
# branchless encoded tree with a uniform calling convention:
#
#     fn(records, enc: EncodedTree, *, max_depth: int, **params) -> (M,) int32
#
# ``params`` only ever contains keys named in ``tunables``; the tuner
# enumerates (variant × parameter grid) candidates from this table and the
# dispatch layer replays the winning entry.


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """One evaluator implementation plus the knobs the tuner may sweep.

    Attributes:
      name: registry key, e.g. ``"pallas_speculative_onehot"``.
      algorithm: "speculative" (Procedure 4/5) or "data_parallel" (Procedure 3)
        — links the variant to the §3.6 runtime model (T₅ vs T₃).
      engine: "pallas" (TPU kernel path) or "jnp" (XLA-compiled host/TPU path).
      jump_mode: node-evaluation formulation, "gather" or "onehot" (MXU).
      tunables: names of the free parameters, e.g. ("block_m",).
      fn: the evaluator callable (uniform signature above).
    """

    name: str
    algorithm: str
    engine: str
    jump_mode: str
    tunables: tuple[str, ...]
    fn: Callable


VARIANTS: dict[str, VariantSpec] = {}


def register_variant(spec: VariantSpec) -> VariantSpec:
    if spec.name in VARIANTS:
        raise ValueError(f"variant {spec.name!r} already registered")
    VARIANTS[spec.name] = spec
    return spec


def get_variant(name: str) -> VariantSpec:
    try:
        return VARIANTS[name]
    except KeyError:
        raise KeyError(
            f"unknown variant {name!r}; registered: {sorted(VARIANTS)}"
        ) from None


def list_variants(*, engine: str | None = None, algorithm: str | None = None) -> list[VariantSpec]:
    out = [
        s
        for s in VARIANTS.values()
        if (engine is None or s.engine == engine)
        and (algorithm is None or s.algorithm == algorithm)
    ]
    return sorted(out, key=lambda s: s.name)


def _pallas_fn(algorithm: str, jump_mode: str) -> Callable:
    def fn(records, enc, *, max_depth=None, **params):
        del max_depth  # PackedTree derives it from the encoding
        return tree_eval(
            records,
            enc,
            algorithm=algorithm,
            jump_mode=jump_mode,
            block_m=params.get("block_m"),
        )

    return fn


def _jnp_speculative_fn(jump_mode: str) -> Callable:
    from repro.core.eval_speculative import eval_speculative_tree

    def fn(records, enc, *, max_depth, **params):
        return eval_speculative_tree(
            enc,
            records,
            max_depth=max_depth,
            jumps_per_round=int(params.get("jumps_per_round", 2)),
            use_onehot_matmul=(jump_mode == "onehot"),
        )

    return fn


def _jnp_data_parallel_fn(records, enc, *, max_depth, **params):
    from repro.core.eval_dataparallel import eval_data_parallel_tree

    del params
    return eval_data_parallel_tree(enc, records, max_depth=max_depth)


for _alg, _jm in (("speculative", "gather"), ("speculative", "onehot"), ("data_parallel", "gather")):
    register_variant(
        VariantSpec(
            name=f"pallas_{_alg}" + (f"_{_jm}" if _alg == "speculative" else ""),
            algorithm=_alg,
            engine="pallas",
            jump_mode=_jm,
            tunables=("block_m",),
            fn=_pallas_fn(_alg, _jm),
        )
    )

for _jm in ("gather", "onehot"):
    register_variant(
        VariantSpec(
            name=f"jnp_speculative_{_jm}",
            algorithm="speculative",
            engine="jnp",
            jump_mode=_jm,
            tunables=("jumps_per_round",),
            fn=_jnp_speculative_fn(_jm),
        )
    )

register_variant(
    VariantSpec(
        name="jnp_data_parallel",
        algorithm="data_parallel",
        engine="jnp",
        jump_mode="gather",
        tunables=(),
        fn=_jnp_data_parallel_fn,
    )
)
