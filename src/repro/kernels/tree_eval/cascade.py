"""Early-exit cascade evaluation of a packed forest (staged majority vote).

The paper's speculative decomposition spends SIMD lanes on work that *might*
be needed; the cascade is the dual lever at forest scale — stop spending
lanes on work that *cannot change the answer*.  Trees are ordered by
discriminative power and evaluated in stages; after each stage every
record's vote margin (top-1 minus top-2 vote count) is compared against a
confidence bound derived from the number of remaining trees:

    margin > bound * remaining

With ``bound = 1.0`` the inequality is exact — even if every remaining tree
voted for the runner-up class the leader could not be overtaken (strict
``>`` matters: the majority-vote argmax breaks ties toward the *lower*
class index, so a tied finish may flip the answer and must not exit).
Records that clear the bound exit; the survivors are **compacted** into a
dense tile (gather), the next stage runs only on them, and their votes are
scattered back.  Masked lanes therefore stop costing kernel time instead of
idling inside the tile.

``bound=None`` disables the exit entirely, making the cascade bit-identical
to ``majority_vote(eval_forest_tuned(...))`` (vote counts are invariant
under tree reordering).  ``bound < 1`` trades exactness for speed; the
per-record ``confidence`` output reports how decided each answer is.

An optional per-call ``deadline_ms`` gives *anytime* semantics: evaluation
stops at the deepest stage the remaining latency budget allows (stage 0
always runs) and the partial-margin confidence is reported for records the
truncated stages never re-examined.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels.tree_eval import ops as _ops
from repro.kernels.tree_eval.ref import forest_eval_ref

# Vote margins are integer counts bounded by the forest size; a coarse
# power-of-two grid keeps the exit-margin histograms readable at any T.
_MARGIN_BOUNDARIES = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)

# Family name the class-level tuner uses for the plain "evaluate everything,
# then majority-vote" path (no early exit); defined next to the cascade
# registry so the cache vocabulary for class-level winners lives in one place.
MAJORITY_FAMILY = "forest_majority"

CASCADE_FAMILY = "cascade"


# ---------------------------------------------------------------------------
# Plan: tree order + stage geometry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CascadePlan:
    """Tree evaluation order and how many trees each stage takes.

    ``order`` is a permutation of the forest's tree indices, most
    discriminative first; ``stage_sizes`` partitions it into consecutive
    stages.  The first stage is the *exit-enabling prefix*: with ``k`` trees
    evaluated and ``T - k`` remaining, an exit requires
    ``margin > bound * (T - k)`` and the margin after ``k`` unanimous trees
    is at most ``k``, so the smallest useful first stage is
    ``k_min = floor(bound * T / (1 + bound)) + 1``.
    """

    order: tuple[int, ...]
    stage_sizes: tuple[int, ...]

    def __post_init__(self):
        if sum(self.stage_sizes) != len(self.order):
            raise ValueError(
                f"stage_sizes {self.stage_sizes} must partition the "
                f"{len(self.order)}-tree order"
            )
        if any(s <= 0 for s in self.stage_sizes):
            raise ValueError(f"stage sizes must be positive: {self.stage_sizes}")
        if sorted(self.order) != list(range(len(self.order))):
            raise ValueError("order must be a permutation of range(n_trees)")

    @property
    def n_trees(self) -> int:
        return len(self.order)

    @property
    def n_stages(self) -> int:
        return len(self.stage_sizes)

    def stage_trees(self, s: int) -> tuple[int, ...]:
        start = sum(self.stage_sizes[:s])
        return self.order[start : start + self.stage_sizes[s]]


def exit_enabling_prefix(n_trees: int, bound: float) -> int:
    """Smallest first-stage size after which an early exit is possible."""
    k = int(np.floor(bound * n_trees / (1.0 + bound))) + 1
    return min(max(k, 1), n_trees)


def rank_trees(forest, records, *, n_classes: int, sample: int = 512) -> tuple[int, ...]:
    """Order trees by agreement with the full-forest majority vote.

    A tree that usually agrees with the ensemble's final answer drives the
    margin up fastest when placed early, which is exactly what the exit
    bound rewards.  Ranked on (a sample of) a calibration batch via the
    reference evaluator; stable sort keeps the original order among ties so
    plans are deterministic.
    """
    rec = np.asarray(records, np.float32)
    if rec.ndim != 2 or rec.shape[0] == 0:
        return tuple(range(int(forest.n_trees)))
    rec = rec[: max(1, int(sample))]
    per_tree = np.asarray(
        forest_eval_ref(
            jnp.asarray(rec),
            jnp.asarray(forest.attr_idx, jnp.int32),
            jnp.asarray(forest.threshold, jnp.float32),
            jnp.asarray(forest.child, jnp.int32),
            jnp.asarray(forest.class_val, jnp.int32),
            max_depth=int(forest.max_depth),
        )
    )  # (T, M)
    c = max(int(n_classes), int(per_tree.max(initial=0)) + 1, 2)
    votes = np.zeros((rec.shape[0], c), np.int32)
    for t in range(per_tree.shape[0]):
        votes[np.arange(rec.shape[0]), per_tree[t]] += 1
    maj = votes.argmax(axis=1)
    agreement = (per_tree == maj[None, :]).mean(axis=1)
    return tuple(int(i) for i in np.argsort(-agreement, kind="stable"))


def plan_cascade(
    forest,
    records=None,
    *,
    n_classes: int,
    stages: int = 2,
    bound: float | None = 1.0,
    sample: int = 512,
    order: tuple[int, ...] | None = None,
) -> CascadePlan:
    """Build a :class:`CascadePlan` for ``forest``.

    Args:
      records: optional calibration batch used to rank trees by
        discriminative power (see :func:`rank_trees`); without it trees run
        in their stored order.
      stages: requested stage count (clamped to what the forest admits).
      bound: the exit bound the plan should enable; sizes the first stage at
        the exit-enabling prefix.  ``None`` plans as if ``1.0``.
      order: explicit tree order overriding calibration.
    """
    t = int(forest.n_trees)
    if order is None:
        if records is not None:
            order = rank_trees(forest, records, n_classes=n_classes, sample=sample)
        else:
            order = tuple(range(t))
    order = tuple(int(i) for i in order)
    if sorted(order) != list(range(t)):
        raise ValueError("order must be a permutation of the forest's tree indices")
    stages = max(1, min(int(stages), t))
    b = 1.0 if bound is None else float(bound)
    if b <= 0.0:
        raise ValueError(f"bound must be positive (or None), got {bound}")
    if stages == 1:
        return CascadePlan(order=order, stage_sizes=(t,))
    first = exit_enabling_prefix(t, b)
    rest = t - first
    n_rest = min(stages - 1, rest)
    if n_rest == 0:
        return CascadePlan(order=order, stage_sizes=(t,))
    base, extra = divmod(rest, n_rest)
    sizes = (first,) + tuple(base + (1 if i < extra else 0) for i in range(n_rest))
    return CascadePlan(order=order, stage_sizes=sizes)


# ---------------------------------------------------------------------------
# Stage vote engines
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("max_depth", "n_classes", "algorithm", "jump_mode")
)
def _votes_jnp(
    records,
    attr_idx,
    threshold,
    child,
    class_val,
    *,
    max_depth: int,
    n_classes: int,
    algorithm: str,
    jump_mode: str,
):
    """(M, C) vote counts for one stage's trees via the jnp evaluators."""
    from repro.core.eval_dataparallel import eval_data_parallel
    from repro.core.eval_speculative import eval_speculative

    rec = jnp.asarray(records, jnp.float32)

    def one(a, t, c, k):
        if algorithm == "speculative":
            return eval_speculative(
                rec, a, t, c, k,
                max_depth=max_depth,
                use_onehot_matmul=(jump_mode == "onehot"),
            )
        return eval_data_parallel(rec, a, t, c, k, max_depth=max_depth)

    per_tree = jax.vmap(one)(attr_idx, threshold, child, class_val)  # (S, M)
    onehot = jax.nn.one_hot(per_tree, n_classes, dtype=jnp.int32)    # (S, M, C)
    return onehot.sum(axis=0)


class _StageForest:
    """View of a subset of a forest's trees (PackedForest-compatible)."""

    def __init__(self, forest, tree_ids: tuple[int, ...]):
        self._forest = forest
        self._ids = tuple(tree_ids)
        self.n_trees = len(self._ids)
        self.n_nodes = int(forest.n_nodes)
        self.max_depth = int(forest.max_depth)

    def tree(self, i: int):
        return self._forest.tree(self._ids[i])


# ---------------------------------------------------------------------------
# Evaluator
# ---------------------------------------------------------------------------


class CascadeResult(NamedTuple):
    """Per-record outcome of one cascade evaluation.

    Attributes:
      classes: (M,) int32 predicted class per record.
      margin: (M,) int32 final top-1 minus top-2 vote count.
      trees_evaluated: (M,) int32 trees that actually voted per record.
      exit_stage: (M,) int32 stage index at which the record cleared the
        bound, or -1 (ran every executed stage).
      stages_run: number of stages executed (< plan.n_stages when the
        deadline truncated the cascade or every record exited).
      confidence: (M,) float32 in [0, 1]; 1.0 when the answer is provably
        final, otherwise the partial-margin ratio ``margin / remaining``.
      stage_survivors: records entering each executed stage.
    """

    classes: np.ndarray
    margin: np.ndarray
    trees_evaluated: np.ndarray
    exit_stage: np.ndarray
    stages_run: int
    confidence: np.ndarray
    stage_survivors: tuple[int, ...]


def _pad_rows(n: int) -> int:
    """Bucket a survivor count to the next power of two (≥ one sublane)."""
    p = _ops.SUBLANE
    while p < n:
        p *= 2
    return p


class CascadeEvaluator:
    """Staged early-exit forest evaluator with inter-stage compaction.

    The stage loop runs on the host: surviving record indices are gathered
    into a dense tile (padded to a power-of-two row count so stage kernels
    retrace only O(log M) times), the stage's vote kernel accumulates
    (rows, C) vote counts on device, and the votes are scattered back into
    the full (M, C) tally.  Exit decisions are pure numpy on the tally.

    Args:
      forest: an ``EncodedForest`` (or anything with its surface).
      plan: explicit :class:`CascadePlan`; default = :func:`plan_cascade`
        over ``calibration`` (or stored tree order).
      n_classes: number of vote classes C.
      bound: exit bound; ``1.0`` exact (default), ``< 1`` relaxed,
        ``None`` disabled (full evaluation, bit-identical to majority vote).
      engine: "pallas" (fused vote kernel) or "jnp" (vmap evaluators);
        default pallas on TPU, jnp elsewhere.
      algorithm / jump_mode / block_m: forwarded to the stage kernels.
      stages / calibration: used only when ``plan`` is None.
      interpret: force Pallas interpret mode (pallas engine only).
    """

    def __init__(
        self,
        forest,
        plan: CascadePlan | None = None,
        *,
        n_classes: int,
        bound: float | None = 1.0,
        engine: str | None = None,
        algorithm: str = "speculative",
        jump_mode: str = "gather",
        block_m: int | None = None,
        stages: int = 2,
        calibration=None,
        interpret: bool | None = None,
        registry: obs.Registry | None = None,
        tracer: obs.Tracer | None = None,
    ):
        if bound is not None and float(bound) <= 0.0:
            raise ValueError(f"bound must be positive or None, got {bound}")
        if engine is None:
            engine = "pallas" if _ops.on_tpu() else "jnp"
        if engine not in ("pallas", "jnp"):
            raise ValueError(f"unknown engine {engine!r}")
        self.forest = forest
        self.n_classes = int(n_classes)
        self._c = max(self.n_classes, 2)
        self.bound = None if bound is None else float(bound)
        self.engine = engine
        self.algorithm = algorithm
        self.jump_mode = jump_mode
        self.block_m = block_m
        self.interpret = interpret
        if plan is None:
            plan = plan_cascade(
                forest,
                calibration,
                n_classes=self.n_classes,
                stages=stages,
                bound=self.bound,
            )
        if plan.n_trees != int(forest.n_trees):
            raise ValueError(
                f"plan covers {plan.n_trees} trees, forest has {forest.n_trees}"
            )
        self.plan = plan
        self._stages = [self._build_stage(s) for s in range(plan.n_stages)]
        # (stage, padded_rows) → EMA of observed stage latency, for the
        # anytime deadline check.
        self._stage_ms: dict[tuple[int, int], float] = {}
        self.obs = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        r = self.obs
        self.m_evals = r.counter("cascade.evals", "cascade evaluations")
        self.m_records = r.counter("cascade.records", "records evaluated")
        self.m_stage_ms = r.histogram(
            "cascade.stage_ms", "per-stage kernel latency", ("stage",))
        self.m_survival = r.histogram(
            "cascade.stage_survival",
            "fraction of the batch entering each stage", ("stage",),
            boundaries=obs.DEFAULT_RATIO_BOUNDARIES)
        self.m_exit_margin = r.histogram(
            "cascade.exit_margin", "final top-1 minus top-2 vote margins",
            boundaries=_MARGIN_BOUNDARIES)
        self.m_compact_ms = r.histogram(
            "cascade.compact_ms",
            "host-side survivor compaction per stage (gather + scatter + exit test)",
            ("stage",))

    # -- stage construction -------------------------------------------------

    def _build_stage(self, s: int) -> Callable:
        ids = self.plan.stage_trees(s)
        if self.engine == "pallas":
            # The packed tables depend on the record attribute count, which
            # EncodedForest does not store — pack lazily on first call.
            packed_by_a: dict[int, _ops.PackedForest] = {}

            def run(rec: np.ndarray) -> np.ndarray:
                a = rec.shape[1]
                packed = packed_by_a.get(a)
                if packed is None:
                    packed = _ops.PackedForest(_StageForest(self.forest, ids), a)
                    packed_by_a[a] = packed
                out = _ops.forest_votes_fused(
                    jnp.asarray(rec),
                    packed,
                    n_classes=self._c,
                    algorithm=self.algorithm,
                    jump_mode=self.jump_mode,
                    block_m=self.block_m,
                    interpret=self.interpret,
                )
                return np.asarray(jax.block_until_ready(out))

            return run

        idx = list(ids)
        tables = (
            jnp.asarray(np.asarray(self.forest.attr_idx)[idx], jnp.int32),
            jnp.asarray(np.asarray(self.forest.threshold)[idx], jnp.float32),
            jnp.asarray(np.asarray(self.forest.child)[idx], jnp.int32),
            jnp.asarray(np.asarray(self.forest.class_val)[idx], jnp.int32),
        )
        max_depth = int(self.forest.max_depth)

        def run(rec: np.ndarray) -> np.ndarray:
            out = _votes_jnp(
                jnp.asarray(rec),
                *tables,
                max_depth=max_depth,
                n_classes=self._c,
                algorithm=self.algorithm,
                jump_mode=self.jump_mode,
            )
            return np.asarray(jax.block_until_ready(out))

        return run

    def _stage_votes(self, s: int, rec: np.ndarray) -> tuple[np.ndarray, int]:
        """Run stage ``s`` on a dense record tile; returns (votes, pad_rows)."""
        n = rec.shape[0]
        rows = _pad_rows(n)
        if rows != n:
            rec = np.concatenate(
                [rec, np.zeros((rows - n, rec.shape[1]), rec.dtype)], axis=0
            )
        t0 = time.perf_counter()
        with self.tracer.span("cascade.stage", cat="cascade", stage=s,
                              survivors=n, rows=rows):
            votes = self._stages[s](rec)[:n]
        ms = (time.perf_counter() - t0) * 1e3
        self.m_stage_ms.labels(stage=s).observe(ms)
        key = (s, rows)
        prev = self._stage_ms.get(key)
        self._stage_ms[key] = ms if prev is None else 0.7 * prev + 0.3 * ms
        return votes, rows

    def _stage_estimate_ms(self, s: int, n: int) -> float:
        """Predicted latency of stage ``s`` over ``n`` records (0 = unknown)."""
        rows = _pad_rows(n)
        est = self._stage_ms.get((s, rows))
        if est is not None:
            return est
        # fall back to the nearest observed bucket for this stage
        seen = [(abs(r - rows), v) for (si, r), v in self._stage_ms.items() if si == s]
        return min(seen)[1] if seen else 0.0

    # -- evaluation ---------------------------------------------------------

    def __call__(self, records, *, deadline_ms: float | None = None) -> CascadeResult:
        rec = np.asarray(records, np.float32)
        if rec.ndim != 2:
            raise ValueError(f"records must be (M, A), got {rec.shape}")
        m = rec.shape[0]
        t_total = self.plan.n_trees
        votes = np.zeros((m, self._c), np.int32)
        trees_evaluated = np.zeros((m,), np.int32)
        exit_stage = np.full((m,), -1, np.int32)
        alive = np.arange(m)
        survivors: list[int] = []
        stages_run = 0
        self.m_evals.inc()
        self.m_records.inc(m)
        espan = self.tracer.span("cascade.eval", cat="cascade", records=m,
                                 deadline_ms=deadline_ms)
        t_start = time.perf_counter()

        with espan:
            for s, size in enumerate(self.plan.stage_sizes):
                if alive.size == 0:
                    break
                if deadline_ms is not None and s > 0:
                    elapsed = (time.perf_counter() - t_start) * 1e3
                    if elapsed + self._stage_estimate_ms(s, alive.size) > deadline_ms:
                        break
                survivors.append(int(alive.size))
                self.m_survival.labels(stage=s).observe(alive.size / max(m, 1))
                # Survivor compaction is host numpy today (see ROADMAP: a
                # Pallas prefix-scan would keep it on-device) — time both
                # halves so it stops being invisible next to the kernels.
                c0 = time.perf_counter()
                with self.tracer.span("cascade.compact", cat="cascade", stage=s,
                                      phase="gather", survivors=int(alive.size)):
                    stage_rec = rec[alive]
                compact_ms = (time.perf_counter() - c0) * 1e3
                stage_votes, _ = self._stage_votes(s, stage_rec)
                c1 = time.perf_counter()
                with self.tracer.span("cascade.compact", cat="cascade", stage=s,
                                      phase="scatter", survivors=int(alive.size)):
                    votes[alive] += stage_votes
                    trees_evaluated[alive] += size
                    stages_run = s + 1
                    remaining = t_total - int(trees_evaluated[alive[0]]) if alive.size else 0
                    if self.bound is not None and remaining > 0:
                        va = votes[alive]
                        top2 = np.partition(va, -2, axis=1)[:, -2:]
                        margin = top2[:, 1] - top2[:, 0]
                        decided = margin > self.bound * remaining
                        if decided.any():
                            exit_stage[alive[decided]] = s
                            alive = alive[~decided]
                compact_ms += (time.perf_counter() - c1) * 1e3
                self.m_compact_ms.labels(stage=s).observe(compact_ms)
            espan.set(stages_run=stages_run)

        classes = votes.argmax(axis=1).astype(np.int32)
        top2 = np.partition(votes, -2, axis=1)[:, -2:]
        margin = (top2[:, 1] - top2[:, 0]).astype(np.int32)
        self.m_exit_margin.observe_many(margin)
        remaining_all = (t_total - trees_evaluated).astype(np.int32)
        with np.errstate(divide="ignore", invalid="ignore"):
            conf = np.where(
                remaining_all <= 0,
                1.0,
                np.clip(margin / np.maximum(remaining_all, 1), 0.0, 1.0),
            ).astype(np.float32)
        return CascadeResult(
            classes=classes,
            margin=margin,
            trees_evaluated=trees_evaluated,
            exit_stage=exit_stage,
            stages_run=stages_run,
            confidence=conf,
            stage_survivors=tuple(survivors),
        )


def eval_cascade(
    forest,
    records,
    *,
    n_classes: int,
    stages: int = 2,
    bound: float | None = 1.0,
    plan: CascadePlan | None = None,
    calibration=None,
    engine: str | None = None,
    algorithm: str = "speculative",
    jump_mode: str = "gather",
    block_m: int | None = None,
    deadline_ms: float | None = None,
    registry: "obs.Registry | None" = None,
    tracer: "obs.Tracer | None" = None,
) -> CascadeResult:
    """One-shot cascade evaluation (builds a :class:`CascadeEvaluator`).

    For repeated batches build the evaluator once — it caches per-stage
    packed tables, compiled kernels and latency estimates.
    """
    ev = CascadeEvaluator(
        forest,
        plan,
        n_classes=n_classes,
        bound=bound,
        engine=engine,
        algorithm=algorithm,
        jump_mode=jump_mode,
        block_m=block_m,
        stages=stages,
        calibration=calibration if calibration is not None else records,
        registry=registry,
        tracer=tracer,
    )
    return ev(records, deadline_ms=deadline_ms)


# ---------------------------------------------------------------------------
# Cascade variant registry (consumed by repro.tune's class-level tuner)
# ---------------------------------------------------------------------------
#
# A cascade variant *builds* a CascadeEvaluator rather than evaluating a
# batch directly: the evaluator is stateful (packed stage tables, latency
# EMAs), so the dispatch layer constructs it once per resolved bucket and
# replays it per batch.  Contract:
#
#     spec.build(forest, *, n_classes, plan=None, stages, bound, block_m,
#                calibration=None) -> CascadeEvaluator


@dataclasses.dataclass(frozen=True)
class CascadeVariantSpec:
    """One cascade evaluator configuration plus its tunable knobs.

    ``family`` is always :data:`CASCADE_FAMILY`; ``tunables`` always
    includes ``"stages"`` (the stage-count grid) and, for the pallas
    engine, ``"block_m"``.
    """

    name: str
    family: str
    algorithm: str
    engine: str
    jump_mode: str
    tunables: tuple[str, ...]
    build: Callable


CASCADE_VARIANTS: dict[str, CascadeVariantSpec] = {}


def register_cascade_variant(spec: CascadeVariantSpec) -> CascadeVariantSpec:
    if spec.name in CASCADE_VARIANTS:
        raise ValueError(f"cascade variant {spec.name!r} already registered")
    CASCADE_VARIANTS[spec.name] = spec
    return spec


def get_cascade_variant(name: str) -> CascadeVariantSpec:
    try:
        return CASCADE_VARIANTS[name]
    except KeyError:
        raise KeyError(
            f"unknown cascade variant {name!r}; registered: {sorted(CASCADE_VARIANTS)}"
        ) from None


def list_cascade_variants(*, engine: str | None = None) -> list[CascadeVariantSpec]:
    out = [
        s for s in CASCADE_VARIANTS.values() if engine is None or s.engine == engine
    ]
    return sorted(out, key=lambda s: s.name)


def _builder(engine: str, algorithm: str, jump_mode: str) -> Callable:
    def build(
        forest,
        *,
        n_classes: int,
        plan: CascadePlan | None = None,
        stages: int = 2,
        bound: float | None = 1.0,
        block_m: int | None = None,
        calibration=None,
        interpret: bool | None = None,
        registry: obs.Registry | None = None,
        tracer: obs.Tracer | None = None,
    ) -> CascadeEvaluator:
        return CascadeEvaluator(
            forest,
            plan,
            n_classes=n_classes,
            bound=bound,
            engine=engine,
            algorithm=algorithm,
            jump_mode=jump_mode,
            block_m=block_m,
            stages=stages,
            calibration=calibration,
            interpret=interpret,
            registry=registry,
            tracer=tracer,
        )

    return build


for _alg, _jm in (("speculative", "gather"), ("speculative", "onehot"), ("data_parallel", "gather")):
    _suffix = f"_{_jm}" if _alg == "speculative" else ""
    register_cascade_variant(
        CascadeVariantSpec(
            name=f"forest_cascade_fused_{_alg}" + _suffix,
            family=CASCADE_FAMILY,
            algorithm=_alg,
            engine="pallas",
            jump_mode=_jm,
            tunables=("stages", "block_m"),
            build=_builder("pallas", _alg, _jm),
        )
    )
    register_cascade_variant(
        CascadeVariantSpec(
            name=f"forest_cascade_vmap_{_alg}" + _suffix,
            family=CASCADE_FAMILY,
            algorithm=_alg,
            engine="jnp",
            jump_mode=_jm,
            tunables=("stages",),
            build=_builder("jnp", _alg, _jm),
        )
    )
