"""Profiling evaluation path: the branchless descent with its eyes open.

Every evaluator in this repo answers *what class*; this module answers the
§3.6 questions the autotuner's cost model runs on — *how deep* did live
traffic actually traverse (d_µ), *how divergent* was each round (the
active-lane fraction the paper's SIMD analysis charges idle processors
for), and *where* did records land (per-node / per-leaf hit counts, the
input to the drift detector in :mod:`repro.obs.prof`).

The descent mirrors :func:`repro.kernels.tree_eval.ref.tree_eval_ref`
step for step — ``idx = child[idx] + (r_a > t)`` for ``max_depth`` rounds,
leaves self-looping — with device-side reductions bolted on:

* ``exit_depth[r]``  — rounds record ``r`` spent at internal nodes before
  reaching its leaf (its traversal depth; mean = measured d_µ);
* ``level_active[l]`` — fraction of records still at an internal node
  entering round ``l`` (the paper's per-level lane occupancy);
* ``node_hits[i]``   — internal-node evaluations at node ``i``;
* ``leaf_hits[i]``   — records terminating at leaf ``i`` (the windowed
  histogram the drift detector compares).

Because the index arithmetic is byte-identical to the reference loop, the
``classes`` output is *bit-exact* with the unprofiled evaluators — the
shadow pass can double-check the serving path while it measures it.

Runs as plain jitted jnp (scatter-adds + means), not a Pallas kernel: the
shadow pass is sampled and off the request path, so portability (interpret
-mode CPU in CI, any backend in prod) beats peak throughput here.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import BOTTOM, tree_depth

__all__ = ["ForestProfile", "TreeProfile", "profile_forest_eval", "profile_tree_eval"]


class TreeProfile(NamedTuple):
    """One profiled descent over a record batch (device arrays).

    ``classes`` is bit-exact with ``tree_eval_ref`` on the same inputs; the
    rest are the measurements.  ``level_active[l]`` is the fraction of
    records still at an internal node *entering* round ``l`` — equivalently
    ``mean(exit_depth > l)``.
    """

    classes: jax.Array      # (M,) int32
    exit_depth: jax.Array   # (M,) int32 — traversal depth per record
    level_active: jax.Array  # (max_depth,) float32 — active-lane fraction
    node_hits: jax.Array    # (N,) int32 — internal evaluations per node
    leaf_hits: jax.Array    # (N,) int32 — terminal records per leaf

    def d_mu(self) -> float:
        """Measured mean traversal depth (the §3.6 d_µ)."""
        return float(jnp.mean(self.exit_depth.astype(jnp.float32)))


class ForestProfile(NamedTuple):
    """Per-tree profiles of one forest descent (leading tree axis T)."""

    classes: jax.Array      # (T, M) int32 — bit-exact with forest_eval_ref
    exit_depth: jax.Array   # (T, M) int32
    level_active: jax.Array  # (T, max_depth) float32
    node_hits: jax.Array    # (T, N) int32
    leaf_hits: jax.Array    # (T, N) int32

    def d_mu(self) -> float:
        """Forest d_µ: mean traversal depth over all trees × records."""
        return float(jnp.mean(self.exit_depth.astype(jnp.float32)))

    def leaf_histogram(self) -> np.ndarray:
        """Leaf-hit counts summed over trees, (N,) — the drift signal."""
        return np.asarray(jnp.sum(self.leaf_hits, axis=0))

    def mean_level_active(self) -> np.ndarray:
        """Active-lane fraction per round averaged over trees, (max_depth,)."""
        return np.asarray(jnp.mean(self.level_active, axis=0))


def _profiled_descent(records, attr_idx, threshold, child, class_val, max_depth):
    """The reference loop with reductions; index math identical to ref.py."""
    m = records.shape[0]
    n = attr_idx.shape[0]
    idx = jnp.zeros((m,), jnp.int32)
    exit_depth = jnp.zeros((m,), jnp.int32)
    node_hits = jnp.zeros((n,), jnp.int32)
    active = []
    for _ in range(max_depth):
        internal = class_val[idx] == BOTTOM   # still descending this round
        live = internal.astype(jnp.int32)
        active.append(jnp.mean(internal.astype(jnp.float32)))
        node_hits = node_hits.at[idx].add(live)
        a = attr_idx[idx]
        t = threshold[idx]
        v = jnp.take_along_axis(records, a[:, None], axis=1)[:, 0]
        idx = child[idx] + (v > t).astype(jnp.int32)
        exit_depth = exit_depth + live
    classes = class_val[idx]
    leaf_hits = jnp.zeros((n,), jnp.int32).at[idx].add(1)
    return classes, exit_depth, jnp.stack(active), node_hits, leaf_hits


@functools.partial(jax.jit, static_argnames=("max_depth",))
def _profile_tree(records, attr_idx, threshold, child, class_val, *, max_depth):
    return _profiled_descent(records, attr_idx, threshold, child, class_val, max_depth)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def _profile_forest(records, attr_idx, threshold, child, class_val, *, max_depth):
    def one(a, t, c, k):
        return _profiled_descent(records, a, t, c, k, max_depth)

    return jax.vmap(one)(attr_idx, threshold, child, class_val)


def profile_tree_eval(records, enc, *, max_depth: int | None = None) -> TreeProfile:
    """Profile one tree's descent over a record batch.

    Args:
      records: (M, A) float array (compared in f32, like every evaluator).
      enc: an :class:`repro.core.tree.EncodedTree`.
      max_depth: descent rounds; default = the tree's depth (leaves
        self-loop, so extra rounds change nothing but waste time).

    Returns:
      A :class:`TreeProfile`; ``classes`` is bit-exact with
      :func:`repro.kernels.tree_eval.ref.tree_eval_ref`.
    """
    records = jnp.asarray(records, jnp.float32)
    if max_depth is None:
        max_depth = max(tree_depth(enc), 1)
    out = _profile_tree(
        records,
        jnp.asarray(enc.attr_idx, jnp.int32),
        jnp.asarray(enc.threshold, jnp.float32),
        jnp.asarray(enc.child, jnp.int32),
        jnp.asarray(enc.class_val, jnp.int32),
        max_depth=int(max_depth),
    )
    return TreeProfile(*out)


def profile_forest_eval(records, forest, *, max_depth: int | None = None) -> ForestProfile:
    """Profile every tree of an :class:`~repro.core.forest.EncodedForest`.

    Same contract as :func:`profile_tree_eval` lifted over the stacked
    (T, N) tree tables; ``classes`` is bit-exact with
    :func:`repro.kernels.tree_eval.ref.forest_eval_ref` (and therefore with
    every tuned forest family).
    """
    records = jnp.asarray(records, jnp.float32)
    if max_depth is None:
        max_depth = max(int(forest.max_depth), 1)
    out = _profile_forest(
        records,
        jnp.asarray(forest.attr_idx, jnp.int32),
        jnp.asarray(forest.threshold, jnp.float32),
        jnp.asarray(forest.child, jnp.int32),
        jnp.asarray(forest.class_val, jnp.int32),
        max_depth=int(max_depth),
    )
    return ForestProfile(*out)
