from repro.serve.engine import (
    BackgroundRetuner,
    EngineStats,
    ForestEngineStats,
    ForestServeEngine,
    Request,
    RetunePolicy,
    ServeEngine,
    TreeEngineStats,
    TreeRequest,
    TreeServeEngine,
)
