from repro.serve.engine import (
    EngineStats,
    ForestEngineStats,
    ForestServeEngine,
    Request,
    ServeEngine,
    TreeEngineStats,
    TreeRequest,
    TreeServeEngine,
)
