from repro.serve.engine import (
    EngineStats,
    Request,
    ServeEngine,
    TreeEngineStats,
    TreeRequest,
    TreeServeEngine,
)
