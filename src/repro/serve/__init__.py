from repro.serve.engine import EngineStats, Request, ServeEngine
