from repro.serve.engine import (
    AnytimePolicy,
    BackgroundRetuner,
    EngineStats,
    ForestEngineStats,
    ForestServeEngine,
    Request,
    RetunePolicy,
    ServeEngine,
    TreeEngineStats,
    TreeRequest,
    TreeServeEngine,
)
