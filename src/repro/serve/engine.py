"""Batched serving engine: batched prefill + decode over slot waves.

A deliberately small serving core: requests are served in *waves* — up to
``max_batch`` equal-length prompts are prefetched with one batched prefill,
then decoded together until every request in the wave finishes (finished
requests keep decoding into a scratch slot but their outputs are frozen;
the decode cost of a wave is its longest member, exactly the "lucky
processor idle time" asymmetry the paper describes for data decomposition —
recorded in the engine stats).  The tree-routed MoE archs take their
hardened speculative-routing path automatically during decode
(``serve_hard_tree`` in the MoE layer).

Caches use a single scalar write position, so prompts inside a wave must
share one length (shorter prompts are left-padded by the caller or the
``pad_to`` option).  Cross-wave lengths may differ freely.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    waves: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    idle_token_slots: int = 0     # finished-request slots still riding decode


class ServeEngine:
    """Wave-batched decoding over one model."""

    def __init__(self, model, params, *, max_batch: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.stats = EngineStats()
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(sub, logits / self.temperature))

    def _pad_wave(self, wave: list[Request], pad_to: Optional[int]) -> np.ndarray:
        lens = {r.prompt.shape[0] for r in wave}
        width = pad_to or max(lens)
        toks = np.zeros((self.max_batch, width), np.int32)
        for i, r in enumerate(wave):
            p = r.prompt[-width:]
            toks[i, width - p.shape[0]:] = p      # left-pad
        return toks

    def run(self, requests: list[Request], *, pad_to: Optional[int] = None) -> list[Request]:
        """Serve all requests in ``max_batch``-sized waves."""
        queue = list(requests)
        while queue:
            wave, queue = queue[: self.max_batch], queue[self.max_batch:]
            self._run_wave(wave, pad_to)
        return requests

    def _run_wave(self, wave: list[Request], pad_to: Optional[int]) -> None:
        self.stats.waves += 1
        toks = self._pad_wave(wave, pad_to)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        nxt = self._sample(logits[:, -1, :])
        for i, r in enumerate(wave):
            r.out_tokens.append(int(nxt[i]))
        budget = max(r.max_new_tokens for r in wave)
        t0 = time.perf_counter()
        for _ in range(budget - 1):
            live = [r for r in wave if len(r.out_tokens) < r.max_new_tokens]
            if not live:
                break
            step_tok = np.array(
                [[r.out_tokens[-1]] for r in wave]
                + [[0]] * (self.max_batch - len(wave)),
                np.int32,
            )
            logits, cache = self._decode(self.params, cache, {"tokens": jnp.asarray(step_tok)})
            nxt = self._sample(logits[:, -1, :])
            self.stats.decode_steps += 1
            for i, r in enumerate(wave):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
                else:
                    self.stats.idle_token_slots += 1
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
        for r in wave:
            r.done = True
