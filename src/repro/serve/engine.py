"""Batched serving engine: batched prefill + decode over slot waves.

A deliberately small serving core: requests are served in *waves* — up to
``max_batch`` equal-length prompts are prefetched with one batched prefill,
then decoded together until every request in the wave finishes (finished
requests keep decoding into a scratch slot but their outputs are frozen;
the decode cost of a wave is its longest member, exactly the "lucky
processor idle time" asymmetry the paper describes for data decomposition —
recorded in the engine stats).  The tree-routed MoE archs take their
hardened speculative-routing path automatically during decode
(``serve_hard_tree`` in the MoE layer).

Caches use a single scalar write position, so prompts inside a wave must
share one length (shorter prompts are left-padded by the caller or the
``pad_to`` option).  Cross-wave lengths may differ freely.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    waves: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    idle_token_slots: int = 0     # finished-request slots still riding decode


class ServeEngine:
    """Wave-batched decoding over one model."""

    def __init__(self, model, params, *, max_batch: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.stats = EngineStats()
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(sub, logits / self.temperature))

    def _pad_wave(self, wave: list[Request], pad_to: Optional[int]) -> np.ndarray:
        lens = {r.prompt.shape[0] for r in wave}
        width = pad_to or max(lens)
        toks = np.zeros((self.max_batch, width), np.int32)
        for i, r in enumerate(wave):
            p = r.prompt[-width:]
            toks[i, width - p.shape[0]:] = p      # left-pad
        return toks

    def run(self, requests: list[Request], *, pad_to: Optional[int] = None) -> list[Request]:
        """Serve all requests in ``max_batch``-sized waves."""
        queue = list(requests)
        while queue:
            wave, queue = queue[: self.max_batch], queue[self.max_batch:]
            self._run_wave(wave, pad_to)
        return requests

    def _run_wave(self, wave: list[Request], pad_to: Optional[int]) -> None:
        self.stats.waves += 1
        toks = self._pad_wave(wave, pad_to)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        nxt = self._sample(logits[:, -1, :])
        for i, r in enumerate(wave):
            r.out_tokens.append(int(nxt[i]))
        budget = max(r.max_new_tokens for r in wave)
        t0 = time.perf_counter()
        for _ in range(budget - 1):
            live = [r for r in wave if len(r.out_tokens) < r.max_new_tokens]
            if not live:
                break
            step_tok = np.array(
                [[r.out_tokens[-1]] for r in wave]
                + [[0]] * (self.max_batch - len(wave)),
                np.int32,
            )
            logits, cache = self._decode(self.params, cache, {"tokens": jnp.asarray(step_tok)})
            nxt = self._sample(logits[:, -1, :])
            self.stats.decode_steps += 1
            for i, r in enumerate(wave):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
                else:
                    self.stats.idle_token_slots += 1
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
        for r in wave:
            r.done = True


# ---------------------------------------------------------------------------
# Tree-classification serving (the paper's workload as a service)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TreeRequest:
    """One classification request: a batch of records to assign classes."""

    uid: int
    records: np.ndarray                 # (m, A) float32
    out: Optional[np.ndarray] = None    # (m,) int32 once served
    done: bool = False


def _next_wave(queue: deque, max_batch: int) -> tuple[list, int]:
    """Pop the next record-count-bounded wave off the request queue.

    Greedy prefix up to ``max_batch`` total records; an oversize request
    forms a singleton wave (it cannot split across waves)."""
    wave, total = [], 0
    while queue and (not wave or total + queue[0].records.shape[0] <= max_batch):
        r = queue.popleft()
        wave.append(r)
        total += r.records.shape[0]
    return wave, total


@dataclasses.dataclass
class TreeEngineStats:
    waves: int = 0
    records: int = 0
    eval_s: float = 0.0
    padded_record_slots: int = 0   # bucket-padding rows (the wave's idle lanes)


class TreeServeEngine:
    """Wave-batched classification over one tree via autotuned dispatch.

    Requests are coalesced into waves of up to ``max_batch`` records and
    evaluated with one :class:`repro.tune.TunedEvaluator` call, which routes
    each wave through the cached-best kernel variant for its shape bucket
    (autotuning on first sight when ``autotune=True``).  Because dispatch
    pads every wave to its M-bucket, steady-state traffic of jittery batch
    sizes compiles once per bucket — the serving analogue of the LM engine's
    fixed-width waves; the padding rows are recorded in the stats as the
    wave's idle-lane cost.
    """

    def __init__(self, tree, *, max_batch: int = 4096, cache=None,
                 autotune: bool = False, engines=None):
        from repro.tune.dispatch import TunedEvaluator
        from repro.tune.space import WorkloadShape

        self._shape_of = WorkloadShape.of
        self._eval = TunedEvaluator(tree, cache=cache, autotune=autotune, engines=engines)
        self.tree = tree
        self.max_batch = max_batch
        self.stats = TreeEngineStats()

    def run(self, requests: list[TreeRequest]) -> list[TreeRequest]:
        """Serve all requests in record-count-bounded waves."""
        queue = deque(requests)
        while queue:
            self._run_wave(*_next_wave(queue, self.max_batch))
        return requests

    def _run_wave(self, wave: list[TreeRequest], total: int) -> None:
        self.stats.waves += 1
        self.stats.records += total
        batch = np.concatenate([r.records for r in wave], axis=0).astype(np.float32)
        shape = self._shape_of(batch, self.tree, self._eval.depth)
        self.stats.padded_record_slots += shape.bucket().m - total
        t0 = time.perf_counter()
        out = np.asarray(jax.block_until_ready(self._eval(batch)))
        self.stats.eval_s += time.perf_counter() - t0
        off = 0
        for r in wave:
            m = r.records.shape[0]
            r.out = out[off:off + m]
            r.done = True
            off += m


# ---------------------------------------------------------------------------
# Sharded-forest serving (repro.dist as a service)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ForestEngineStats:
    waves: int = 0
    records: int = 0
    chunks: int = 0                # streaming chunks across all waves
    eval_s: float = 0.0
    chunk_ms: list = dataclasses.field(default_factory=list)  # per-chunk latency


class ForestServeEngine:
    """Wave-batched forest classification over the device mesh.

    The forest analogue of :class:`TreeServeEngine`: requests coalesce into
    waves of up to ``max_batch`` records, each wave runs through the
    ``repro.dist`` sharded executor behind a streaming chunker, so
    host→device transfer of one chunk overlaps evaluation of the previous
    (double buffering).  Per-chunk latencies land in ``stats.chunk_ms`` —
    the same accounting ``TreeServeEngine`` keeps per wave, at chunk
    granularity.  With ``n_classes`` set, requests get majority-vote
    classes (m,); otherwise per-tree assignments (T, m).
    """

    def __init__(self, forest, *, max_batch: int = 65536, chunk_records: int = 8192,
                 n_classes: Optional[int] = None, mesh=None, plan=None,
                 decomposition=None, cache=None, autotune: bool = False, engines=None):
        from repro.dist import ShardedForestEvaluator, StreamingChunker

        self._eval = ShardedForestEvaluator(
            forest, mesh=mesh, plan=plan, decomposition=decomposition,
            cache=cache, autotune=autotune, engines=engines,
        )
        self._chunker = StreamingChunker(self._eval, chunk_records=chunk_records)
        self.forest = self._eval.forest
        self.max_batch = max_batch
        self.n_classes = n_classes
        self.stats = ForestEngineStats()

    @property
    def plan(self):
        """The executor's chosen ShardPlan (None until the first wave)."""
        return self._eval.plan

    def run(self, requests: list[TreeRequest]) -> list[TreeRequest]:
        """Serve all requests in record-count-bounded waves."""
        queue = deque(requests)
        while queue:
            self._run_wave(*_next_wave(queue, self.max_batch))
        return requests

    def _run_wave(self, wave: list[TreeRequest], total: int) -> None:
        self.stats.waves += 1
        self.stats.records += total
        batch = np.concatenate([r.records for r in wave], axis=0).astype(np.float32)

        def on_chunk(latency_ms: float, n: int) -> None:
            self.stats.chunks += 1
            self.stats.chunk_ms.append(latency_ms)

        t0 = time.perf_counter()
        per_tree = self._chunker.eval(batch, on_chunk=on_chunk)   # (T, total)
        if self.n_classes is not None:
            from repro.core.forest import majority_vote

            out = np.asarray(majority_vote(jnp.asarray(per_tree), self.n_classes))
        else:
            out = per_tree
        self.stats.eval_s += time.perf_counter() - t0
        off = 0
        for r in wave:
            m = r.records.shape[0]
            r.out = out[off:off + m] if self.n_classes is not None else out[:, off:off + m]
            r.done = True
            off += m
