"""Batched serving engine: batched prefill + decode over slot waves.

A deliberately small serving core: requests are served in *waves* — up to
``max_batch`` equal-length prompts are prefetched with one batched prefill,
then decoded together until every request in the wave finishes (finished
requests keep decoding into a scratch slot but their outputs are frozen;
the decode cost of a wave is its longest member, exactly the "lucky
processor idle time" asymmetry the paper describes for data decomposition —
recorded in the engine stats).  The tree-routed MoE archs take their
hardened speculative-routing path automatically during decode
(``serve_hard_tree`` in the MoE layer).

Caches use a single scalar write position, so prompts inside a wave must
share one length (shorter prompts are left-padded by the caller or the
``pad_to`` option).  Cross-wave lengths may differ freely.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    waves: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    idle_token_slots: int = 0     # finished-request slots still riding decode


class ServeEngine:
    """Wave-batched decoding over one model."""

    def __init__(self, model, params, *, max_batch: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.stats = EngineStats()
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(sub, logits / self.temperature))

    def _pad_wave(self, wave: list[Request], pad_to: Optional[int]) -> np.ndarray:
        lens = {r.prompt.shape[0] for r in wave}
        width = pad_to or max(lens)
        toks = np.zeros((self.max_batch, width), np.int32)
        for i, r in enumerate(wave):
            p = r.prompt[-width:]
            toks[i, width - p.shape[0]:] = p      # left-pad
        return toks

    def run(self, requests: list[Request], *, pad_to: Optional[int] = None) -> list[Request]:
        """Serve all requests in ``max_batch``-sized waves."""
        queue = list(requests)
        while queue:
            wave, queue = queue[: self.max_batch], queue[self.max_batch:]
            self._run_wave(wave, pad_to)
        return requests

    def _run_wave(self, wave: list[Request], pad_to: Optional[int]) -> None:
        self.stats.waves += 1
        toks = self._pad_wave(wave, pad_to)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        nxt = self._sample(logits[:, -1, :])
        for i, r in enumerate(wave):
            r.out_tokens.append(int(nxt[i]))
        budget = max(r.max_new_tokens for r in wave)
        t0 = time.perf_counter()
        for _ in range(budget - 1):
            live = [r for r in wave if len(r.out_tokens) < r.max_new_tokens]
            if not live:
                break
            step_tok = np.array(
                [[r.out_tokens[-1]] for r in wave]
                + [[0]] * (self.max_batch - len(wave)),
                np.int32,
            )
            logits, cache = self._decode(self.params, cache, {"tokens": jnp.asarray(step_tok)})
            nxt = self._sample(logits[:, -1, :])
            self.stats.decode_steps += 1
            for i, r in enumerate(wave):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
                else:
                    self.stats.idle_token_slots += 1
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
        for r in wave:
            r.done = True


# ---------------------------------------------------------------------------
# Background re-tune policy (hot-bucket re-measurement, off the request path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetunePolicy:
    """When and how a serve engine re-measures hot shape buckets.

    The tune-on-first-miss policy (``autotune=True``) blocks the first wave
    of every new bucket on a full measurement sweep — fine for benches,
    wrong for serving.  Under this policy the engine resolves new buckets
    instantly (cache hit or §3.6 heuristic) and *promotes* buckets that
    prove hot: once a bucket has served ``hot_waves`` waves, a background
    thread re-measures its candidate space with the real wave data and
    atomically swaps the winner in.  Requests never wait on a measurement,
    and because every candidate is exact, a swap mid-traffic cannot change
    any result — only its latency.

    Attributes:
      hot_waves: waves a bucket must serve before it is re-measured.
      warmup / iters: measurement discipline forwarded to the tuner
        (kept small — the measurement shares the machine with live traffic).
      max_concurrent: measurement threads allowed at once; a hot bucket
        that cannot start immediately retries on its next wave.
    """

    hot_waves: int = 32
    warmup: int = 1
    iters: int = 3
    max_concurrent: int = 1


class BackgroundRetuner:
    """Drives :class:`RetunePolicy` for one engine: counts bucket hits,
    launches measurement threads, promotes winners.

    ``measure(batch)`` must run the tuner (persisting the winner to the
    shared cache) and return the winning entry; ``promote(key, entry)``
    must atomically swap the engine's evaluator onto it (see
    ``TunedEvaluator.promote`` / ``ShardedForestEvaluator
    .invalidate_resolution``).  Both run on the worker thread — the request
    path only pays a counter increment.
    """

    def __init__(self, measure: Callable, promote: Callable, policy: RetunePolicy):
        self.measure = measure
        self.promote = promote
        self.policy = policy
        self.hits: dict[str, int] = {}
        self.started: set[str] = set()
        self.done: list[tuple[str, object]] = []     # (bucket key, winning entry)
        self.errors: list[tuple[str, Exception]] = []
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()

    def note(self, key: str, batch: np.ndarray) -> None:
        """Record one served wave for ``key``; maybe launch a re-tune."""
        with self._lock:
            n = self.hits[key] = self.hits.get(key, 0) + 1
            if n < self.policy.hot_waves or key in self.started:
                return
            self._threads = [t for t in self._threads if t.is_alive()]
            if len(self._threads) >= self.policy.max_concurrent:
                return  # retried on the bucket's next wave
            self.started.add(key)
            snap = np.array(batch, copy=True)  # the wave buffer is reused
            th = threading.Thread(
                target=self._work, args=(key, snap), daemon=True, name=f"retune:{key}"
            )
            self._threads.append(th)
        th.start()

    def _work(self, key: str, batch: np.ndarray) -> None:
        try:
            entry = self.measure(batch)
            self.promote(key, entry)
            with self._lock:
                self.done.append((key, entry))
        except Exception as e:  # a failed re-tune must never take serving down
            with self._lock:
                self.errors.append((key, e))

    def drain(self, timeout: float | None = None) -> None:
        """Join outstanding measurement threads (tests / shutdown)."""
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout)

    @property
    def retunes(self) -> int:
        with self._lock:
            return len(self.done)


# ---------------------------------------------------------------------------
# Tree-classification serving (the paper's workload as a service)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TreeRequest:
    """One classification request: a batch of records to assign classes."""

    uid: int
    records: np.ndarray                 # (m, A) float32
    out: Optional[np.ndarray] = None    # (m,) int32 once served
    done: bool = False
    # anytime serving only: per-record answer confidence in [0, 1] — 1.0
    # when the class is provably final, the partial-margin ratio when the
    # latency SLO truncated the cascade before all trees voted
    confidence: Optional[np.ndarray] = None


def _next_wave(queue: deque, max_batch: int) -> tuple[list, int]:
    """Pop the next record-count-bounded wave off the request queue.

    Greedy prefix up to ``max_batch`` total records; an oversize request
    forms a singleton wave (it cannot split across waves)."""
    wave, total = [], 0
    while queue and (not wave or total + queue[0].records.shape[0] <= max_batch):
        r = queue.popleft()
        wave.append(r)
        total += r.records.shape[0]
    return wave, total


@dataclasses.dataclass
class TreeEngineStats:
    waves: int = 0
    records: int = 0
    eval_s: float = 0.0
    padded_record_slots: int = 0   # bucket-padding rows (the wave's idle lanes)
    retunes: int = 0               # background winner promotions completed
    bucket_waves: dict = dataclasses.field(default_factory=dict)  # key → waves served


class TreeServeEngine:
    """Wave-batched classification over one tree via autotuned dispatch.

    Requests are coalesced into waves of up to ``max_batch`` records and
    evaluated with one :class:`repro.tune.TunedEvaluator` call, which routes
    each wave through the cached-best kernel variant for its shape bucket.
    Because dispatch pads every wave to its M-bucket, steady-state traffic
    of jittery batch sizes compiles once per bucket — the serving analogue
    of the LM engine's fixed-width waves; the padding rows are recorded in
    the stats as the wave's idle-lane cost.

    Kernel selection policy: a new bucket resolves instantly (cache hit or
    the §3.6 heuristic); buckets that prove *hot* under the ``retune``
    policy are re-measured on a background thread with real wave data and
    the winner is swapped in atomically (:class:`RetunePolicy`).  The
    legacy blocking tune-on-first-miss behaviour remains available as
    ``autotune=True``.
    """

    def __init__(self, tree, *, max_batch: int = 4096, cache=None,
                 autotune: bool = False, engines=None,
                 retune: RetunePolicy | None = RetunePolicy()):
        from repro.tune.dispatch import TunedEvaluator
        from repro.tune.measure import tune_workload
        from repro.tune.space import Candidate, WorkloadShape

        self._shape_of = WorkloadShape.of
        self._eval = TunedEvaluator(tree, cache=cache, autotune=autotune, engines=engines)
        self.tree = tree
        self.max_batch = max_batch
        self.stats = TreeEngineStats()
        self.retuner: BackgroundRetuner | None = None
        if retune is not None:

            def measure(batch):
                entry, _ = tune_workload(
                    batch, tree, cache=self._eval.cache, engines=engines,
                    warmup=retune.warmup, iters=retune.iters,
                )
                return entry

            def promote(key, entry):
                self._eval.promote(key, Candidate.make(entry.variant, **entry.params))
                self.stats.retunes += 1

            self.retuner = BackgroundRetuner(measure, promote, retune)

    def run(self, requests: list[TreeRequest]) -> list[TreeRequest]:
        """Serve all requests in record-count-bounded waves."""
        queue = deque(requests)
        while queue:
            self._run_wave(*_next_wave(queue, self.max_batch))
        return requests

    def _run_wave(self, wave: list[TreeRequest], total: int) -> None:
        self.stats.waves += 1
        self.stats.records += total
        batch = np.concatenate([r.records for r in wave], axis=0).astype(np.float32)
        shape = self._shape_of(batch, self.tree, self._eval.depth)
        self.stats.padded_record_slots += shape.bucket().m - total
        t0 = time.perf_counter()
        out = np.asarray(jax.block_until_ready(self._eval(batch)))
        self.stats.eval_s += time.perf_counter() - t0
        off = 0
        for r in wave:
            m = r.records.shape[0]
            r.out = out[off:off + m]
            r.done = True
            off += m
        key = shape.key()
        self.stats.bucket_waves[key] = self.stats.bucket_waves.get(key, 0) + 1
        if self.retuner is not None:
            self.retuner.note(key, batch)


# ---------------------------------------------------------------------------
# Sharded-forest serving (repro.dist as a service)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnytimePolicy:
    """Anytime serving: answer within an SLO by truncating cascade stages.

    The engine evaluates each wave through an early-exit cascade
    (:class:`repro.kernels.tree_eval.CascadeEvaluator`) with the SLO as the
    per-wave deadline: stage 0 always runs, and each later stage runs only
    if its predicted latency (per-stage EMA) fits the remaining budget.
    Requests report per-record ``confidence`` — 1.0 where the class is
    provably final, the partial-margin ratio where the deadline cut the
    forest short.

    Attributes:
      slo_ms: per-wave latency budget in milliseconds.
      stages: cascade stage count (more stages = finer truncation grain).
      bound: early-exit bound; 1.0 keeps non-truncated answers exact.
      calibration_sample: records from the first wave used to rank trees.
    """

    slo_ms: float
    stages: int = 3
    bound: float = 1.0
    calibration_sample: int = 512


@dataclasses.dataclass
class ForestEngineStats:
    waves: int = 0
    records: int = 0
    chunks: int = 0                # streaming chunks across all waves
    eval_s: float = 0.0
    chunk_ms: list = dataclasses.field(default_factory=list)  # per-chunk latency
    retunes: int = 0               # background winner promotions completed
    bucket_waves: dict = dataclasses.field(default_factory=dict)  # key → waves served
    anytime_waves: int = 0         # waves served through the anytime cascade
    anytime_truncations: int = 0   # waves the SLO stopped before the last stage
    anytime_stages: list = dataclasses.field(default_factory=list)  # stages run per wave


class ForestServeEngine:
    """Wave-batched forest classification over the device mesh.

    The forest analogue of :class:`TreeServeEngine`: requests coalesce into
    waves of up to ``max_batch`` records, each wave runs through the
    ``repro.dist`` sharded executor behind a streaming chunker, so
    host→device transfer of one chunk overlaps evaluation of the previous
    (double buffering).  Per-chunk latencies land in ``stats.chunk_ms`` —
    the same accounting ``TreeServeEngine`` keeps per wave, at chunk
    granularity.  With ``n_classes`` set, requests get majority-vote
    classes (m,); otherwise per-tree assignments (T, m).

    Hot forest buckets are re-measured in the background under the
    ``retune`` policy (all three forest candidate families, real wave
    data); the freshly stored winner is picked up atomically via
    ``ShardedForestEvaluator.invalidate_resolution`` — see
    :class:`RetunePolicy`.
    """

    def __init__(self, forest, *, max_batch: int = 65536, chunk_records: int = 8192,
                 n_classes: Optional[int] = None, mesh=None, plan=None,
                 decomposition=None, cache=None, autotune: bool = False, engines=None,
                 retune: RetunePolicy | None = RetunePolicy(),
                 anytime: AnytimePolicy | None = None):
        from repro.dist import ShardedForestEvaluator, StreamingChunker

        if anytime is not None and n_classes is None:
            raise ValueError("anytime serving needs n_classes (it votes classes)")
        self._eval = ShardedForestEvaluator(
            forest, mesh=mesh, plan=plan, decomposition=decomposition,
            cache=cache, autotune=autotune, engines=engines,
        )
        self._chunker = StreamingChunker(self._eval, chunk_records=chunk_records)
        self.forest = self._eval.forest
        self.max_batch = max_batch
        self.n_classes = n_classes
        self.anytime = anytime
        self._cascade = None   # built lazily: calibrated on the first wave
        self.stats = ForestEngineStats()
        self.retuner: BackgroundRetuner | None = None
        if retune is not None:

            def measure(batch):
                # the executor owns key consistency: single-device measures
                # the forest bucket, a mesh measures the *shard* operating
                # point — either way the winner lands where the next
                # resolution looks
                return self._eval.retune(batch, warmup=retune.warmup, iters=retune.iters)

            def promote(key, entry):
                # the measurement already stored the winner; dropping
                # resolution state makes the next wave pick it up — the
                # executor-level analogue of TunedEvaluator.promote
                self._eval.invalidate_resolution()
                self.stats.retunes += 1

            self.retuner = BackgroundRetuner(measure, promote, retune)

    @property
    def plan(self):
        """The executor's chosen ShardPlan (None until the first wave)."""
        return self._eval.plan

    def run(self, requests: list[TreeRequest]) -> list[TreeRequest]:
        """Serve all requests in record-count-bounded waves."""
        queue = deque(requests)
        while queue:
            self._run_wave(*_next_wave(queue, self.max_batch))
        return requests

    def _anytime_cascade(self, batch: np.ndarray):
        """The wave cascade, built once and calibrated on the first wave."""
        if self._cascade is None:
            from repro.kernels.tree_eval import CascadeEvaluator

            pol = self.anytime
            self._cascade = CascadeEvaluator(
                self.forest,
                n_classes=self.n_classes,
                bound=pol.bound,
                stages=pol.stages,
                calibration=batch[: pol.calibration_sample],
            )
        return self._cascade

    def _run_wave(self, wave: list[TreeRequest], total: int) -> None:
        self.stats.waves += 1
        self.stats.records += total
        batch = np.concatenate([r.records for r in wave], axis=0).astype(np.float32)

        if self.anytime is not None:
            # anytime path: the cascade owns staging/early exit, so the wave
            # bypasses the chunker — the SLO check needs whole-stage latencies
            cascade = self._anytime_cascade(batch)
            t0 = time.perf_counter()
            res = cascade(batch, deadline_ms=self.anytime.slo_ms)
            self.stats.eval_s += time.perf_counter() - t0
            self.stats.anytime_waves += 1
            self.stats.anytime_stages.append(res.stages_run)
            # truncation = the deadline (not the exit bound) stopped the run:
            # some record never cleared the bound yet has trees left unvoted
            truncated = res.stages_run < cascade.plan.n_stages and bool(
                np.any(
                    (res.exit_stage < 0)
                    & (res.trees_evaluated < cascade.plan.n_trees)
                )
            )
            if truncated:
                self.stats.anytime_truncations += 1
            off = 0
            for r in wave:
                m = r.records.shape[0]
                r.out = res.classes[off:off + m]
                r.confidence = res.confidence[off:off + m]
                r.done = True
                off += m
        else:
            def on_chunk(latency_ms: float, n: int) -> None:
                self.stats.chunks += 1
                self.stats.chunk_ms.append(latency_ms)

            t0 = time.perf_counter()
            per_tree = self._chunker.eval(batch, on_chunk=on_chunk)   # (T, total)
            if self.n_classes is not None:
                from repro.core.forest import majority_vote

                out = np.asarray(majority_vote(jnp.asarray(per_tree), self.n_classes))
            else:
                out = per_tree
            self.stats.eval_s += time.perf_counter() - t0
            off = 0
            for r in wave:
                m = r.records.shape[0]
                r.out = out[off:off + m] if self.n_classes is not None else out[:, off:off + m]
                r.done = True
                off += m
        key = self._eval._forest_evaluator().shape_of(batch).key()
        self.stats.bucket_waves[key] = self.stats.bucket_waves.get(key, 0) + 1
        if self.retuner is not None:
            self.retuner.note(key, batch)
