"""Batched serving engine: batched prefill + decode over slot waves.

A deliberately small serving core: requests are served in *waves* — up to
``max_batch`` equal-length prompts are prefetched with one batched prefill,
then decoded together until every request in the wave finishes (finished
requests keep decoding into a scratch slot but their outputs are frozen;
the decode cost of a wave is its longest member, exactly the "lucky
processor idle time" asymmetry the paper describes for data decomposition —
recorded in the engine stats).  The tree-routed MoE archs take their
hardened speculative-routing path automatically during decode
(``serve_hard_tree`` in the MoE layer).

Caches use a single scalar write position, so prompts inside a wave must
share one length (shorter prompts are left-padded by the caller or the
``pad_to`` option).  Cross-wave lengths may differ freely.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

# Histogram grid for anytime stage counts (cascades rarely exceed 8 stages).
_STAGE_BOUNDARIES = tuple(float(i) for i in range(1, 9))


def _make_flight(flight, registry, tracer, engine: str):
    """Coerce the ``flight=`` argument (policy or ready recorder) into a
    :class:`repro.obs.FlightRecorder` sharing the engine's registry/tracer."""
    if flight is None:
        return None
    if isinstance(flight, obs.FlightRecorder):
        return flight
    return obs.FlightRecorder(flight, registry=registry, tracer=tracer,
                              engine=engine)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class EngineStats:
    """LM-engine counters on a locked :class:`repro.obs.Registry`.

    The pre-obs dataclass fields survive as read properties, so callers and
    tests keep working; mutations go through the registry's instruments
    (``m_*`` handles), which makes every counter thread-safe and exportable
    (JSON snapshot / Prometheus text, see :mod:`repro.obs.export`).
    """

    def __init__(self, registry: obs.Registry | None = None):
        self.registry = registry if registry is not None else obs.Registry()
        r = self.registry
        self.m_waves = r.counter("serve.lm.waves", "LM waves served")
        self.m_prefill_s = r.counter("serve.lm.prefill_s", "prefill seconds")
        self.m_decode_s = r.counter("serve.lm.decode_s", "decode seconds")
        self.m_decode_steps = r.counter("serve.lm.decode_steps", "decode steps run")
        self.m_idle = r.counter(
            "serve.lm.idle_token_slots",
            "finished-request slots still riding decode",
        )

    @property
    def waves(self) -> int:
        return int(self.m_waves.value)

    @property
    def prefill_s(self) -> float:
        return self.m_prefill_s.value

    @property
    def decode_s(self) -> float:
        return self.m_decode_s.value

    @property
    def decode_steps(self) -> int:
        return int(self.m_decode_steps.value)

    @property
    def idle_token_slots(self) -> int:
        return int(self.m_idle.value)


class ServeEngine:
    """Wave-batched decoding over one model."""

    def __init__(self, model, params, *, max_batch: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 registry: obs.Registry | None = None,
                 tracer: obs.Tracer | None = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.obs = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.stats = EngineStats(self.obs)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(sub, logits / self.temperature))

    def _pad_wave(self, wave: list[Request], pad_to: Optional[int]) -> np.ndarray:
        lens = {r.prompt.shape[0] for r in wave}
        width = pad_to or max(lens)
        toks = np.zeros((self.max_batch, width), np.int32)
        for i, r in enumerate(wave):
            p = r.prompt[-width:]
            toks[i, width - p.shape[0]:] = p      # left-pad
        return toks

    def run(self, requests: list[Request], *, pad_to: Optional[int] = None) -> list[Request]:
        """Serve all requests in ``max_batch``-sized waves."""
        queue = list(requests)
        while queue:
            wave, queue = queue[: self.max_batch], queue[self.max_batch:]
            self._run_wave(wave, pad_to)
        return requests

    def _run_wave(self, wave: list[Request], pad_to: Optional[int]) -> None:
        with self.tracer.span("serve.wave", cat="serve", engine="lm",
                              requests=len(wave)):
            self.stats.m_waves.inc()
            toks = self._pad_wave(wave, pad_to)
            t0 = time.perf_counter()
            with self.tracer.span("serve.prefill", cat="serve", width=toks.shape[1]):
                logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
                jax.block_until_ready(logits)
            self.stats.m_prefill_s.inc(time.perf_counter() - t0)
            nxt = self._sample(logits[:, -1, :])
            for i, r in enumerate(wave):
                r.out_tokens.append(int(nxt[i]))
            budget = max(r.max_new_tokens for r in wave)
            t0 = time.perf_counter()
            with self.tracer.span("serve.decode", cat="serve") as dspan:
                steps = 0
                for _ in range(budget - 1):
                    live = [r for r in wave if len(r.out_tokens) < r.max_new_tokens]
                    if not live:
                        break
                    step_tok = np.array(
                        [[r.out_tokens[-1]] for r in wave]
                        + [[0]] * (self.max_batch - len(wave)),
                        np.int32,
                    )
                    logits, cache = self._decode(self.params, cache, {"tokens": jnp.asarray(step_tok)})
                    nxt = self._sample(logits[:, -1, :])
                    self.stats.m_decode_steps.inc()
                    steps += 1
                    for i, r in enumerate(wave):
                        if len(r.out_tokens) < r.max_new_tokens:
                            r.out_tokens.append(int(nxt[i]))
                        else:
                            self.stats.m_idle.inc()
                jax.block_until_ready(logits)
                dspan.set(steps=steps)
            self.stats.m_decode_s.inc(time.perf_counter() - t0)
            for r in wave:
                r.done = True


# ---------------------------------------------------------------------------
# Background re-tune policy (hot-bucket re-measurement, off the request path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetunePolicy:
    """When and how a serve engine re-measures hot shape buckets.

    The tune-on-first-miss policy (``autotune=True``) blocks the first wave
    of every new bucket on a full measurement sweep — fine for benches,
    wrong for serving.  Under this policy the engine resolves new buckets
    instantly (cache hit or §3.6 heuristic) and *promotes* buckets that
    prove hot: once a bucket has served ``hot_waves`` waves, a background
    thread re-measures its candidate space with the real wave data and
    atomically swaps the winner in.  Requests never wait on a measurement,
    and because every candidate is exact, a swap mid-traffic cannot change
    any result — only its latency.

    Attributes:
      hot_waves: waves a bucket must serve before it is re-measured.
      warmup / iters: measurement discipline forwarded to the tuner
        (kept small — the measurement shares the machine with live traffic).
      max_concurrent: measurement threads allowed at once; a hot bucket
        that cannot start immediately retries on its next wave.
    """

    hot_waves: int = 32
    warmup: int = 1
    iters: int = 3
    max_concurrent: int = 1


class BackgroundRetuner:
    """Drives :class:`RetunePolicy` for one engine: counts bucket hits,
    launches measurement threads, promotes winners.

    ``measure(batch)`` must run the tuner (persisting the winner to the
    shared cache) and return the winning entry; ``promote(key, entry)``
    must atomically swap the engine's evaluator onto it (see
    ``TunedEvaluator.promote`` / ``ShardedForestEvaluator
    .invalidate_resolution``).  Both run on the worker thread — the request
    path only pays a counter increment.
    """

    def __init__(self, measure: Callable, promote: Callable, policy: RetunePolicy,
                 *, registry: obs.Registry | None = None,
                 tracer: obs.Tracer | None = None):
        self.measure = measure
        self.promote = promote
        self.policy = policy
        self.hits: dict[str, int] = {}
        self.started: set[str] = set()
        self.done: list[tuple[str, object]] = []     # (bucket key, winning entry)
        self.errors: list[tuple[str, Exception]] = []
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()
        r = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.m_launched = r.counter(
            "serve.retune.launched", "background re-tune measurements started")
        self.m_completed = r.counter(
            "serve.retune.completed", "background re-tunes promoted")
        self.m_failed = r.counter(
            "serve.retune.failed", "background re-tunes that raised")
        self.m_forced = r.counter(
            "serve.retune.forced", "re-tunes forced by the drift detector")
        self.m_measure_ms = r.histogram(
            "serve.retune.measure_ms", "background measurement wall time")

    def force(self, key: str, batch: np.ndarray) -> bool:
        """Launch a re-tune for ``key`` immediately (drift detector hook).

        Bypasses the hot-waves gate *and* the once-per-bucket ``started``
        guard — a drifted bucket was tuned for traffic that no longer
        exists, so it must be measurable again.  Still respects
        ``max_concurrent`` and never runs two measurements of the same
        bucket at once; returns False when no worker slot was available.
        """
        with self._lock:
            self._threads = [t for t in self._threads if t.is_alive()]
            if len(self._threads) >= self.policy.max_concurrent:
                return False
            if any(t.name == f"retune:{key}" for t in self._threads):
                return False
            self.started.add(key)
            snap = np.array(batch, copy=True)
            th = threading.Thread(
                target=self._work, args=(key, snap), daemon=True, name=f"retune:{key}"
            )
            self._threads.append(th)
        self.m_launched.inc()
        self.m_forced.inc()
        th.start()
        return True

    def note(self, key: str, batch: np.ndarray) -> None:
        """Record one served wave for ``key``; maybe launch a re-tune."""
        with self._lock:
            n = self.hits[key] = self.hits.get(key, 0) + 1
            if n < self.policy.hot_waves or key in self.started:
                return
            self._threads = [t for t in self._threads if t.is_alive()]
            if len(self._threads) >= self.policy.max_concurrent:
                return  # retried on the bucket's next wave
            self.started.add(key)
            snap = np.array(batch, copy=True)  # the wave buffer is reused
            th = threading.Thread(
                target=self._work, args=(key, snap), daemon=True, name=f"retune:{key}"
            )
            self._threads.append(th)
        self.m_launched.inc()
        th.start()

    def _work(self, key: str, batch: np.ndarray) -> None:
        try:
            t0 = time.perf_counter()
            with self.tracer.span("serve.retune.measure", cat="serve", bucket=key):
                entry = self.measure(batch)
            self.m_measure_ms.observe((time.perf_counter() - t0) * 1e3)
            with self.tracer.span("serve.retune.promote", cat="serve", bucket=key):
                self.promote(key, entry)
            self.m_completed.inc()
            with self._lock:
                self.done.append((key, entry))
        except Exception as e:  # a failed re-tune must never take serving down
            self.m_failed.inc()
            with self._lock:
                self.errors.append((key, e))

    def drain(self, timeout: float | None = None) -> None:
        """Join outstanding measurement threads (tests / shutdown)."""
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout)

    @property
    def retunes(self) -> int:
        with self._lock:
            return len(self.done)


# ---------------------------------------------------------------------------
# Tree-classification serving (the paper's workload as a service)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TreeRequest:
    """One classification request: a batch of records to assign classes."""

    uid: int
    records: np.ndarray                 # (m, A) float32
    out: Optional[np.ndarray] = None    # (m,) int32 once served
    done: bool = False
    # anytime serving only: per-record answer confidence in [0, 1] — 1.0
    # when the class is provably final, the partial-margin ratio when the
    # latency SLO truncated the cascade before all trees voted
    confidence: Optional[np.ndarray] = None


def _next_wave(queue: deque, max_batch: int) -> tuple[list, int]:
    """Pop the next record-count-bounded wave off the request queue.

    Greedy prefix up to ``max_batch`` total records; an oversize request
    forms a singleton wave (it cannot split across waves)."""
    wave, total = [], 0
    while queue and (not wave or total + queue[0].records.shape[0] <= max_batch):
        r = queue.popleft()
        wave.append(r)
        total += r.records.shape[0]
    return wave, total


class _ClassifierStatsBase:
    """Shared serve-engine instruments (tree + forest engines).

    One parent instrument per metric, labelled by ``engine`` so a registry
    shared across engines keeps the series apart; each stats object holds
    its engine's labelled children as ``m_*`` handles.  The pre-obs
    dataclass fields survive as read properties — including ``retunes``,
    which the :class:`BackgroundRetuner` worker increments concurrently
    with the request thread and which is exactly the counter the locked
    registry exists for.
    """

    _engine = "classifier"

    def __init__(self, registry: obs.Registry | None = None):
        self.registry = registry if registry is not None else obs.Registry()
        r, eng = self.registry, self._engine
        lbl = {"engine": eng}
        self.m_waves = r.counter(
            "serve.waves", "classification waves served", ("engine",)).labels(**lbl)
        self.m_records = r.counter(
            "serve.records", "records served", ("engine",)).labels(**lbl)
        self.m_eval_s = r.counter(
            "serve.eval_s", "wave evaluation seconds", ("engine",)).labels(**lbl)
        self.m_padded_slots = r.counter(
            "serve.padded_record_slots",
            "bucket-padding rows (the wave's idle lanes)", ("engine",)).labels(**lbl)
        self.m_retunes = r.counter(
            "serve.retunes", "background winner promotions completed",
            ("engine",)).labels(**lbl)
        self._bucket_waves = r.counter(
            "serve.bucket_waves", "waves served per shape bucket",
            ("engine", "bucket"))
        self._wave_ms = r.histogram(
            "serve.wave_ms", "wave latency per shape bucket", ("engine", "bucket"))
        self.m_queue_wait_ms = r.histogram(
            "serve.queue_wait_ms",
            "time a request waited in the queue before its wave started",
            ("engine",)).labels(**lbl)
        self.m_pad_fraction = r.histogram(
            "serve.pad_fraction", "padding rows / bucket rows per wave",
            ("engine",), boundaries=obs.DEFAULT_RATIO_BOUNDARIES).labels(**lbl)

    def wave_ms(self, bucket: str) -> obs.Histogram:
        """The wave-latency histogram series for one shape bucket."""
        return self._wave_ms.labels(engine=self._engine, bucket=bucket)

    def note_bucket_wave(self, bucket: str) -> None:
        self._bucket_waves.labels(engine=self._engine, bucket=bucket).inc()

    # -- compat read properties (the pre-obs dataclass surface) -------------

    @property
    def waves(self) -> int:
        return int(self.m_waves.value)

    @property
    def records(self) -> int:
        return int(self.m_records.value)

    @property
    def eval_s(self) -> float:
        return self.m_eval_s.value

    @property
    def padded_record_slots(self) -> int:
        return int(self.m_padded_slots.value)

    @property
    def retunes(self) -> int:
        return int(self.m_retunes.value)

    @property
    def bucket_waves(self) -> dict:
        """{bucket key: waves served} — reconstructed from the labelled series."""
        return {
            labels[1]: int(series.value)
            for labels, series in self._bucket_waves.series()
            if labels[0] == self._engine
        }


class TreeEngineStats(_ClassifierStatsBase):
    _engine = "tree"


class TreeServeEngine:
    """Wave-batched classification over one tree via autotuned dispatch.

    Requests are coalesced into waves of up to ``max_batch`` records and
    evaluated with one :class:`repro.tune.TunedEvaluator` call, which routes
    each wave through the cached-best kernel variant for its shape bucket.
    Because dispatch pads every wave to its M-bucket, steady-state traffic
    of jittery batch sizes compiles once per bucket — the serving analogue
    of the LM engine's fixed-width waves; the padding rows are recorded in
    the stats as the wave's idle-lane cost.

    Kernel selection policy: a new bucket resolves instantly (cache hit or
    the §3.6 heuristic); buckets that prove *hot* under the ``retune``
    policy are re-measured on a background thread with real wave data and
    the winner is swapped in atomically (:class:`RetunePolicy`).  The
    legacy blocking tune-on-first-miss behaviour remains available as
    ``autotune=True``.
    """

    def __init__(self, tree, *, max_batch: int = 4096, cache=None,
                 autotune: bool = False, engines=None,
                 retune: RetunePolicy | None = RetunePolicy(),
                 profile: "obs.ProfilePolicy | None" = obs.ProfilePolicy(),
                 registry: obs.Registry | None = None,
                 tracer: obs.Tracer | None = None,
                 flight: "obs.FlightPolicy | obs.FlightRecorder | None" = None):
        from repro.tune.dispatch import TunedEvaluator
        from repro.tune.measure import tune_workload
        from repro.tune.space import Candidate, WorkloadShape

        self._shape_of = WorkloadShape.of
        self.obs = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.flight = _make_flight(flight, self.obs, self.tracer, "tree")
        self.profiler: obs.TraversalProfiler | None = None
        if profile is not None:
            from repro.kernels.tree_eval.profile import profile_tree_eval

            def _profile_fn(batch, _tree=tree):
                return profile_tree_eval(batch, _tree)

            def _on_drift(key, distance, records):
                # drift = the bucket's tuned winner was picked for traffic
                # that no longer exists: annotate the flight ring and force
                # a background re-measurement on the drifted records
                if self.flight is not None:
                    self.flight.note_drift(bucket=key, distance=distance,
                                           engine="tree")
                if self.retuner is not None:
                    self.retuner.force(key, records)

            self.profiler = obs.TraversalProfiler(
                _profile_fn, profile, registry=self.obs, tracer=self.tracer,
                n_nodes=int(tree.n_nodes), on_drift=_on_drift, engine="tree")
        self._eval = TunedEvaluator(
            tree, cache=cache, autotune=autotune, engines=engines,
            registry=self.obs, tracer=self.tracer, profiler=self.profiler,
        )
        self.tree = tree
        self.max_batch = max_batch
        self.stats = TreeEngineStats(self.obs)
        self.retuner: BackgroundRetuner | None = None
        if retune is not None:

            def measure(batch):
                entry, _ = tune_workload(
                    batch, tree, cache=self._eval.cache, engines=engines,
                    warmup=retune.warmup, iters=retune.iters,
                    registry=self.obs,
                )
                return entry

            def promote(key, entry):
                self._eval.promote(key, Candidate.make(entry.variant, **entry.params))
                # locked counter, not `+= 1` on a plain field: this runs on
                # the retuner worker concurrently with the request thread
                self.stats.m_retunes.inc()

            self.retuner = BackgroundRetuner(
                measure, promote, retune, registry=self.obs, tracer=self.tracer)

    def run(self, requests: list[TreeRequest]) -> list[TreeRequest]:
        """Serve all requests in record-count-bounded waves."""
        queue = deque(requests)
        t_enq = time.perf_counter()
        for r in queue:
            r._t_enqueue = t_enq
        while queue:
            self._run_wave(*_next_wave(queue, self.max_batch))
        return requests

    def _run_wave(self, wave: list[TreeRequest], total: int) -> None:
        t_wave = time.perf_counter()
        for r in wave:
            enq = getattr(r, "_t_enqueue", None)
            if enq is not None:
                self.stats.m_queue_wait_ms.observe((t_wave - enq) * 1e3)
        self.stats.m_waves.inc()
        self.stats.m_records.inc(total)
        batch = np.concatenate([r.records for r in wave], axis=0).astype(np.float32)
        shape = self._shape_of(batch, self.tree, self._eval.depth)
        key = shape.key()
        bucket_m = shape.bucket().m
        self.stats.m_padded_slots.inc(bucket_m - total)
        self.stats.m_pad_fraction.observe((bucket_m - total) / max(bucket_m, 1))
        with self.tracer.span("serve.wave", cat="serve", engine="tree",
                              requests=len(wave), records=total, bucket=key):
            t0 = time.perf_counter()
            try:
                with self.tracer.span("kernel.dispatch", cat="kernel", bucket=key):
                    out = np.asarray(jax.block_until_ready(self._eval(batch)))
            except BaseException as exc:
                if self.flight is not None:
                    self.flight.note_exception(exc)
                raise
            dt = time.perf_counter() - t0
        self.stats.m_eval_s.inc(dt)
        self.stats.wave_ms(key).observe(dt * 1e3)
        if self.flight is not None:
            self.flight.note_wave(latency_ms=dt * 1e3, bucket=key,
                                  records=total, requests=len(wave))
        off = 0
        for r in wave:
            m = r.records.shape[0]
            r.out = out[off:off + m]
            r.done = True
            off += m
        self.stats.note_bucket_wave(key)
        if self.profiler is not None:
            self.profiler.note_wave(key, batch)
        if self.retuner is not None:
            self.retuner.note(key, batch)

    def dump_flight(self, reason: str = "manual"):
        """Write a flight-recorder debug bundle now; returns its path.

        Requires the engine to have been built with ``flight=``.
        """
        if self.flight is None:
            raise RuntimeError("engine built without flight= recorder")
        return self.flight.dump(reason)


# ---------------------------------------------------------------------------
# Sharded-forest serving (repro.dist as a service)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnytimePolicy:
    """Anytime serving: answer within an SLO by truncating cascade stages.

    The engine evaluates each wave through an early-exit cascade
    (:class:`repro.kernels.tree_eval.CascadeEvaluator`) with the SLO as the
    per-wave deadline: stage 0 always runs, and each later stage runs only
    if its predicted latency (per-stage EMA) fits the remaining budget.
    Requests report per-record ``confidence`` — 1.0 where the class is
    provably final, the partial-margin ratio where the deadline cut the
    forest short.

    Attributes:
      slo_ms: per-wave latency budget in milliseconds.
      stages: cascade stage count (more stages = finer truncation grain).
      bound: early-exit bound; 1.0 keeps non-truncated answers exact.
      calibration_sample: records from the first wave used to rank trees.
    """

    slo_ms: float
    stages: int = 3
    bound: float = 1.0
    calibration_sample: int = 512


class ForestEngineStats(_ClassifierStatsBase):
    _engine = "forest"

    def __init__(self, registry: obs.Registry | None = None):
        super().__init__(registry)
        r = self.registry
        lbl = {"engine": self._engine}
        self.m_chunks = r.counter(
            "serve.chunks", "streaming chunks across all waves",
            ("engine",)).labels(**lbl)
        self.m_chunk_ms = r.histogram(
            "serve.chunk_ms", "per-chunk latency", ("engine",)).labels(**lbl)
        self.m_anytime_waves = r.counter(
            "serve.anytime.waves", "waves served through the anytime cascade")
        self.m_anytime_truncations = r.counter(
            "serve.anytime.truncations",
            "waves the SLO stopped before the last stage")
        self.m_anytime_stages = r.histogram(
            "serve.anytime.stages_run", "cascade stages run per anytime wave",
            boundaries=_STAGE_BOUNDARIES)
        self.m_anytime_confidence = r.histogram(
            "serve.anytime.confidence", "per-record answer confidence",
            boundaries=obs.DEFAULT_RATIO_BOUNDARIES)
        # raw per-chunk / per-wave sequences survive as plain lists — benches
        # take medians over them and tests index into them
        self.chunk_ms: list = []
        self.anytime_stages: list = []

    @property
    def chunks(self) -> int:
        return int(self.m_chunks.value)

    @property
    def anytime_waves(self) -> int:
        return int(self.m_anytime_waves.value)

    @property
    def anytime_truncations(self) -> int:
        return int(self.m_anytime_truncations.value)


class ForestServeEngine:
    """Wave-batched forest classification over the device mesh.

    The forest analogue of :class:`TreeServeEngine`: requests coalesce into
    waves of up to ``max_batch`` records, each wave runs through the
    ``repro.dist`` sharded executor behind a streaming chunker, so
    host→device transfer of one chunk overlaps evaluation of the previous
    (double buffering).  Per-chunk latencies land in ``stats.chunk_ms`` —
    the same accounting ``TreeServeEngine`` keeps per wave, at chunk
    granularity.  With ``n_classes`` set, requests get majority-vote
    classes (m,); otherwise per-tree assignments (T, m).

    Hot forest buckets are re-measured in the background under the
    ``retune`` policy (all three forest candidate families, real wave
    data); the freshly stored winner is picked up atomically via
    ``ShardedForestEvaluator.invalidate_resolution`` — see
    :class:`RetunePolicy`.
    """

    def __init__(self, forest, *, max_batch: int = 65536, chunk_records: int = 8192,
                 n_classes: Optional[int] = None, mesh=None, plan=None,
                 decomposition=None, cache=None, autotune: bool = False, engines=None,
                 layouts=None,
                 retune: RetunePolicy | None = RetunePolicy(),
                 anytime: AnytimePolicy | None = None,
                 profile: "obs.ProfilePolicy | None" = obs.ProfilePolicy(),
                 registry: obs.Registry | None = None,
                 tracer: obs.Tracer | None = None,
                 flight: "obs.FlightPolicy | obs.FlightRecorder | None" = None):
        from repro.dist import ShardedForestEvaluator, StreamingChunker

        if anytime is not None and n_classes is None:
            raise ValueError("anytime serving needs n_classes (it votes classes)")
        self.obs = registry if registry is not None else obs.Registry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.flight = _make_flight(flight, self.obs, self.tracer, "forest")
        self.profiler: obs.TraversalProfiler | None = None
        if profile is not None:

            def _profile_fn(batch):
                # deferred attribute access: self.forest is the executor's
                # normalised EncodedForest, assigned a few lines below
                from repro.kernels.tree_eval.profile import profile_forest_eval

                return profile_forest_eval(batch, self.forest)

            def _on_drift(key, distance, records):
                if self.flight is not None:
                    self.flight.note_drift(bucket=key, distance=distance,
                                           engine="forest")
                if self.retuner is not None:
                    self.retuner.force(key, records)

            self.profiler = obs.TraversalProfiler(
                _profile_fn, profile, registry=self.obs, tracer=self.tracer,
                n_classes=n_classes, on_drift=_on_drift, engine="forest")
        self._eval = ShardedForestEvaluator(
            forest, mesh=mesh, plan=plan, decomposition=decomposition,
            cache=cache, autotune=autotune, engines=engines, layouts=layouts,
            registry=self.obs, tracer=self.tracer, profiler=self.profiler,
        )
        self._chunker = StreamingChunker(
            self._eval, chunk_records=chunk_records,
            registry=self.obs, tracer=self.tracer,
        )
        self.forest = self._eval.forest
        self.max_batch = max_batch
        self.n_classes = n_classes
        self.anytime = anytime
        self._cascade = None   # built lazily: calibrated on the first wave
        self.stats = ForestEngineStats(self.obs)
        self.retuner: BackgroundRetuner | None = None
        if retune is not None:

            def measure(batch):
                # the executor owns key consistency: single-device measures
                # the forest bucket, a mesh measures the *shard* operating
                # point — either way the winner lands where the next
                # resolution looks
                return self._eval.retune(batch, warmup=retune.warmup, iters=retune.iters)

            def promote(key, entry):
                # the measurement already stored the winner; dropping
                # resolution state makes the next wave pick it up — the
                # executor-level analogue of TunedEvaluator.promote
                self._eval.invalidate_resolution()
                # locked counter, not `+= 1` on a plain field: this runs on
                # the retuner worker concurrently with the request thread
                self.stats.m_retunes.inc()

            self.retuner = BackgroundRetuner(
                measure, promote, retune, registry=self.obs, tracer=self.tracer)

    @property
    def plan(self):
        """The executor's chosen ShardPlan (None until the first wave)."""
        return self._eval.plan

    def run(self, requests: list[TreeRequest]) -> list[TreeRequest]:
        """Serve all requests in record-count-bounded waves."""
        queue = deque(requests)
        t_enq = time.perf_counter()
        for r in queue:
            r._t_enqueue = t_enq
        while queue:
            self._run_wave(*_next_wave(queue, self.max_batch))
        return requests

    def _anytime_cascade(self, batch: np.ndarray):
        """The wave cascade, built once and calibrated on the first wave."""
        if self._cascade is None:
            from repro.kernels.tree_eval import CascadeEvaluator

            pol = self.anytime
            self._cascade = CascadeEvaluator(
                self.forest,
                n_classes=self.n_classes,
                bound=pol.bound,
                stages=pol.stages,
                calibration=batch[: pol.calibration_sample],
                registry=self.obs,
                tracer=self.tracer,
            )
        return self._cascade

    def _run_wave(self, wave: list[TreeRequest], total: int) -> None:
        try:
            self._run_wave_inner(wave, total)
        except BaseException as exc:
            if self.flight is not None:
                self.flight.note_exception(exc)
            raise

    def _run_wave_inner(self, wave: list[TreeRequest], total: int) -> None:
        t_wave = time.perf_counter()
        for r in wave:
            enq = getattr(r, "_t_enqueue", None)
            if enq is not None:
                self.stats.m_queue_wait_ms.observe((t_wave - enq) * 1e3)
        self.stats.m_waves.inc()
        self.stats.m_records.inc(total)
        batch = np.concatenate([r.records for r in wave], axis=0).astype(np.float32)
        wspan = self.tracer.span(
            "serve.wave", cat="serve", engine="forest",
            requests=len(wave), records=total,
            mode="anytime" if self.anytime is not None else "stream",
        )
        with wspan:
            if self.anytime is not None:
                # anytime path: the cascade owns staging/early exit, so the
                # wave bypasses the chunker — the SLO check needs whole-stage
                # latencies
                cascade = self._anytime_cascade(batch)
                t0 = time.perf_counter()
                res = cascade(batch, deadline_ms=self.anytime.slo_ms)
                dt = time.perf_counter() - t0
                self.stats.m_eval_s.inc(dt)
                self.stats.m_anytime_waves.inc()
                self.stats.m_anytime_stages.observe(res.stages_run)
                self.stats.anytime_stages.append(res.stages_run)
                # truncation = the deadline (not the exit bound) stopped the
                # run: some record never cleared the bound yet has trees left
                # unvoted
                truncated = res.stages_run < cascade.plan.n_stages and bool(
                    np.any(
                        (res.exit_stage < 0)
                        & (res.trees_evaluated < cascade.plan.n_trees)
                    )
                )
                if truncated:
                    self.stats.m_anytime_truncations.inc()
                self.stats.m_anytime_confidence.observe_many(
                    np.asarray(res.confidence, dtype=np.float64))
                wspan.set(stages_run=res.stages_run, truncated=truncated)
                off = 0
                for r in wave:
                    m = r.records.shape[0]
                    r.out = res.classes[off:off + m]
                    r.confidence = res.confidence[off:off + m]
                    r.done = True
                    off += m
            else:
                def on_chunk(latency_ms: float, n: int) -> None:
                    self.stats.m_chunks.inc()
                    self.stats.m_chunk_ms.observe(latency_ms)
                    self.stats.chunk_ms.append(latency_ms)

                t0 = time.perf_counter()
                per_tree = self._chunker.eval(batch, on_chunk=on_chunk)   # (T, total)
                if self.n_classes is not None:
                    from repro.core.forest import majority_vote

                    with self.tracer.span("serve.vote", cat="serve", records=total):
                        out = np.asarray(
                            majority_vote(jnp.asarray(per_tree), self.n_classes))
                else:
                    out = per_tree
                dt = time.perf_counter() - t0
                self.stats.m_eval_s.inc(dt)
                off = 0
                for r in wave:
                    m = r.records.shape[0]
                    r.out = out[off:off + m] if self.n_classes is not None else out[:, off:off + m]
                    r.done = True
                    off += m
            key = self._eval._forest_evaluator().shape_of(batch).key()
            wspan.set(bucket=key)
        self.stats.wave_ms(key).observe(dt * 1e3)
        self.stats.note_bucket_wave(key)
        if self.flight is not None:
            self.flight.note_wave(
                latency_ms=dt * 1e3, bucket=key, records=total,
                requests=len(wave),
                mode="anytime" if self.anytime is not None else "stream",
            )
        if self.profiler is not None:
            self.profiler.note_wave(key, batch)
        if self.retuner is not None:
            self.retuner.note(key, batch)

    def dump_flight(self, reason: str = "manual"):
        """Write a flight-recorder debug bundle now; returns its path.

        Requires the engine to have been built with ``flight=``.
        """
        if self.flight is None:
            raise RuntimeError("engine built without flight= recorder")
        return self.flight.dump(reason)
