"""Classification-tree structures and the branchless breadth-first encoding.

Implements Procedure 1 of Spencer (2011), *Speculative Parallel Evaluation of
Classification Trees on GPGPU Compute Engines*:

    The tree is stored as a flat array in breadth-first order.  Every right
    child has index ``leftChild + 1`` so each node stores a single
    ``childIndex`` and the next node during evaluation is computed without a
    branch as ``next = childIndex + (r_a > t)``.

Leaf encoding
-------------
The paper states leaves "always evaluate to themselves by setting their
threshold to -inf and their child index to be their own index".  With the
paper's strict ``>`` predicate a ``-inf`` threshold would yield
``next = self + 1``; the self-loop requires the predicate to be *false*, so we
encode leaf thresholds as ``+inf`` (an erratum-level fix that preserves the
paper's intent: ``r_a > +inf`` is false for all finite/NaN attributes, hence
``next = childIndex + 0 = self``).  NaN attribute values compare false against
any threshold and therefore deterministically take the left branch, matching
IEEE semantics of the branchless predicate.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator, NamedTuple, Optional, Sequence

import numpy as np

BOTTOM = -1  # class sentinel for internal nodes (the paper's "⊥")


@dataclasses.dataclass
class Node:
    """A linked classification-tree node (pre-encoding).

    Internal nodes carry ``(attr, threshold)`` and two children; leaves carry
    ``class_val`` only.  Trees are *full* binary trees: every internal node
    has exactly two children (CART and the paper both guarantee this).
    """

    attr: int = 0
    threshold: float = 0.0
    left: Optional["Node"] = None
    right: Optional["Node"] = None
    class_val: int = BOTTOM

    @property
    def is_leaf(self) -> bool:
        return self.left is None and self.right is None

    def validate(self) -> None:
        if self.is_leaf:
            if self.class_val == BOTTOM:
                raise ValueError("leaf node missing class value")
        else:
            if self.left is None or self.right is None:
                raise ValueError("internal node must have both children (full binary tree)")
            if self.class_val != BOTTOM:
                raise ValueError("internal node must have class ⊥")
            self.left.validate()
            self.right.validate()

    def depth(self) -> int:
        """Depth in *edges* on the longest root→leaf path (single leaf = 0)."""
        if self.is_leaf:
            return 0
        return 1 + max(self.left.depth(), self.right.depth())

    def count_nodes(self) -> int:
        if self.is_leaf:
            return 1
        return 1 + self.left.count_nodes() + self.right.count_nodes()

    def count_leaves(self) -> int:
        if self.is_leaf:
            return 1
        return self.left.count_leaves() + self.right.count_leaves()

    def iter_breadth_first(self) -> Iterator["Node"]:
        q: deque[Node] = deque([self])
        while q:
            n = q.popleft()
            yield n
            if not n.is_leaf:
                q.append(n.left)
                q.append(n.right)


class EncodedTree(NamedTuple):
    """Branchless breadth-first array encoding (Procedure 1).

    All fields are dense arrays of length ``n_nodes`` (padded length when a
    kernel requires lane alignment — padding nodes are self-looping leaves
    with ``class_val = 0`` that are unreachable from the root).

    attr_idx:  int32 (N,)  attribute index evaluated by node ``i``
    threshold: float32 (N,)  decision threshold (``+inf`` for leaves)
    child:     int32 (N,)  left-child index; right child is ``child+1``;
               leaves store their own index (self-loop)
    class_val: int32 (N,)  assigned class for leaves, ``-1`` (⊥) for internal
    """

    attr_idx: np.ndarray
    threshold: np.ndarray
    child: np.ndarray
    class_val: np.ndarray

    @property
    def n_nodes(self) -> int:
        return int(self.attr_idx.shape[-1])

    @property
    def is_leaf_mask(self) -> np.ndarray:
        return np.asarray(self.class_val) != BOTTOM

    @property
    def n_leaves(self) -> int:
        return int(self.is_leaf_mask.sum())

    @property
    def n_internal(self) -> int:
        return self.n_nodes - self.n_leaves


def breadth_first_encode(root: Node) -> EncodedTree:
    """Procedure 1: breadth-first branchless encoding of a full binary tree."""
    root.validate()
    n_nodes = root.count_nodes()
    attr_idx = np.zeros((n_nodes,), np.int32)
    threshold = np.zeros((n_nodes,), np.float32)
    child = np.zeros((n_nodes,), np.int32)
    class_val = np.full((n_nodes,), BOTTOM, np.int32)

    # Procedure 1, with the queue carrying (node, my_index).
    q: deque[Node] = deque([root])
    child_index = 1
    i = 0
    while q:
        n = q.popleft()
        attr_idx[i] = n.attr
        if n.is_leaf:
            threshold[i] = np.inf  # predicate always false -> self-loop
            child[i] = i
            class_val[i] = n.class_val
        else:
            threshold[i] = n.threshold
            child[i] = child_index
            q.append(n.left)
            child_index += 1
            q.append(n.right)
            child_index += 1
        i += 1
    return EncodedTree(attr_idx, threshold, child, class_val)


def decode_to_linked(enc: EncodedTree) -> Node:
    """Inverse of :func:`breadth_first_encode` (for round-trip testing)."""
    leaf = enc.is_leaf_mask
    nodes = [Node() for _ in range(enc.n_nodes)]
    for i in range(enc.n_nodes):
        if leaf[i]:
            nodes[i].class_val = int(enc.class_val[i])
        else:
            nodes[i].attr = int(enc.attr_idx[i])
            nodes[i].threshold = float(enc.threshold[i])
            nodes[i].left = nodes[int(enc.child[i])]
            nodes[i].right = nodes[int(enc.child[i]) + 1]
    return nodes[0]


def tree_depth(enc: EncodedTree) -> int:
    """Longest root→leaf path (edges) from the encoded form."""
    depth = np.zeros((enc.n_nodes,), np.int64)
    best = 0
    # BFS order guarantees parents precede children.
    leaf = enc.is_leaf_mask
    for i in range(enc.n_nodes):
        if leaf[i]:
            best = max(best, int(depth[i]))
        else:
            c = int(enc.child[i])
            depth[c] = depth[i] + 1
            depth[c + 1] = depth[i] + 1
    return best


def node_depths(enc: EncodedTree) -> np.ndarray:
    """Per-node depth (root = 0)."""
    depth = np.zeros((enc.n_nodes,), np.int64)
    leaf = enc.is_leaf_mask
    for i in range(enc.n_nodes):
        if not leaf[i]:
            c = int(enc.child[i])
            depth[c] = depth[i] + 1
            depth[c + 1] = depth[i] + 1
    return depth


def validate_encoding(enc: EncodedTree) -> None:
    """Structural invariants of the breadth-first branchless encoding.

    Used by property tests: BFS order implies ``child[i] > i`` for internal
    nodes and children appear in increasing order; leaves self-loop with
    ``+inf`` thresholds; every non-root node has exactly one parent.
    """
    n = enc.n_nodes
    leaf = enc.is_leaf_mask
    indeg = np.zeros((n,), np.int64)
    for i in range(n):
        if leaf[i]:
            if enc.child[i] != i:
                raise ValueError(f"leaf {i} does not self-loop")
            if not np.isposinf(enc.threshold[i]):
                raise ValueError(f"leaf {i} threshold must be +inf")
            if enc.class_val[i] == BOTTOM:
                raise ValueError(f"leaf {i} missing class")
        else:
            c = int(enc.child[i])
            if not (i < c and c + 1 < n):
                raise ValueError(f"internal {i} child {c} violates BFS order")
            if enc.class_val[i] != BOTTOM:
                raise ValueError(f"internal {i} has class value")
            indeg[c] += 1
            indeg[c + 1] += 1
    if indeg[0] != 0:
        raise ValueError("root has a parent")
    bad = np.nonzero(indeg[1:] != 1)[0]
    if bad.size:
        raise ValueError(f"nodes {bad + 1} do not have exactly one parent")


# ---------------------------------------------------------------------------
# Procedure-5 support tables
# ---------------------------------------------------------------------------


def leaf_paths(enc: EncodedTree) -> np.ndarray:
    """Static ``path`` initialisation (Procedure 5 ``leafPaths``).

    Leaves map to themselves; internal entries are arbitrary (0) because the
    node-evaluation step overwrites them for every record.
    """
    n = enc.n_nodes
    out = np.zeros((n,), np.int32)
    leaf = enc.is_leaf_mask
    out[leaf] = np.nonzero(leaf)[0].astype(np.int32)
    return out


def processor_node_map(enc: EncodedTree) -> np.ndarray:
    """Procedure 5 ``processorNodeMap``: indices of the internal nodes.

    Processor ``p`` in a record group evaluates node ``processorNodeMap[p]``;
    only ``(N-1)/2`` processors (for a full tree) do productive work.
    """
    return np.nonzero(~enc.is_leaf_mask)[0].astype(np.int32)


def pad_tree(enc: EncodedTree, n_padded: int) -> EncodedTree:
    """Pad the node array to ``n_padded`` with unreachable self-loop leaves.

    Padding keeps lane alignment for the TPU kernels (multiples of 128).  The
    phantom nodes are leaves with class 0 that no internal node points to, so
    they never influence results — mirroring the paper's "phantom node" used
    to fill the 16-thread half-warp for a 15-internal-node tree.
    """
    n = enc.n_nodes
    if n_padded < n:
        raise ValueError(f"cannot pad {n} nodes down to {n_padded}")
    if n_padded == n:
        return enc
    pad = n_padded - n
    idx = np.arange(n, n_padded, dtype=np.int32)
    return EncodedTree(
        np.concatenate([enc.attr_idx, np.zeros((pad,), np.int32)]),
        np.concatenate([enc.threshold, np.full((pad,), np.inf, np.float32)]),
        np.concatenate([enc.child, idx]),
        np.concatenate([enc.class_val, np.zeros((pad,), np.int32)]),
    )


def attr_select_matrix(enc: EncodedTree, n_attrs: int, dtype=np.float32) -> np.ndarray:
    """One-hot attribute-selection matrix ``S[a, n] = 1 ⇔ attr_idx[n] == a``.

    The TPU-native replacement for the CUDA shared-memory gather in the node-
    evaluation step: ``vals[R, N] = records[R, A] @ S[A, N]`` puts node ``n``'s
    attribute value in lane ``n`` via a single MXU matmul.
    """
    out = np.zeros((n_attrs, enc.n_nodes), dtype)
    out[enc.attr_idx, np.arange(enc.n_nodes)] = 1
    return out


# ---------------------------------------------------------------------------
# Random tree generation (tests / geometry sweeps, paper §6 future work)
# ---------------------------------------------------------------------------


def random_tree(
    *,
    n_attrs: int,
    n_classes: int,
    max_depth: int,
    seed: int = 0,
    balance: float = 1.0,
    min_depth: int = 1,
) -> Node:
    """Generate a random full binary classification tree.

    ``balance`` in (0, 1]: probability that a node at depth < max_depth keeps
    splitting; 1.0 yields a perfect tree of depth ``max_depth``, small values
    yield shallow straggly trees (the paper's §6 geometry-sweep axis).
    """
    rng = np.random.default_rng(seed)

    def build(depth: int) -> Node:
        must_split = depth < min_depth
        may_split = depth < max_depth
        if may_split and (must_split or rng.random() < balance):
            return Node(
                attr=int(rng.integers(0, n_attrs)),
                threshold=float(np.round(rng.normal(), 4)),
                left=build(depth + 1),
                right=build(depth + 1),
            )
        return Node(class_val=int(rng.integers(0, n_classes)))

    root = build(0)
    if root.is_leaf:  # guarantee at least one split
        root = Node(
            attr=0,
            threshold=0.0,
            left=Node(class_val=0),
            right=Node(class_val=min(1, n_classes - 1)),
        )
    return root


def perfect_tree(depth: int, n_attrs: int, n_classes: int, seed: int = 0) -> Node:
    """A perfectly balanced tree of the given depth."""
    return random_tree(
        n_attrs=n_attrs,
        n_classes=n_classes,
        max_depth=depth,
        min_depth=depth,
        seed=seed,
        balance=1.0,
    )


def paper_tree(seed: int = 7) -> Node:
    """A tree with the same geometry class as the paper's experimental tree.

    The paper's Orange-trained classifier has N=31 nodes, 16 leaves and depth
    11 (an unbalanced full binary tree over 19 attributes and 7 classes).  We
    rebuild an equivalent-geometry tree deterministically: 15 internal nodes
    forming a depth-11 "vine with bushes" shape.
    """
    rng = np.random.default_rng(seed)

    def leaf() -> Node:
        return Node(class_val=int(rng.integers(0, 7)))

    def split(left: Node, right: Node) -> Node:
        return Node(
            attr=int(rng.integers(0, 19)),
            threshold=float(np.round(rng.normal(), 4)),
            left=left,
            right=right,
        )

    # Build a depth-11 spine of 11 internal nodes, then attach 4 more splits
    # along the upper spine to reach 15 internal / 16 leaves.
    node = split(leaf(), leaf())  # depth counted from here upward
    for _ in range(10):
        node = split(node, leaf())
    # node now: depth 11, 11 internal, 12 leaves. Add 4 splits on right leaves.
    for _ in range(4):
        cur = node
        while not cur.right.is_leaf:
            cur = cur.right
        cur.right = split(leaf(), leaf())
    assert node.count_nodes() == 31 and node.count_leaves() == 16
    assert node.depth() == 11
    return node


def forest_of(trees: Sequence[Node]) -> list[EncodedTree]:
    return [breadth_first_encode(t) for t in trees]
