"""Paper §3.6 — asymptotic runtime models for Procedures 2, 3 and 5.

Implements the closed-form run-time, speedup and efficiency expressions and
the speculative-wins crossover bound (equation 1):

    T₂        = M · d_µ · (t_e + t_c)
    T₃(P)     = (M/P) · d_µ · (t_e + t_c) + t_i + t_s(M)
    T₅(P)     = (M·p/P) · (t_e + log₂(d_µ)·t_c) + t_i + t_s(M)
    S_k(P)    = T₂ / T_k(P)
    E_k(P)    = S_k(P) / P
    speculative beats data decomposition  ⇔  p < 2·d_µ / (1 + log₂ d_µ)

with t_s(M) = σ·M + γ (shared-memory transmission), t_i indexing overhead.
These curves are plotted by ``benchmarks/analysis_curves.py`` and the
crossover is property-tested against the closed forms.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Machine/workload constants of §3.6."""

    t_e: float = 1.0          # node attribute-vs-threshold evaluation time
    t_c: float = 1.0          # class-vs-⊥ comparison time
    t_i: float = 0.0          # per-processor dataset-indexing time
    sigma: float = 0.0        # per-record shared-memory transmission slope
    gamma: float = 0.0        # transmission intercept

    @property
    def t_n(self) -> float:
        """Node evaluation time t_n = t_e + t_c."""
        return self.t_e + self.t_c

    def t_s(self, m: float) -> float:
        return self.sigma * m + self.gamma


def t2_serial(m: float, d_mu: float, cm: CostModel = CostModel()) -> float:
    return m * d_mu * cm.t_n


def t3_data_parallel(m: float, d_mu: float, p_total: float, cm: CostModel = CostModel()) -> float:
    return (m / p_total) * d_mu * cm.t_n + cm.t_i + cm.t_s(m)


def t5_speculative(
    m: float, d_mu: float, p_total: float, p_group: float, cm: CostModel = CostModel()
) -> float:
    return (m * p_group / p_total) * (cm.t_e + math.log2(d_mu) * cm.t_c) + cm.t_i + cm.t_s(m)


def s3_speedup(m, d_mu, p_total, cm: CostModel = CostModel()):
    return t2_serial(m, d_mu, cm) / t3_data_parallel(m, d_mu, p_total, cm)


def s5_speedup(m, d_mu, p_total, p_group, cm: CostModel = CostModel()):
    return t2_serial(m, d_mu, cm) / t5_speculative(m, d_mu, p_total, p_group, cm)


def e3_efficiency(m, d_mu, p_total, cm: CostModel = CostModel()):
    return s3_speedup(m, d_mu, p_total, cm) / p_total


def e5_efficiency(m, d_mu, p_total, p_group, cm: CostModel = CostModel()):
    return s5_speedup(m, d_mu, p_total, p_group, cm) / p_total


def crossover_group_size(d_mu: float) -> float:
    """Equation (1): speculative wins iff p_group < 2·d_µ/(1 + log₂ d_µ).

    (Derived under t_e ≈ t_c; the paper notes the slope is ≈ 1/3 for
    practical d_µ, so only shallow trees or small groups benefit under the
    *independent-processor* model — the SIMD experiments then show the model's
    assumptions are what break on real hardware.)
    """
    if d_mu <= 1:
        return 2.0 * d_mu
    return 2.0 * d_mu / (1.0 + math.log2(d_mu))


def speculative_wins(d_mu: float, p_group: float) -> bool:
    return p_group < crossover_group_size(d_mu)


def mean_traversal_depth(depths: np.ndarray) -> float:
    """d_µ estimated from observed per-record leaf depths (paper: measured on
    a significant sample such as the training set)."""
    return float(np.asarray(depths).mean())


def speculation_waste_ratio(n_nodes: float, d_mu: float) -> float:
    """§3.6 speculative waste: node evaluations per record, all-N vs d_µ.

    Procedure 5 evaluates every one of the ``N`` nodes for each record where
    the divergent descent touches only ``d_µ`` on average — the ratio
    ``N / d_µ`` is the work multiplier speculation pays for its shallower
    critical path.  Measured d_µ (from the traversal profiler) makes this
    the *observed* waste rather than the geometry-prior estimate.
    """
    return float(n_nodes) / max(float(d_mu), 1.0)


def level_active_fractions(depths: np.ndarray, max_depth: int) -> np.ndarray:
    """Fraction of records still descending when entering each round.

    ``out[l] = mean(depth > l)`` for ``l in range(max_depth)`` — the
    active-lane occupancy the paper's SIMD analysis charges idle processors
    for at every level below a record's exit depth.
    """
    depths = np.asarray(depths)
    return np.array(
        [float((depths > l).mean()) for l in range(int(max_depth))], np.float64
    )


def observed_depths(enc, records) -> np.ndarray:
    """Per-record traversal depth under the branchless descent (host)."""
    from repro.core.tree import BOTTOM

    records = np.asarray(records)
    m = records.shape[0]
    out = np.zeros((m,), np.int64)
    for r in range(m):
        i, d = 0, 0
        while enc.class_val[i] == BOTTOM:
            i = int(enc.child[i]) + int(records[r, enc.attr_idx[i]] > enc.threshold[i])
            d += 1
        out[r] = d
    return out
