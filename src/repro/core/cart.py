"""CART classification-tree training (Gini impurity, continuous attributes).

The paper trains its classifier offline with the Orange library and focuses on
evaluation.  Per the build-every-substrate rule we implement the trainer
ourselves: a standard CART — exhaustive axis-aligned threshold search
minimising weighted Gini impurity, recursive splitting until purity,
``max_depth`` or ``min_samples_split``.  Produces full binary trees with
continuous attributes, exactly the tree class the paper's evaluator assumes
(§2.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.tree import Node


@dataclasses.dataclass(frozen=True)
class CartConfig:
    max_depth: int = 16
    min_samples_split: int = 2
    min_gain: float = 1e-7
    max_thresholds_per_attr: int = 64  # subsample candidate thresholds when large


def _gini(counts: np.ndarray) -> float:
    tot = counts.sum()
    if tot == 0:
        return 0.0
    p = counts / tot
    return float(1.0 - (p * p).sum())


def _best_split(x: np.ndarray, y: np.ndarray, n_classes: int, cfg: CartConfig):
    """Exhaustive (attr, threshold) search minimising weighted Gini.

    Uses the classic sorted-prefix-count sweep: O(A · M log M).
    Returns (gain, attr, threshold) or None.
    """
    m, n_attrs = x.shape
    parent_counts = np.bincount(y, minlength=n_classes)
    parent_gini = _gini(parent_counts)
    best = None
    for a in range(n_attrs):
        order = np.argsort(x[:, a], kind="stable")
        xs = x[order, a]
        ys = y[order]
        # candidate split positions: where consecutive sorted values differ
        diff = np.nonzero(xs[1:] > xs[:-1])[0]
        if diff.size == 0:
            continue
        if diff.size > cfg.max_thresholds_per_attr:
            sel = np.linspace(0, diff.size - 1, cfg.max_thresholds_per_attr).astype(int)
            diff = diff[sel]
        # prefix class counts
        onehot = np.zeros((m, n_classes), np.int64)
        onehot[np.arange(m), ys] = 1
        prefix = onehot.cumsum(axis=0)  # prefix[i] = counts of ys[:i+1]
        for pos in diff:
            left = prefix[pos]
            right = parent_counts - left
            nl, nr = pos + 1, m - pos - 1
            g = (nl * _gini(left) + nr * _gini(right)) / m
            gain = parent_gini - g
            if best is None or gain > best[0]:
                # paper predicate is r > t  →  right; so threshold is the
                # left-group max: values ≤ t go left.
                thr = float(xs[pos])
                best = (gain, a, thr)
    return best


def _majority(y: np.ndarray, n_classes: int) -> int:
    return int(np.bincount(y, minlength=n_classes).argmax())


def train_cart(
    x: np.ndarray,
    y: np.ndarray,
    n_classes: int | None = None,
    cfg: CartConfig = CartConfig(),
) -> Node:
    """Train a CART classification tree.

    Args:
      x: (M, A) float features.
      y: (M,) int class labels in [0, n_classes).

    Returns:
      root :class:`Node` of a full binary tree.
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.int64)
    if n_classes is None:
        n_classes = int(y.max()) + 1

    def build(idx: np.ndarray, depth: int) -> Node:
        ys = y[idx]
        if (
            depth >= cfg.max_depth
            or idx.size < cfg.min_samples_split
            or np.all(ys == ys[0])
        ):
            return Node(class_val=_majority(ys, n_classes))
        found = _best_split(x[idx], ys, n_classes, cfg)
        if found is None or found[0] <= cfg.min_gain:
            return Node(class_val=_majority(ys, n_classes))
        _, a, thr = found
        mask = x[idx, a] > thr
        right_idx = idx[mask]
        left_idx = idx[~mask]
        if left_idx.size == 0 or right_idx.size == 0:
            return Node(class_val=_majority(ys, n_classes))
        return Node(
            attr=a,
            threshold=thr,
            left=build(left_idx, depth + 1),
            right=build(right_idx, depth + 1),
        )

    root = build(np.arange(x.shape[0]), 0)
    if root.is_leaf:
        # degenerate dataset: wrap in a trivial split so downstream code
        # always sees ≥1 internal node (a full binary tree).
        root = Node(attr=0, threshold=np.float64(np.inf), left=Node(class_val=root.class_val),
                    right=Node(class_val=root.class_val))
    return root


def accuracy(pred: np.ndarray, y: np.ndarray) -> float:
    return float((np.asarray(pred) == np.asarray(y)).mean())
