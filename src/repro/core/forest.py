"""Random-forest evaluation (Sharp's extension, paper §1) + top-k routing.

Sharp (2008) evaluates forests by concatenating tree encodings into one node
array and iterating over trees; we keep per-tree encodings stacked into a
(T, N_pad) batch and ``vmap`` the paper's evaluators over the tree axis — the
stacked layout is the TPU-native equivalent of texture concatenation.

Forests serve two roles here:
  1. classic majority-vote classification (the paper's lineage), and
  2. **top-k expert routing**: a forest of k trees where tree ``j`` emits the
     j-th expert choice for each token (used by the tree-routed MoE layer).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import EncodedTree, Node, breadth_first_encode, pad_tree, tree_depth
from repro.core.eval_speculative import eval_speculative


class EncodedForest:
    """T trees padded to a common node count and stacked."""

    def __init__(self, trees: Sequence[EncodedTree]):
        if not trees:
            raise ValueError("empty forest")
        n_pad = max(t.n_nodes for t in trees)
        padded = [pad_tree(t, n_pad) for t in trees]
        self.n_trees = len(trees)
        self.n_nodes = n_pad
        self.max_depth = max(tree_depth(t) for t in trees)
        self.attr_idx = np.stack([p.attr_idx for p in padded])  # (T, N)
        self.threshold = np.stack([p.threshold for p in padded])
        self.child = np.stack([p.child for p in padded])
        self.class_val = np.stack([p.class_val for p in padded])

    @classmethod
    def from_nodes(cls, roots: Sequence[Node]) -> "EncodedForest":
        return cls([breadth_first_encode(r) for r in roots])

    def tree(self, i: int) -> EncodedTree:
        """Recover tree ``i`` as a standalone (padded) encoding."""
        return EncodedTree(
            self.attr_idx[i], self.threshold[i], self.child[i], self.class_val[i]
        )


def eval_forest(
    forest: EncodedForest,
    records,
    *,
    jumps_per_round: int = 2,
    use_onehot_matmul: bool = True,
) -> jax.Array:
    """Per-tree class assignments, shape (T, M), via the speculative evaluator."""
    rec = jnp.asarray(records, jnp.float32)

    def one_tree(a, t, c, k):
        return eval_speculative(
            rec,
            a,
            t,
            c,
            k,
            max_depth=forest.max_depth,
            jumps_per_round=jumps_per_round,
            use_onehot_matmul=use_onehot_matmul,
        )

    return jax.vmap(one_tree)(
        jnp.asarray(forest.attr_idx),
        jnp.asarray(forest.threshold),
        jnp.asarray(forest.child),
        jnp.asarray(forest.class_val),
    )


def eval_forest_tuned(
    forest: "EncodedForest | Sequence[EncodedTree]",
    records,
    *,
    cache=None,
    autotune: bool = False,
    engines: tuple[str, ...] | None = None,
    families: tuple[str, ...] | None = None,
    layouts: tuple[str, ...] | None = None,
) -> jax.Array:
    """Per-tree class assignments, shape (T, M), via forest-level dispatch.

    The whole call resolves through :class:`repro.tune.ForestTunedEvaluator`
    as one unit: the (T, M, N_max, A, depth-profile) bucket picks between
    per-tree variant vectors (trees of different geometry may legitimately
    use different kernels), the shared-variant vmap path, and the fused
    stacked Pallas kernel that evaluates the forest in one launch.  With
    ``autotune=True`` the first sight of a bucket measures all three
    families and persists the winner.  Every family is exact, so the choice
    never changes results — bit-identical to evaluating each tree with
    ``eval_serial``.  ``layouts=("f32", "quant")`` opts the compact
    quantized node tables into the competition (still exact — dispatch only
    builds universal-mode quantizations).
    """
    from repro.tune import ForestTunedEvaluator

    return ForestTunedEvaluator(
        forest, cache=cache, autotune=autotune, engines=engines,
        families=families, layouts=layouts,
    )(jnp.asarray(records, jnp.float32))


def eval_forest_sharded(
    forest: "EncodedForest | Sequence[EncodedTree]",
    records,
    *,
    mesh=None,
    plan=None,
    decomposition: str | None = None,
    cache=None,
    autotune: bool = False,
    engines: tuple[str, ...] | None = None,
) -> jax.Array:
    """Per-tree class assignments, shape (T, M), across the device mesh.

    The :mod:`repro.dist` planner picks a record-/tree-/hybrid-sharded
    decomposition (or honours an explicit ``plan``/``mesh``/
    ``decomposition``), the executor lowers it with ``shard_map``, and each
    shard's kernel is still selected through ``repro.tune``.  Exact: results
    bit-match :func:`eval_forest_tuned` for every plan; on a single device
    this *is* the plain tuned path (no ``shard_map`` overhead).
    """
    from repro.dist import ShardedForestEvaluator

    return ShardedForestEvaluator(
        forest,
        mesh=mesh,
        plan=plan,
        decomposition=decomposition,
        cache=cache,
        autotune=autotune,
        engines=engines,
    )(records)


def eval_forest_cascade(
    forest: EncodedForest,
    records,
    *,
    n_classes: int,
    stages: int = 2,
    bound: float | None = 1.0,
    plan=None,
    calibration=None,
    engine: str | None = None,
    deadline_ms: float | None = None,
    registry=None,
    tracer=None,
):
    """Staged early-exit majority vote — the forest-scale dual of speculation.

    Trees are evaluated in stages (most discriminative first); records whose
    vote margin already exceeds ``bound`` times the remaining tree count exit
    early, and the survivors are compacted into dense tiles between stages.
    With ``bound=None`` every tree runs and the classes are bit-identical to
    ``majority_vote(eval_forest_tuned(forest, records), n_classes)``; with
    ``bound=1.0`` the exits are provably unable to change the answer, so the
    classes still match exactly while easy records skip most of the forest.

    Returns a :class:`repro.kernels.tree_eval.CascadeResult` — classes plus
    per-record margin, trees evaluated, exit stage and confidence.

    ``registry=`` / ``tracer=`` thread through to the evaluator so the
    host-side compaction between stages (``cascade.compact_ms`` /
    ``cascade.compact`` spans) lands in the caller's trace.
    """
    from repro.kernels.tree_eval import eval_cascade

    return eval_cascade(
        forest,
        records,
        n_classes=n_classes,
        stages=stages,
        bound=bound,
        plan=plan,
        calibration=calibration,
        engine=engine,
        deadline_ms=deadline_ms,
        registry=registry,
        tracer=tracer,
    )


def majority_vote(per_tree: jax.Array, n_classes: int) -> jax.Array:
    """(T, M) per-tree classes → (M,) majority class."""
    onehot = jax.nn.one_hot(per_tree, n_classes, dtype=jnp.int32)  # (T, M, C)
    return jnp.argmax(onehot.sum(axis=0), axis=-1).astype(jnp.int32)


def route_topk(per_tree: jax.Array) -> jax.Array:
    """(k, M) per-tree expert picks → (M, k) routing table (may repeat)."""
    return per_tree.T
