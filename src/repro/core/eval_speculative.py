"""Procedures 4 & 5: speculative parallel tree evaluation in JAX.

The paper's core contribution.  For each record, *every node of the tree* is
evaluated speculatively in one branch-free vector step, producing a successor
array ``path`` (leaves self-loop).  The root's eventual successor — the
record's terminal leaf — is then found by **pointer jumping**
(``path[i] = path[path[i]]``), needing only ``Θ(log₂ d)`` rounds instead of a
``d``-step descent.

Mapping to TPU (vs. the paper's CUDA record groups):
  * record group of p = N CUDA threads  →  one row of a (records × nodes) tile;
    nodes live on the 128-lane axis, records on the sublane axis.
  * shared-memory ``path`` + barrier()   →  a (M, N) register/VMEM array; tile
    lanes are lock-step so the warp-synchronous barrier elision in the paper's
    EvalTreeByNode is implicit and free.
  * node-eval attribute gather           →  either a vectorized gather
    (``records[:, attr_idx]``) or a one-hot MXU matmul (see kernels/tree_eval).
  * multi-jump per loop (Procedure 5 line 20) → ``jumps_per_round``.

Procedure-5 improvements implemented here:
  * leaves are pre-initialised from the static ``leafPaths`` table and only
    internal nodes are (re)computed — ``internal_only=True``;
  * several pointer jumps per synchronisation round (``jumps_per_round``);
  * the processor→node map exists implicitly: we compute internal-node
    successors with a mask rather than per-lane index tables, which is the
    natural SIMD-register formulation.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.tree import BOTTOM, EncodedTree


def _tree_arrays(enc: EncodedTree):
    return (
        jnp.asarray(enc.attr_idx, jnp.int32),
        jnp.asarray(enc.threshold, jnp.float32),
        jnp.asarray(enc.child, jnp.int32),
        jnp.asarray(enc.class_val, jnp.int32),
    )


_F32_MAX = float(jnp.finfo(jnp.float32).max)


def sanitize_records(records: jax.Array) -> jax.Array:
    """Make a record batch safe for one-hot-matmul node evaluation.

    The MXU formulation ``vals = records @ S`` multiplies every attribute by
    0 or 1 and sums, so a single non-finite attribute poisons the whole row
    (IEEE ``inf * 0 = NaN``).  Clamping preserves routing against every
    finite threshold: NaN and -FLT_MAX both fail ``v > t`` for all reachable
    thresholds, ±inf route exactly like ±FLT_MAX, and the leaf self-loop's
    +inf threshold still rejects everything.  Gather-based evaluators don't
    need this — they read only the addressed attribute.
    """
    records = jnp.asarray(records, jnp.float32)
    return jnp.where(
        jnp.isnan(records), -_F32_MAX, jnp.clip(records, -_F32_MAX, _F32_MAX)
    )


def speculative_node_eval(
    records: jax.Array,
    attr_idx: jax.Array,
    threshold: jax.Array,
    child: jax.Array,
    *,
    use_onehot_matmul: bool = False,
    attr_select: jax.Array | None = None,
) -> jax.Array:
    """Evaluate every node against every record (the speculative step).

    Returns ``path`` (M, N) int32: the successor of node ``n`` for record
    ``m`` — ``child[n] + (r[attr[n]] > threshold[n])``.  Leaves self-loop by
    construction of the encoding (+inf thresholds).

    ``use_onehot_matmul`` selects the MXU formulation
    ``vals = records @ S`` with ``S[a, n] = 1⇔attr[n]==a`` — on TPU this
    replaces a cross-lane gather with a systolic matmul; on CPU it is the
    same arithmetic.
    """
    if use_onehot_matmul:
        records = sanitize_records(records)
        if attr_select is None:
            n_attrs = records.shape[-1]
            attr_select = jax.nn.one_hot(attr_idx, n_attrs, dtype=records.dtype).T
        vals = records @ attr_select  # (M, N)
    else:
        vals = records[:, attr_idx]  # (M, N) gather
    return child[None, :] + (vals > threshold[None, :]).astype(jnp.int32)


def pointer_jump(path: jax.Array, rounds: int, jumps_per_round: int = 1) -> jax.Array:
    """Parallel path reduction: ``path[i] ← path[path[i]]`` (Procedure 4 l.15).

    ``jumps_per_round`` > 1 is Procedure 5's multi-reduction optimisation
    (line 20, ``path[path[path[i]]]``): fewer synchronisation rounds when the
    average traversal depth d_µ exceeds the per-round doubling.
    """
    def one_round(p):
        for _ in range(jumps_per_round):
            p = jnp.take_along_axis(p, p, axis=1)
        return p

    return jax.lax.fori_loop(0, rounds, lambda _, p: one_round(p), path)


def rounds_for_depth(max_depth: int, jumps_per_round: int = 1) -> int:
    """Pointer-jump rounds guaranteeing root→leaf convergence.

    After ``j`` total jumps every pointer skips ``2^j`` original steps, so we
    need ``2^(rounds·k) ≥ max_depth`` where each round applies ``k`` jumps...
    careful: ``k`` jumps inside one round compose as ``2^k`` doubling only in
    terms of *jump applications*; total applications = rounds·k and coverage
    is ``2^(rounds·k)``.  We need ``2^(rounds·k) ≥ max_depth``.
    """
    if max_depth <= 1:
        return 1
    total_jumps = max(1, math.ceil(math.log2(max_depth)))
    return math.ceil(total_jumps / jumps_per_round)


@partial(jax.jit, static_argnames=("max_depth", "jumps_per_round", "use_onehot_matmul", "early_exit"))
def eval_speculative(
    records: jax.Array,
    attr_idx: jax.Array,
    threshold: jax.Array,
    child: jax.Array,
    class_val: jax.Array,
    *,
    max_depth: int,
    jumps_per_round: int = 2,
    use_onehot_matmul: bool = False,
    early_exit: bool = False,
) -> jax.Array:
    """Procedure 4/5: speculative node evaluation + pointer-jump reduction.

    Args:
      records: (M, A) float array.
      max_depth: static tree-depth bound.
      jumps_per_round: Procedure-5 multi-jump factor (paper found 2 optimal).
      use_onehot_matmul: MXU-friendly node evaluation.
      early_exit: use a while-loop testing ``class[path[:,0]] ≠ ⊥`` for every
        record (Procedure 4 line 14) instead of the static round bound.

    Returns:
      (M,) int32 class assignments.
    """
    path = speculative_node_eval(
        records, attr_idx, threshold, child, use_onehot_matmul=use_onehot_matmul
    )

    if early_exit:

        def cond(p):
            return jnp.any(class_val[p[:, 0]] == BOTTOM)

        def body(p):
            for _ in range(jumps_per_round):
                p = jnp.take_along_axis(p, p, axis=1)
            return p

        path = jax.lax.while_loop(cond, body, path)
    else:
        path = pointer_jump(path, rounds_for_depth(max_depth, jumps_per_round), jumps_per_round)
    return class_val[path[:, 0]]


def eval_speculative_tree(
    enc: EncodedTree,
    records,
    *,
    max_depth: int,
    jumps_per_round: int = 2,
    use_onehot_matmul: bool = False,
    early_exit: bool = False,
):
    """Convenience wrapper taking an :class:`EncodedTree`."""
    a, t, c, k = _tree_arrays(enc)
    return eval_speculative(
        jnp.asarray(records, jnp.float32),
        a,
        t,
        c,
        k,
        max_depth=max_depth,
        jumps_per_round=jumps_per_round,
        use_onehot_matmul=use_onehot_matmul,
        early_exit=early_exit,
    )


def shard_eval_speculative(
    enc: EncodedTree,
    records,
    *,
    max_depth: int,
    mesh,
    axis: str = "data",
    jumps_per_round: int = 2,
):
    """Record groups sharded over the mesh ``axis``; tree replicated.

    Each device holds G/|axis| record groups — the paper's grid of record
    groups mapped onto the device mesh; ``path`` never leaves a device
    (it is the shared-memory analogue), so the only collective traffic is
    the record scatter / class gather, i.e. the paper's t_s(M) term.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    a, t, c, k = _tree_arrays(enc)
    rec = jnp.asarray(records, jnp.float32)
    fn = jax.jit(
        partial(
            eval_speculative,
            max_depth=max_depth,
            jumps_per_round=jumps_per_round,
            use_onehot_matmul=True,
        ),
        in_shardings=(
            NamedSharding(mesh, P(axis, None)),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
        ),
        out_shardings=NamedSharding(mesh, P(axis)),
    )
    return fn(rec, a, t, c, k)
