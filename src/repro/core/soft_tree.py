"""Soft (differentiable) decision trees that harden to the paper's encoding.

The paper evaluates *fixed* trees trained offline.  To make trees a
first-class LM-framework feature (tree-routed MoE, tree token heads) we need
to *learn* them inside a JAX training loop, then serve them with the paper's
branchless speculative evaluator.  The standard trick (soft decision trees,
à la Jordan & Jacobs '94 / Frosst & Hinton '17) is used, restricted to the
paper's tree class:

  * a **perfect binary tree** of depth ``d`` with ``2^d - 1`` internal nodes;
  * internal node ``n`` tests *one scalar feature* ``z_n`` against threshold
    ``t_n`` — axis-aligned, exactly the paper's §2.1 tree definition.  For
    router use, ``z = x @ W`` first projects the hidden state to one feature
    per internal node, so node ``n`` tests feature ``n`` (attr_idx = node id);
  * TRAIN: gate ``g_n = σ((z_n - t_n)/τ)``, leaf probability = product of
    gate terms along the root→leaf path (computed in closed form below);
  * SERVE: harden — take the sign of ``z_n - t_n`` — and emit an
    :class:`EncodedTree` evaluated by Procedure 4/5 kernels.

Shapes: depth d, I = 2^d - 1 internal nodes, L = 2^d leaves.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import BOTTOM, EncodedTree


@dataclasses.dataclass(frozen=True)
class SoftTreeConfig:
    depth: int
    in_features: int          # feature dim of the projection input
    n_outputs: int            # leaves map onto this many classes/experts
    temperature: float = 1.0
    dtype: object = jnp.float32

    @property
    def n_internal(self) -> int:
        return 2**self.depth - 1

    @property
    def n_leaves(self) -> int:
        return 2**self.depth


class SoftTreeParams(NamedTuple):
    proj: jax.Array       # (in_features, I) — one learned feature per node
    threshold: jax.Array  # (I,)
    leaf_map: jax.Array   # (L,) int32 — leaf → output id (static, non-learned)


def init_soft_tree(cfg: SoftTreeConfig, key: jax.Array) -> SoftTreeParams:
    kp, _ = jax.random.split(key)
    scale = 1.0 / np.sqrt(cfg.in_features)
    proj = jax.random.normal(kp, (cfg.in_features, cfg.n_internal), cfg.dtype) * scale
    threshold = jnp.zeros((cfg.n_internal,), cfg.dtype)
    # leaves cycle over outputs; for n_leaves == n_outputs this is identity.
    leaf_map = jnp.arange(cfg.n_leaves, dtype=jnp.int32) % cfg.n_outputs
    return SoftTreeParams(proj, threshold, leaf_map)


def _paths(depth: int) -> tuple[np.ndarray, np.ndarray]:
    """Static (L, d) tables: internal-node index and branch bit along each
    root→leaf path of a perfect tree in breadth-first numbering.

    BFS numbering of a perfect tree: internal node n has children 2n+1, 2n+2;
    leaves occupy [I, I+L).  Leaf ℓ's path is read from the bits of ℓ.
    """
    n_leaves = 2**depth
    node_idx = np.zeros((n_leaves, depth), np.int32)
    branch = np.zeros((n_leaves, depth), np.int32)
    for leaf in range(n_leaves):
        n = 0
        for lvl in range(depth):
            bit = (leaf >> (depth - 1 - lvl)) & 1
            node_idx[leaf, lvl] = n
            branch[leaf, lvl] = bit
            n = 2 * n + 1 + bit
    return node_idx, branch


def leaf_probs(cfg: SoftTreeConfig, params: SoftTreeParams, x: jax.Array) -> jax.Array:
    """Soft leaf distribution, shape (..., L).

    ``g_n = σ((z_n - t_n)/τ)`` is the probability of branching *right*
    (matching the paper's ``r_a > t`` → right predicate); leaf probability is
    the product over its path — computed as a sum of log-gates for stability.
    """
    z = x @ params.proj  # (..., I)
    logits = (z - params.threshold) / cfg.temperature
    log_right = jax.nn.log_sigmoid(logits)    # log σ(u)
    log_left = jax.nn.log_sigmoid(-logits)    # log σ(-u) = log(1-σ(u))
    node_idx, branch = _paths(cfg.depth)
    node_idx = jnp.asarray(node_idx)
    branch = jnp.asarray(branch)
    lr = log_right[..., node_idx]  # (..., L, d)
    ll = log_left[..., node_idx]
    log_p = jnp.where(branch.astype(bool), lr, ll).sum(axis=-1)  # (..., L)
    return jnp.exp(log_p)


def output_probs(cfg: SoftTreeConfig, params: SoftTreeParams, x: jax.Array) -> jax.Array:
    """Soft output distribution over ``n_outputs`` (sums leaf probs per output)."""
    lp = leaf_probs(cfg, params, x)  # (..., L)
    onehot = jax.nn.one_hot(params.leaf_map, cfg.n_outputs, dtype=lp.dtype)  # (L, O)
    return lp @ onehot


def harden(cfg: SoftTreeConfig, params: SoftTreeParams) -> EncodedTree:
    """Freeze a trained soft tree into the paper's branchless encoding.

    The emitted tree's "records" are the projected features ``z = x @ proj``
    (A = I attributes, attr_idx[n] = n for internal nodes): apply
    ``eval_speculative(z, ...)`` or the Pallas kernel to serve it.
    """
    depth = cfg.depth
    n_int, n_leaf = cfg.n_internal, cfg.n_leaves
    n = n_int + n_leaf
    attr_idx = np.zeros((n,), np.int32)
    threshold = np.full((n,), np.inf, np.float32)
    child = np.arange(n, dtype=np.int32)  # leaves default to self-loop
    class_val = np.full((n,), BOTTOM, np.int32)
    thr = np.asarray(jax.device_get(params.threshold), np.float32)
    lmap = np.asarray(jax.device_get(params.leaf_map), np.int32)
    for i in range(n_int):
        attr_idx[i] = i          # node i tests projected feature i
        threshold[i] = thr[i]
        child[i] = 2 * i + 1     # perfect-tree BFS: right = left + 1 holds
    for leaf in range(n_leaf):
        class_val[n_int + leaf] = lmap[leaf]
    # BFS numbering of a perfect tree puts all internal nodes before leaves
    # only level-by-level; with children 2i+1/2i+2 the layout is exactly
    # breadth-first and leaves occupy [I, I+L): the encoding is valid as-is.
    return EncodedTree(attr_idx, threshold, child, class_val)


def load_balance_loss(leaf_p: jax.Array) -> jax.Array:
    """Encourage uniform leaf usage (Switch-style aux loss over the batch)."""
    mean_p = leaf_p.reshape(-1, leaf_p.shape[-1]).mean(axis=0)
    l = mean_p.shape[-1]
    return l * jnp.sum(mean_p * mean_p)
