"""Procedure 3: data-parallel tree evaluation in JAX.

One *lane* per record; every lane iterates the branchless descent
``i = child[i] + (r_a > t)``.  On SIMD hardware the while-loop trip count is
the *maximum* depth over the vector (lanes that reach a leaf early self-loop
harmlessly) — exactly the divergence cost the paper attributes to data
decomposition on CUDA warps.  Two loop flavours are provided:

* ``fixed`` — ``lax.fori_loop`` for ``max_depth`` rounds (static trip count;
  what a warp effectively pays when any lane walks the deepest path).
* ``early_exit`` — ``lax.while_loop`` that stops when every record has
  reached a leaf (models independent processors, paper §3.6's T₃ analysis).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.tree import BOTTOM, EncodedTree


def _tree_arrays(enc: EncodedTree):
    return (
        jnp.asarray(enc.attr_idx, jnp.int32),
        jnp.asarray(enc.threshold, jnp.float32),
        jnp.asarray(enc.child, jnp.int32),
        jnp.asarray(enc.class_val, jnp.int32),
    )


@partial(jax.jit, static_argnames=("max_depth", "loop"))
def eval_data_parallel(
    records: jax.Array,
    attr_idx: jax.Array,
    threshold: jax.Array,
    child: jax.Array,
    class_val: jax.Array,
    *,
    max_depth: int,
    loop: str = "fixed",
) -> jax.Array:
    """Procedure 3: one record per lane, branchless descent.

    Args:
      records: (M, A) float array.
      attr_idx/threshold/child/class_val: encoded tree fields.
      max_depth: static bound on tree depth (loop trip count).
      loop: "fixed" | "early_exit".

    Returns:
      (M,) int32 class assignments.
    """
    m = records.shape[0]
    idx0 = jnp.zeros((m,), jnp.int32)

    def step(idx):
        a = attr_idx[idx]  # (M,) gather over nodes
        t = threshold[idx]
        v = jnp.take_along_axis(records, a[:, None].astype(jnp.int32), axis=1)[:, 0]
        return child[idx] + (v > t).astype(jnp.int32)

    if loop == "fixed":
        idx = jax.lax.fori_loop(0, max_depth, lambda _, i: step(i), idx0)
    elif loop == "early_exit":

        def cond(idx):
            return jnp.any(class_val[idx] == BOTTOM)

        idx = jax.lax.while_loop(cond, step, idx0)
    else:
        raise ValueError(f"unknown loop mode {loop!r}")
    return class_val[idx]


def eval_data_parallel_tree(enc: EncodedTree, records, *, max_depth: int, loop: str = "fixed"):
    """Convenience wrapper taking an :class:`EncodedTree`."""
    a, t, c, k = _tree_arrays(enc)
    return eval_data_parallel(
        jnp.asarray(records, jnp.float32), a, t, c, k, max_depth=max_depth, loop=loop
    )


def shard_eval_data_parallel(enc: EncodedTree, records, *, max_depth: int, mesh, axis: str = "data"):
    """Multi-device data decomposition: records sharded over ``axis``.

    The direct analogue of Procedure 3's ``D[m·p .. m(p+1))`` slicing — pjit
    moves each shard to its processor; the tree (small) is replicated, exactly
    like the paper's constant-memory broadcast.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    a, t, c, k = _tree_arrays(enc)
    rec = jnp.asarray(records, jnp.float32)
    fn = jax.jit(
        partial(eval_data_parallel, max_depth=max_depth, loop="fixed"),
        in_shardings=(
            NamedSharding(mesh, P(axis, None)),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
        ),
        out_shardings=NamedSharding(mesh, P(axis)),
    )
    return fn(rec, a, t, c, k)
