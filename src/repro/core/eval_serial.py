"""Procedure 2: serial branchless tree evaluation (the speedup reference).

The paper establishes Sharp's branchless traversal as the *best known serial
algorithm* and measures all parallel speedups against it.  This module is the
host (numpy) implementation — deliberately simple, loop-based, and branch-free
at each decision node: ``i = child[i] + (r_a > t)``.
"""

from __future__ import annotations

import numpy as np

from repro.core.tree import BOTTOM, EncodedTree


def eval_serial(enc: EncodedTree, records: np.ndarray) -> np.ndarray:
    """Procedure 2 over a dataset.

    Args:
      enc: branchless breadth-first encoded tree.
      records: float array (M, A).

    Returns:
      int32 array (M,) of assigned classes.
    """
    records = np.asarray(records)
    m = records.shape[0]
    out = np.empty((m,), np.int32)
    attr, thr, child, cls = enc.attr_idx, enc.threshold, enc.child, enc.class_val
    for r in range(m):
        rec = records[r]
        i = 0
        while cls[i] == BOTTOM:
            # branchless next-node computation (the predicate result is the
            # 0/1 child offset; no explicit if/else on the path taken)
            i = child[i] + int(rec[attr[i]] > thr[i])
        out[r] = cls[i]
    return out


def eval_serial_vectorized_host(enc: EncodedTree, records: np.ndarray, max_depth: int) -> np.ndarray:
    """Host-side vectorized descent (used as a fast oracle for big datasets).

    Semantically identical to :func:`eval_serial`; runs the branchless update
    for ``max_depth`` rounds over all records at once (leaves self-loop so
    overshooting is a no-op).
    """
    records = np.asarray(records)
    m = records.shape[0]
    idx = np.zeros((m,), np.int64)
    rows = np.arange(m)
    for _ in range(max_depth):
        a = enc.attr_idx[idx]
        t = enc.threshold[idx]
        idx = enc.child[idx] + (records[rows, a] > t)
    return enc.class_val[idx].astype(np.int32)
