"""Windowed speculative evaluation — the paper's §6 future-work idea, built.

For very large trees the speculative decomposition's p = N processors exceed
SIMD concurrency (or VMEM).  The paper proposes evaluating a *window* of
levels at a time: speculate within the window, reduce, adopt the resulting
node as the new root, repeat.

TPU-native formulation: breadth-first numbering stores each level
contiguously, so a window of ``w`` consecutive levels is a contiguous index
range ``[lo, hi)`` shared by every record — no per-record node sets.  Each
round: (1) speculatively evaluate all nodes in the window (one one-hot
matmul over ``hi - lo`` lanes), (2) pointer-jump ``⌈log₂ w⌉`` times *within
the window* (successors beyond ``hi`` park unchanged and are picked up by
the next window), (3) advance.  The working set is bounded by the widest
``w``-level band instead of N — the paper's "overcoming SIMD concurrency
limits or the exponential growth of memory demand".

Exactness: leaves self-loop, and any pointer that exits the window is
resolved in a later round, so the result equals the unwindowed evaluator
(property-tested).
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.tree import EncodedTree, node_depths


def level_offsets(enc: EncodedTree) -> np.ndarray:
    """BFS start index of every level (levels are contiguous in BFS order)."""
    depths = node_depths(enc)
    max_d = int(depths.max())
    starts = np.zeros((max_d + 2,), np.int64)
    for lvl in range(max_d + 1):
        idx = np.nonzero(depths == lvl)[0]
        starts[lvl] = idx.min() if idx.size else starts[lvl - 1]
    starts[max_d + 1] = enc.n_nodes
    # verify contiguity (true for BFS encodings of full trees)
    for lvl in range(max_d + 1):
        idx = np.nonzero(depths == lvl)[0]
        if idx.size:
            assert idx.max() - idx.min() + 1 == idx.size, "BFS levels not contiguous"
    return starts


def eval_windowed(
    enc: EncodedTree,
    records,
    *,
    window_levels: int = 4,
) -> jax.Array:
    """Windowed speculative evaluation; exact-equal to the full evaluator.

    Per window round the node axis is only ``max_band = max nodes in any
    ``window_levels`` consecutive levels`` wide — the SIMD-concurrency bound
    the paper's §6 asks for.
    """
    rec = jnp.asarray(records, jnp.float32)
    m = rec.shape[0]
    starts = level_offsets(enc)
    max_d = len(starts) - 2
    attr = jnp.asarray(enc.attr_idx, jnp.int32)
    thr = jnp.asarray(enc.threshold, jnp.float32)
    child = jnp.asarray(enc.child, jnp.int32)
    cls = jnp.asarray(enc.class_val, jnp.int32)

    cur = jnp.zeros((m,), jnp.int32)          # each record's current node
    w = max(window_levels, 1)
    # 2^jumps >= w guarantees a band-top pointer traverses the whole window
    jumps = max(1, math.ceil(math.log2(w + 1)))

    for lo_lvl in range(0, max_d + 1, w):
        hi_lvl = min(lo_lvl + w, max_d + 1)
        lo, hi = int(starts[lo_lvl]), int(starts[hi_lvl])
        if hi <= lo:
            continue
        band_attr = attr[lo:hi]
        band_thr = thr[lo:hi]
        band_child = child[lo:hi]
        # (1) speculative node evaluation over the band (every record × node)
        vals = rec[:, band_attr]                                  # (M, band)
        succ = band_child[None, :] + (vals > band_thr[None, :]).astype(jnp.int32)
        # (2) pointer DOUBLING within the band (Procedure 4's
        # path[i] <- path[path[i]], restricted to the window): after k rounds
        # every in-band pointer skips 2^k original steps; pointers that exit
        # the band park and are resolved by a later window.
        def double(p):
            inside = (p >= lo) & (p < hi)
            p_in = jnp.clip(p - lo, 0, hi - lo - 1)
            nxt = jnp.take_along_axis(p, p_in, axis=1)
            return jnp.where(inside, nxt, p)

        ptr = succ
        for _ in range(jumps):
            ptr = double(ptr)
        # (3) advance each record's node through the band
        in_band = (cur >= lo) & (cur < hi)
        take = jnp.take_along_axis(
            ptr, jnp.clip(cur - lo, 0, hi - lo - 1)[:, None], axis=1
        )[:, 0]
        cur = jnp.where(in_band, take, cur)
    return cls[cur]
