"""Core paper contribution: branchless + speculative classification-tree evaluation.

Spencer (2011): Procedures 1–5 and the §3.6 analysis, as composable JAX.
"""

from repro.core.tree import (
    BOTTOM,
    EncodedTree,
    Node,
    attr_select_matrix,
    breadth_first_encode,
    decode_to_linked,
    leaf_paths,
    node_depths,
    pad_tree,
    paper_tree,
    perfect_tree,
    processor_node_map,
    random_tree,
    tree_depth,
    validate_encoding,
)
from repro.core.eval_serial import eval_serial, eval_serial_vectorized_host
from repro.core.eval_dataparallel import eval_data_parallel, eval_data_parallel_tree
from repro.core.eval_speculative import (
    eval_speculative,
    eval_speculative_tree,
    pointer_jump,
    rounds_for_depth,
    speculative_node_eval,
)
from repro.core.cart import CartConfig, accuracy, train_cart
from repro.core.forest import (
    EncodedForest,
    eval_forest,
    eval_forest_cascade,
    eval_forest_sharded,
    eval_forest_tuned,
    majority_vote,
    route_topk,
)
from repro.core.soft_tree import (
    SoftTreeConfig,
    SoftTreeParams,
    harden,
    init_soft_tree,
    leaf_probs,
    load_balance_loss,
    output_probs,
)
from repro.core.windowed import eval_windowed, level_offsets
from repro.core import analysis

__all__ = [
    "BOTTOM",
    "EncodedTree",
    "Node",
    "attr_select_matrix",
    "breadth_first_encode",
    "decode_to_linked",
    "leaf_paths",
    "node_depths",
    "pad_tree",
    "paper_tree",
    "perfect_tree",
    "processor_node_map",
    "random_tree",
    "tree_depth",
    "validate_encoding",
    "eval_serial",
    "eval_serial_vectorized_host",
    "eval_data_parallel",
    "eval_data_parallel_tree",
    "eval_speculative",
    "eval_speculative_tree",
    "pointer_jump",
    "rounds_for_depth",
    "speculative_node_eval",
    "CartConfig",
    "accuracy",
    "train_cart",
    "EncodedForest",
    "eval_forest",
    "eval_forest_cascade",
    "eval_forest_sharded",
    "eval_forest_tuned",
    "majority_vote",
    "route_topk",
    "SoftTreeConfig",
    "SoftTreeParams",
    "harden",
    "init_soft_tree",
    "leaf_probs",
    "load_balance_loss",
    "output_probs",
    "analysis",
    "eval_windowed",
    "level_offsets",
]
