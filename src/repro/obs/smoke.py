"""Observability smoke: a traced serve pass, exported and sanity-checked.

The CI ``obs`` job runs this module end-to-end: build a small bagged
forest, push a few record waves through a :class:`repro.serve.ForestServeEngine`
with metrics + tracing enabled, export a Chrome/Perfetto trace and a
Prometheus text snapshot, then assert that

* the Chrome trace parses as JSON and contains nested ``serve.wave`` →
  ``stream.eval`` → ``kernel.dispatch`` spans;
* the Prometheus text parses line-by-line and names the core series
  (wave latency, chunker throughput/overlap, tuner resolutions);
* registering a conflicting duplicate metric raises
  :class:`repro.obs.DuplicateMetricError`;
* a serve pass under an unmeetable SLO trips the flight recorder — breach
  counters land in the registry and the dumped bundle (``flight.json`` +
  Perfetto ``trace.json``) parses;
* a sampled shadow-profile pass publishes per-bucket d_µ / waste-ratio
  gauges and Perfetto **counter tracks** (``"ph": "C"`` events) that parse.

Artifacts land in ``--out`` (default ``/tmp/repro_obs_smoke``) so the CI
job can upload them.  Exit code 0 means every assertion passed.

    PYTHONPATH=src python -m repro.obs.smoke [--out DIR]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

N_TREES = 6
N_CLASSES = 7
WAVE_RECORDS = 512
REQUESTS = 4

# Series the serve path must populate for the snapshot to count as healthy.
CORE_METRICS = (
    "serve.waves",
    "serve.records",
    "serve.wave_ms",
    "serve.queue_wait_ms",
    "serve.pad_fraction",
    "stream.chunks",
    "stream.chunk_ms",
    "stream.overlap_ratio",
    "tune.resolutions",
)

# A wave span must (transitively) contain these children on the stream path.
NESTED_SPANS = ("serve.wave", "stream.eval", "kernel.dispatch")


def _forest(seed: int = 0):
    import numpy as np

    from repro.core import CartConfig, EncodedForest, breadth_first_encode, train_cart
    from repro.data.segmentation import make_segmentation

    data = make_segmentation(seed)
    rng = np.random.default_rng(seed)
    trees = []
    for _ in range(N_TREES):
        idx = rng.integers(0, data.x_train.shape[0], data.x_train.shape[0])
        root = train_cart(
            data.x_train[idx], data.y_train[idx], N_CLASSES,
            CartConfig(max_depth=6, min_samples_split=16, min_gain=4e-3),
        )
        trees.append(breadth_first_encode(root))
    return EncodedForest(trees), data


def _serve_traced(registry, tracer, flight=None, profile=None):
    import numpy as np

    from repro.serve import ForestServeEngine, TreeRequest

    forest, data = _forest()
    rec = np.tile(data.x_test, (WAVE_RECORDS // data.x_test.shape[0] + 1, 1))
    rec = rec[:WAVE_RECORDS].astype(np.float32)
    # profile defaults to None (not the engine's default-on policy) so the
    # span-nesting and flight checks stay deterministic; check_profiler
    # passes an explicit synchronous policy
    eng = ForestServeEngine(
        forest, max_batch=WAVE_RECORDS, chunk_records=WAVE_RECORDS // 4,
        n_classes=N_CLASSES, retune=None, profile=profile,
        registry=registry, tracer=tracer, flight=flight,
    )
    reqs = [TreeRequest(uid=i, records=rec) for i in range(REQUESTS)]
    out = eng.run(reqs)
    assert len(out) == REQUESTS, f"served {len(out)}/{REQUESTS} requests"
    return eng


def check_flight_bundle(out_dir: Path) -> None:
    """A breach-forced serve pass must dump a loadable flight bundle."""
    from repro import obs

    registry, tracer = obs.Registry(), obs.Tracer()
    policy = obs.FlightPolicy(slo_ms=1e-6, out_dir=str(out_dir),
                              min_dump_interval_s=0.0)
    eng = _serve_traced(registry, tracer, flight=policy)
    snap = obs.snapshot(registry)
    breach_series = [k for k in snap["counters"] if k.startswith("flight.slo_breaches")]
    assert breach_series and all(snap["counters"][k] > 0 for k in breach_series), \
        f"no SLO breaches counted under a {policy.slo_ms} ms SLO"
    bundles = sorted(out_dir.glob("flight-forest-*"))
    assert bundles, "no flight bundle dumped on breach"
    bundle = bundles[-1]
    flight = json.loads((bundle / "flight.json").read_text())
    assert flight["reason"] == "slo_breach" and flight["waves"], \
        "flight.json missing reason/waves"
    trace = json.loads((bundle / "trace.json").read_text())
    assert trace.get("traceEvents"), "flight trace.json has no traceEvents"
    _ = eng.dump_flight("smoke")  # the explicit path must work too
    print(f"flight recorder ok: {len(bundles)} bundle(s), "
          f"{len(flight['waves'])} waves in ring, breaches counted")


def check_chrome_trace(path: Path) -> None:
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events, "empty traceEvents"
    by_name: dict[str, list[dict]] = {}
    for ev in events:
        by_name.setdefault(ev.get("name", ""), []).append(ev)
    for name in NESTED_SPANS:
        assert name in by_name, f"missing span {name!r} in trace"
    # nesting: some kernel.dispatch span must sit inside a stream.eval span
    # which sits inside a serve.wave span (same thread, time containment).
    def _contains(outer: dict, inner: dict) -> bool:
        return (outer["tid"] == inner["tid"]
                and outer["ts"] <= inner["ts"]
                and inner["ts"] + inner.get("dur", 0) <= outer["ts"] + outer.get("dur", 0))

    nested = any(
        _contains(w, e) and _contains(e, k)
        for w in by_name["serve.wave"]
        for e in by_name["stream.eval"]
        for k in by_name["kernel.dispatch"]
    )
    assert nested, "no serve.wave > stream.eval > kernel.dispatch nesting found"
    print(f"chrome trace ok: {len(events)} events, nesting verified")


def check_prometheus(path: Path) -> None:
    text = path.read_text()
    assert text.endswith("\n"), "prometheus text must end with a newline"
    seen = set()
    for line in text.splitlines():
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            seen.add(line.split()[2])
            continue
        assert line, "blank line in prometheus exposition"
        name = line.split("{", 1)[0].split(" ", 1)[0]
        sample = line.rsplit(" ", 1)
        assert len(sample) == 2, f"malformed sample line: {line!r}"
        float(sample[1])  # value must parse
        seen.add(name.removesuffix("_bucket").removesuffix("_count")
                 .removesuffix("_sum"))
    # exposition names are the dotted originals with dots sanitised away
    missing = [m for m in CORE_METRICS if m.replace(".", "_") not in seen]
    assert not missing, f"core metrics absent from snapshot: {missing}"
    print(f"prometheus text ok: {len(seen)} series, core metrics present")


def check_profiler(out_dir: Path) -> None:
    """A sampled shadow pass must publish gauges + parsable counter tracks."""
    from repro import obs

    registry, tracer = obs.Registry(), obs.Tracer()
    eng = _serve_traced(
        registry, tracer,
        profile=obs.ProfilePolicy(sample_every=1, synchronous=True),
    )
    assert eng.profiler is not None, "engine built without a profiler"
    eng.profiler.drain()
    snap = obs.snapshot(registry)
    sampled = [v for k, v in snap["counters"].items()
               if k.startswith("prof.sampled")]
    assert sampled and sum(sampled) > 0, "no profiled waves counted"
    d_mu = {k: v for k, v in snap["gauges"].items() if k.startswith("prof.d_mu")}
    assert d_mu and all(v >= 1.0 for v in d_mu.values()), \
        f"per-bucket d_mu gauges missing or degenerate: {d_mu}"
    waste = {k: v for k, v in snap["gauges"].items()
             if k.startswith("prof.waste_ratio")}
    assert waste and all(v >= 1.0 for v in waste.values()), \
        f"per-bucket waste-ratio gauges missing or degenerate: {waste}"

    path = out_dir / "profile_trace.json"
    tracer.write_chrome_trace(path)
    doc = json.loads(path.read_text())
    counters = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
    assert counters, "no counter-track events in profile trace"
    for ev in counters:
        # Perfetto counter tracks: no dur, numeric args only
        assert "dur" not in ev, f"counter event carries dur: {ev}"
        args = ev.get("args")
        assert args and all(isinstance(v, (int, float)) for v in args.values()), \
            f"counter event args not numeric: {ev}"
    tracks = {e["name"] for e in counters}
    for prefix in ("prof.d_mu/", "prof.waste/"):
        assert any(t.startswith(prefix) for t in tracks), \
            f"no {prefix}* counter track among {sorted(tracks)}"
    print(f"profiler ok: {len(d_mu)} bucket(s), {len(counters)} counter "
          f"events across {len(tracks)} tracks")


def check_duplicate_registration(registry) -> None:
    from repro.obs import DuplicateMetricError

    registry.counter("smoke.dup", "first registration")
    try:
        registry.gauge("smoke.dup", "conflicting kind")
    except DuplicateMetricError:
        print("duplicate registration raises: ok")
        return
    raise AssertionError("conflicting re-registration did not raise")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="/tmp/repro_obs_smoke")
    args = parser.parse_args(argv)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    from repro import obs

    registry, tracer = obs.Registry(), obs.Tracer()
    eng = _serve_traced(registry, tracer)
    print(f"served: {eng.stats.waves} waves, {eng.stats.records} records, "
          f"{eng.stats.chunks} chunks")

    trace_path = out / "trace.json"
    tracer.write_chrome_trace(trace_path)
    prom_path = out / "metrics.prom"
    prom_path.write_text(obs.prometheus_text(registry))
    snap_path = out / "snapshot.json"
    obs.write_json_snapshot(registry, snap_path)

    check_chrome_trace(trace_path)
    check_prometheus(prom_path)
    json.loads(snap_path.read_text())  # snapshot must round-trip
    check_duplicate_registration(registry)
    check_flight_bundle(out / "flight")
    check_profiler(out)
    print(f"artifacts in {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
