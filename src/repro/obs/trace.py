"""Span tracer: ring-buffered timed spans with a Chrome/Perfetto exporter.

``Tracer.span("serve.wave", cat="serve", bucket=key)`` is a context manager
that records one complete ("X") trace event — wall-clock start + duration,
thread id, free-form args.  Events land in a bounded ring buffer (a
``deque(maxlen=...)`` appended under a lock), so tracing from the request
thread and the :class:`~repro.serve.engine.BackgroundRetuner` worker at
once is safe and memory stays bounded no matter how long an engine serves.

Nesting is positional: spans opened inside other spans on the same thread
are contained in time, which is exactly how the Chrome trace-event format
(and Perfetto's UI) reconstructs the stack — the exporter does not need
explicit parent ids for the wave→chunk→kernel hierarchy to render nested.
Cross-thread work (background re-tune measurements) shows up on its own
track, named via thread-name metadata events.

Span naming convention (see docs/observability.md): dotted lowercase
``layer.operation[.phase]`` — e.g. ``serve.wave``, ``stream.chunk.submit``,
``kernel.dispatch``, ``cascade.stage``, ``tune.measure`` — with the layer
repeated in ``cat`` so Perfetto can filter by subsystem.

Kernel bridging: with ``jax_annotations=True`` every span also enters a
``jax.profiler.TraceAnnotation`` of the same name, so when the JAX/XLA
profiler is active (``jax.profiler.trace``) the host-side spans line up
with device timelines in the same TensorBoard/Perfetto view.  The bridge
is optional and import-guarded — absent profiler support degrades to plain
host spans.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import NamedTuple, Optional

__all__ = ["NULL_TRACER", "SpanEvent", "Tracer", "write_chrome_trace"]


class SpanEvent(NamedTuple):
    """One completed span (times in µs relative to the tracer's epoch)."""

    name: str
    cat: str
    ts_us: float
    dur_us: float
    thread: int          # thread ident (raw)
    thread_name: str
    args: dict
    ph: str = "X"        # trace phase: "X" complete span, "C" counter sample


class _Span:
    """Active span: context manager recording one SpanEvent on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0", "_jax_cm")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args
        self._t0 = 0.0
        self._jax_cm = None

    def set(self, **kw) -> None:
        """Attach args discovered mid-span (chunk counts, winners, ...)."""
        self._args.update(kw)

    def __enter__(self) -> "_Span":
        ann = self._tracer._annotation_cls
        if ann is not None:
            self._jax_cm = ann(self._name)
            self._jax_cm.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        if self._jax_cm is not None:
            self._jax_cm.__exit__(exc_type, exc, tb)
        if exc_type is not None:
            self._args.setdefault("error", exc_type.__name__)
        self._tracer._record(self._name, self._cat, self._t0, t1, self._args)


class _NullSpan:
    """Shared no-op span: the disabled path allocates nothing per call."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **kw) -> None:
        return None


_NULL_SPAN = _NullSpan()


class Tracer:
    """Bounded in-memory span recorder with Chrome trace-event export.

    Args:
      capacity: ring-buffer size in spans; the oldest spans fall off first
        (steady-state serving keeps the most recent window).
      enabled: a disabled tracer's :meth:`span` returns a shared no-op
        context manager — one branch, zero allocation.
      jax_annotations: additionally wrap every span in a
        ``jax.profiler.TraceAnnotation`` so device profiles correlate.
    """

    def __init__(self, *, capacity: int = 65536, enabled: bool = True,
                 jax_annotations: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._events: deque[SpanEvent] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._dropped = 0
        self._annotation_cls = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation

                self._annotation_cls = TraceAnnotation
            except Exception:      # profiler unavailable: plain host spans
                self._annotation_cls = None

    # -- recording ----------------------------------------------------------

    def span(self, name: str, *, cat: str = "repro", **args):
        """A context manager timing one span; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, *, cat: str = "repro", **args) -> None:
        """Record a zero-duration marker event (coalescing decisions, swaps)."""
        if not self.enabled:
            return
        t = time.perf_counter()
        self._record(name, cat, t, t, args)

    def counter(self, name: str, value: float, *, cat: str = "prof",
                series: str = "value") -> None:
        """Record one sample on a Perfetto counter track (``"C"`` phase).

        Successive samples with the same ``name`` render as a stepped
        timeline in Perfetto — e.g. per-bucket measured d_µ or waste ratio
        over the lifetime of a serving engine.  ``series`` names the counter
        track's value series (one arg key = one line on the track).
        """
        if not self.enabled:
            return
        t = time.perf_counter()
        self._record(name, cat, t, t, {series: float(value)}, ph="C")

    def _record(self, name: str, cat: str, t0: float, t1: float, args: dict,
                *, ph: str = "X") -> None:
        th = threading.current_thread()
        ev = SpanEvent(
            name=name,
            cat=cat,
            ts_us=(t0 - self._epoch) * 1e6,
            dur_us=(t1 - t0) * 1e6,
            thread=th.ident or 0,
            thread_name=th.name,
            args=args,
            ph=ph,
        )
        with self._lock:
            if len(self._events) == self.capacity:
                self._dropped += 1
            self._events.append(ev)

    # -- introspection / export ---------------------------------------------

    def events(self) -> list[SpanEvent]:
        """Snapshot of the ring buffer, oldest first."""
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        """Spans evicted by the ring bound since construction."""
        with self._lock:
            return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object (load in Perfetto / about:tracing).

        Complete ("X") events carry µs timestamps relative to the tracer
        epoch; counter ("C") samples from :meth:`counter` carry numeric args
        and no duration (Perfetto draws them as counter tracks); per-thread
        metadata ("M") events name the tracks.  Args are emitted as-is, so
        bucket keys, chunk sizes and winners are inspectable per-span in
        the UI.
        """
        pid = os.getpid()
        events = self.events()
        tids: dict[int, str] = {}
        out = []
        for e in events:
            tids.setdefault(e.thread, e.thread_name)
            ev = {
                "name": e.name,
                "cat": e.cat,
                "ph": e.ph,
                "ts": round(e.ts_us, 3),
                "pid": pid,
                "tid": e.thread,
                "args": {k: _jsonable(v) for k, v in e.args.items()},
            }
            if e.ph != "C":  # counter samples are point values, no duration
                ev["dur"] = round(e.dur_us, 3)
            out.append(ev)
        meta = [
            {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": name}}
            for tid, name in sorted(tids.items())
        ]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path) -> None:
        write_chrome_trace(self, path)


def _jsonable(v):
    """Span args must survive json.dump whatever the caller attached."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def write_chrome_trace(tracer: Tracer, path) -> None:
    """Serialise ``tracer``'s ring buffer as Chrome trace-event JSON."""
    with open(path, "w") as f:
        json.dump(tracer.chrome_trace(), f)


#: Shared disabled tracer: components default to this so tracing is strictly
#: opt-in and the untraced hot path costs one branch per span site.
NULL_TRACER = Tracer(capacity=1, enabled=False)
