"""repro.obs — unified metrics, span tracing and kernel-profiling bridge.

The telemetry substrate under the whole serving stack (serve engines, the
autotuner, the dist executor + streaming chunker, the early-exit cascade):

  metrics.py  thread-safe registry of counters / gauges / fixed-boundary
              histograms with p50/p95/p99 derivation; labelled series;
              near-zero-cost when disabled; duplicate-registration guard.
  trace.py    ring-buffered span tracer (context-manager API, safe from
              worker threads) with a Chrome/Perfetto trace-event exporter
              and optional ``jax.profiler.TraceAnnotation`` bridging so
              host spans line up with device profiles.
  export.py   JSON snapshot + Prometheus text exposition, stdlib-only.
  perf.py     bench trajectory store (``results/history/<bench>.jsonl``)
              and the noise-aware perf-regression detector behind the CI
              ``perf-gate`` job; stdlib-only.
  flight.py   SLO flight recorder for the serve engines — bounded ring of
              recent waves, breach counters, crash-dump bundles (metrics
              snapshot + Perfetto trace) on breach/exception/demand.
  prof.py     traversal profiler — sampled shadow passes over the live
              workload measuring §3.6's d_µ / speculation waste / lane
              occupancy / leaf-hit drift, feeding the tuner and cascade
              planner measured values instead of priors.
  smoke.py    the CI ``obs`` job: serve a workload with tracing on, export
              both formats, assert they parse and carry the core metrics.

Wiring model: every engine/evaluator owns a private :class:`Registry` by
default (so per-engine stats views stay exact and tests stay isolated) and
accepts ``registry=`` / ``tracer=`` to share one — `ForestServeEngine`
threads its registry and tracer through the chunker, the executor, the
tuned evaluators and the cascade, which is what makes one wave's
wave→chunk→kernel spans land in a single trace.  Cross-cutting counters
from functional APIs default to :func:`default_registry`.

See docs/observability.md for the metric catalog and span-naming
convention.
"""

from repro.obs.export import prometheus_text, snapshot, write_json_snapshot
from repro.obs.flight import FlightPolicy, FlightRecorder
from repro.obs.metrics import (
    DEFAULT_MS_BOUNDARIES,
    DEFAULT_RATIO_BOUNDARIES,
    Counter,
    DuplicateMetricError,
    Gauge,
    Histogram,
    Registry,
    default_registry,
    set_default_registry,
)
from repro.obs.perf import (
    Regression,
    append_history,
    detect_regressions,
    extract_series,
    load_history,
)
from repro.obs.prof import (
    BucketProfile,
    ProfilePolicy,
    TraversalProfiler,
    leaf_drift_distance,
    survival_from_classes,
)
from repro.obs.trace import NULL_TRACER, SpanEvent, Tracer, write_chrome_trace

__all__ = [
    "BucketProfile",
    "Counter",
    "DEFAULT_MS_BOUNDARIES",
    "DEFAULT_RATIO_BOUNDARIES",
    "DuplicateMetricError",
    "FlightPolicy",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "NULL_TRACER",
    "ProfilePolicy",
    "Registry",
    "Regression",
    "SpanEvent",
    "Tracer",
    "TraversalProfiler",
    "append_history",
    "default_registry",
    "detect_regressions",
    "extract_series",
    "leaf_drift_distance",
    "load_history",
    "prometheus_text",
    "set_default_registry",
    "snapshot",
    "survival_from_classes",
    "write_chrome_trace",
    "write_json_snapshot",
]
