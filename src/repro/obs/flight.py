"""SLO flight recorder: a bounded ring of recent waves + crash-dump bundles.

A serve engine under load is the one place a perf bug is both most costly
and hardest to reproduce after the fact — by the time someone looks, the
offending wave is gone.  :class:`FlightRecorder` keeps a bounded in-memory
ring of the engine's most recent wave records (latency, bucket, sizes,
caller-supplied annotations) and, when something goes wrong — a wave
breaching the latency SLO, an exception escaping the eval path, or an
explicit ``engine.dump_flight()`` — writes a self-contained debug bundle
to disk:

* ``flight.json`` — the dump reason, the policy, the wave ring, and a full
  metrics-registry snapshot (via :func:`repro.obs.export.snapshot`);
* ``trace.json`` — the tracer's Chrome/Perfetto trace of the same window,
  loadable in ``ui.perfetto.dev``.

Breaches and dumps are themselves counted in the registry
(``flight.slo_breaches``, ``flight.dumps``) so a fleet exporter sees them
without reading disk.  Dumping is rate-limited (``min_dump_interval_s``)
so a sustained breach storm produces one bundle, not thousands.

Stdlib-only (plus the sibling obs modules) — importable without jax.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from pathlib import Path
from typing import Optional

from .export import snapshot
from .metrics import Registry
from .trace import NULL_TRACER, Tracer

__all__ = ["FlightPolicy", "FlightRecorder"]


@dataclasses.dataclass(frozen=True)
class FlightPolicy:
    """What the recorder keeps, what trips it, and where bundles land.

    ``slo_ms=None`` disables breach detection (the ring and manual dumps
    still work).  ``capacity`` bounds the wave ring.  Bundles are written
    under ``out_dir`` as ``flight-<engine>-<seq>-<reason>/``.
    """

    slo_ms: Optional[float] = None
    capacity: int = 256
    out_dir: str = "/tmp/repro_flight"
    min_dump_interval_s: float = 30.0
    dump_on_breach: bool = True
    dump_on_exception: bool = True


class FlightRecorder:
    """Bounded wave ring + breach accounting + debug-bundle dumps.

    One recorder serves one engine; engines call :meth:`note_wave` after
    each wave and :meth:`note_exception` when eval raises.  Thread-safe —
    serve engines may run waves from worker threads.
    """

    def __init__(
        self,
        policy: Optional[FlightPolicy] = None,
        *,
        registry: Optional[Registry] = None,
        tracer: Optional[Tracer] = None,
        engine: str = "serve",
    ):
        self.policy = policy or FlightPolicy()
        self.engine = engine
        self._registry = registry
        self._tracer = tracer if tracer is not None else NULL_TRACER
        self._ring: deque = deque(maxlen=max(1, int(self.policy.capacity)))
        self._lock = threading.Lock()
        self._seq = 0
        self._last_dump_t: Optional[float] = None
        if registry is not None:
            self._m_breaches = registry.counter(
                "flight.slo_breaches",
                "Waves whose latency exceeded the flight-recorder SLO",
                ("engine",),
            ).labels(engine=engine)
            self._m_dumps = registry.counter(
                "flight.dumps",
                "Flight-recorder debug bundles written, by trigger",
                ("engine", "reason"),
            )
        else:
            self._m_breaches = None
            self._m_dumps = None

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------

    def note_wave(self, *, latency_ms: float, bucket: str = "",
                  records: int = 0, requests: int = 0, **annotations) -> bool:
        """Record one completed wave; returns True if it breached the SLO.

        A breach increments ``flight.slo_breaches`` and (policy permitting,
        rate limit permitting) dumps a bundle.
        """
        rec = {
            "t": time.time(),
            "latency_ms": float(latency_ms),
            "bucket": str(bucket),
            "records": int(records),
            "requests": int(requests),
        }
        if annotations:
            rec.update({k: _jsonable(v) for k, v in annotations.items()})
        slo = self.policy.slo_ms
        breached = slo is not None and latency_ms > slo
        rec["breach"] = breached
        with self._lock:
            self._ring.append(rec)
        if breached:
            if self._m_breaches is not None:
                self._m_breaches.inc()
            if self.policy.dump_on_breach:
                self._maybe_dump("slo_breach")
        return breached

    def note_drift(self, *, bucket: str = "", distance: float = 0.0,
                   **annotations) -> None:
        """Record a traversal-drift event in the wave ring.

        Drift is context, not an emergency: the record rides the ring so the
        *next* bundle (whatever triggers it) shows that the workload's leaf
        distribution moved — no dump of its own.
        """
        rec = {
            "t": time.time(),
            "drift": True,
            "bucket": str(bucket),
            "distance": float(distance),
        }
        if annotations:
            rec.update({k: _jsonable(v) for k, v in annotations.items()})
        with self._lock:
            self._ring.append(rec)

    def note_exception(self, exc: BaseException) -> None:
        """Record an exception escaping the eval path; dump if configured."""
        rec = {
            "t": time.time(),
            "exception": type(exc).__name__,
            "message": str(exc),
        }
        with self._lock:
            self._ring.append(rec)
        if self.policy.dump_on_exception:
            self._maybe_dump("exception")

    # ------------------------------------------------------------------
    # dumping
    # ------------------------------------------------------------------

    def _maybe_dump(self, reason: str) -> Optional[Path]:
        """Dump unless within the rate-limit window (manual dumps bypass it)."""
        now = time.monotonic()
        with self._lock:
            last = self._last_dump_t
            if last is not None and now - last < self.policy.min_dump_interval_s:
                return None
            self._last_dump_t = now
        return self.dump(reason, _stamp=False)

    def dump(self, reason: str = "manual", *, _stamp: bool = True) -> Path:
        """Write a ``flight-<engine>-<seq>-<reason>/`` bundle; returns its path."""
        with self._lock:
            self._seq += 1
            seq = self._seq
            ring = list(self._ring)
            if _stamp:
                self._last_dump_t = time.monotonic()
        out = Path(self.policy.out_dir) / f"flight-{self.engine}-{seq:04d}-{reason}"
        out.mkdir(parents=True, exist_ok=True)
        bundle = {
            "engine": self.engine,
            "reason": reason,
            "ts": time.time(),
            "policy": dataclasses.asdict(self.policy),
            "waves": ring,
            "metrics": snapshot(self._registry) if self._registry is not None else None,
        }
        (out / "flight.json").write_text(json.dumps(bundle, indent=2, sort_keys=True))
        (out / "trace.json").write_text(json.dumps(self._tracer.chrome_trace()))
        if self._m_dumps is not None:
            self._m_dumps.labels(engine=self.engine, reason=reason).inc()
        return out

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def waves(self) -> list:
        """A copy of the current wave ring, oldest first."""
        with self._lock:
            return list(self._ring)


def _jsonable(v):
    """Coerce an annotation value to something json.dumps accepts."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)
