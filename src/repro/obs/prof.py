"""Traversal profiler: sampled shadow passes feeding the §3.6 cost model.

PR 7/8 built generic telemetry *around* the kernels (latency histograms,
spans, perf trajectories); this module looks *inside* them.  A
:class:`TraversalProfiler` rides a serve engine's wave loop: 1-in-N waves
(policy-controlled, like ``RetunePolicy``) get a *shadow pass* — the
profiling descent from :mod:`repro.kernels.tree_eval.profile`, run off the
request path on a bounded record sample — whose device-side reductions
yield the quantities the paper's runtime model (§3.6) otherwise only
*assumes*:

* measured mean traversal depth **d_µ** per shape bucket (vs the
  ``tune/heuristic.py`` geometry prior),
* the **speculation-waste ratio** ``N / d_µ`` — node evaluations the
  speculative all-nodes pass pays per record over the divergent descent,
* per-level **active-lane fractions** (SIMD occupancy by round),
* per-leaf **hit histograms**, windowed into a **drift detector**: when
  live traffic stops landing where it used to, the bucket's tuned winner
  and cascade plan were chosen for a workload that no longer exists, so
  drift raises an event that (via the engine's ``on_drift`` hook) forces a
  background re-tune and is recorded in flight bundles.

Everything is published twice: through the shared :class:`~repro.obs.
metrics.Registry` (gauges + histograms + counters, Prometheus-exportable)
and as Perfetto *counter tracks* via :meth:`~repro.obs.trace.Tracer.
counter`, so d_µ / waste / survival render as stepped timelines alongside
the wave spans.

The feedback loop closes in ``tune/dispatch.py``: evaluators consult
:meth:`TraversalProfiler.d_mu` / :meth:`survival` before falling back to
host sampling or the geometry prior, with provenance counters mirroring
``tune.heuristic_agreement``.

Drift thresholding follows :mod:`repro.obs.perf`'s noise-aware style: a
fixed floor until enough history exists, then ``max(floor, median +
k·MAD)`` of the bucket's own past distances — quiet buckets get tight
thresholds, noisy ones are not flagged for breathing.
"""

from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.obs.metrics import DEFAULT_RATIO_BOUNDARIES, Registry
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = [
    "BucketProfile",
    "ProfilePolicy",
    "TraversalProfiler",
    "leaf_drift_distance",
    "survival_from_classes",
]

# Exit-depth histogram grid: unit steps through the depths real CART trees
# reach, geometric past that (the descent is O(depth) rounds, capped ~64).
DEPTH_BOUNDARIES: tuple[float, ...] = (
    1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0, 16.0, 20.0, 24.0,
    32.0, 48.0, 64.0,
)


@dataclasses.dataclass(frozen=True)
class ProfilePolicy:
    """When and how much to shadow-profile (engine-level, like RetunePolicy).

    Attributes:
      sample_every: profile every k-th wave of each bucket (the first wave
        always profiles so a fresh bucket gets measured d_µ before its
        first background re-tune).  ``<= 0`` disables profiling entirely.
        The default (64) keeps the request-path median clean on CPU-only
        hosts, where a shadow pass co-running with serving steals compute
        from the wave being served — sampled waves pay a few ms of
        co-run cost, the rest pay one counter increment.
      sample_records: per-pass record cap — the shadow descent runs on at
        most this many records of the sampled wave (bounds its cost
        independently of ``max_batch``).
      max_concurrent: shadow passes in flight at once; further sampled
        waves are skipped, not queued (profiling must never back-pressure
        serving).
      synchronous: run the pass inline in ``note_wave`` instead of a
        worker thread — deterministic, for tests and the smoke check.
      drift_window: leaf-histogram window length per bucket.
      drift_min_samples: histograms required before drift is evaluated.
      drift_threshold: χ² distance floor that always counts as drift.
      drift_k_mad: noise multiplier — with enough history the effective
        threshold is ``max(drift_threshold, median + k·MAD)`` of the
        bucket's past distances.
    """

    sample_every: int = 64
    sample_records: int = 512
    max_concurrent: int = 1
    synchronous: bool = False
    drift_window: int = 8
    drift_min_samples: int = 4
    drift_threshold: float = 0.25
    drift_k_mad: float = 5.0


@dataclasses.dataclass
class BucketProfile:
    """Latest measured traversal statistics for one shape bucket."""

    d_mu: float                      # measured mean traversal depth
    waste_ratio: float               # N / d_mu (§3.6 speculative waste)
    survival: Optional[float]        # measured cascade survival (forests)
    samples: int                     # shadow passes contributing
    records: int                     # records profiled in total
    level_active: np.ndarray         # (max_depth,) active-lane fraction
    leaf_hist: np.ndarray            # (N,) latest leaf-hit counts


def leaf_drift_distance(p: np.ndarray, q: np.ndarray) -> float:
    """Symmetric χ² distance between two leaf-hit distributions.

    ``0.5 · Σ (p_i − q_i)² / (p_i + q_i)`` over the normalised histograms —
    bounded [0, 1], zero iff identical, and (unlike KL) defined when leaves
    go unvisited.  Mismatched lengths are padded with zeros (a re-encoded
    tree changes its leaf count; the mass moved is what matters).
    """
    p = np.asarray(p, np.float64).ravel()
    q = np.asarray(q, np.float64).ravel()
    n = max(p.size, q.size)
    if p.size < n:
        p = np.pad(p, (0, n - p.size))
    if q.size < n:
        q = np.pad(q, (0, n - q.size))
    ps, qs = p.sum(), q.sum()
    if ps <= 0 or qs <= 0:
        return 0.0 if ps == qs else 1.0
    p, q = p / ps, q / qs
    denom = p + q
    mask = denom > 0
    return float(0.5 * np.sum((p[mask] - q[mask]) ** 2 / denom[mask]))


def survival_from_classes(
    classes: np.ndarray, n_classes: int, *, stages: int = 2, bound: float = 1.0
) -> Optional[float]:
    """Measured per-stage cascade survival from profiled per-tree votes.

    Replays the margin-exit rule of :mod:`repro.kernels.tree_eval.cascade`
    on the shadow pass's (T, M) per-tree classes: trees split into
    ``stages`` even prefixes, a record survives a stage while its vote
    margin can still be flipped (``margin <= bound · remaining``).  Returns
    the mean fraction alive entering stages 2..S — the quantity
    ``measured_survival_rate`` estimates with an extra evaluation, now free
    with every profile.  ``None`` when there is no ensemble to cascade
    (single tree or fewer than 2 trees/stages).
    """
    classes = np.asarray(classes)
    if classes.ndim != 2 or classes.shape[0] < 2 or stages < 2:
        return None
    t, m = classes.shape
    stages = min(stages, t)
    votes = np.zeros((m, int(n_classes)), np.int64)
    cut_prev = 0
    alive_fracs = []
    for s in range(1, stages):
        cut = (t * s) // stages
        for ti in range(cut_prev, cut):
            np.add.at(votes, (np.arange(m), np.clip(classes[ti], 0, n_classes - 1)), 1)
        cut_prev = cut
        part = np.sort(votes, axis=1)
        margin = part[:, -1] - part[:, -2]
        remaining = t - cut
        alive_fracs.append(float((margin <= bound * remaining).mean()))
    return float(np.mean(alive_fracs)) if alive_fracs else None


class TraversalProfiler:
    """Sampled shadow-pass profiler attached to a serve engine's wave loop.

    Args:
      profile_fn: ``batch -> TreeProfile | ForestProfile`` — the engine
        binds :func:`~repro.kernels.tree_eval.profile.profile_tree_eval` or
        ``profile_forest_eval`` over its model (kept a closure so this
        module stays jax-free and testable with fakes).
      policy: sampling/drift policy; ``None`` → default :class:`ProfilePolicy`.
      registry / tracer: the engine's obs pair; metrics land under
        ``prof.*`` and counter tracks under ``prof.<stat>/<bucket>``.
      n_nodes: node-table size N for the waste ratio; inferred from the
        profile's hit arrays when omitted.
      n_classes: enables measured cascade survival on (T, M) profiles.
      on_drift: ``(bucket_key, distance, records) -> None`` — the engine
        wires this to flight-recorder annotation + forced re-tune.
      engine: label stamped on spans/bundle annotations.
    """

    def __init__(
        self,
        profile_fn: Callable[[np.ndarray], object],
        policy: Optional[ProfilePolicy] = None,
        *,
        registry: Optional[Registry] = None,
        tracer: Optional[Tracer] = None,
        n_nodes: Optional[int] = None,
        n_classes: Optional[int] = None,
        on_drift: Optional[Callable[[str, float, np.ndarray], None]] = None,
        engine: str = "engine",
    ):
        self.profile_fn = profile_fn
        self.policy = policy if policy is not None else ProfilePolicy()
        self.obs = registry if registry is not None else Registry(enabled=False)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.n_nodes = n_nodes
        self.n_classes = n_classes
        self.on_drift = on_drift
        self.engine = engine

        self._lock = threading.Lock()
        self._wave_counts: dict[str, int] = {}
        self._profiles: dict[str, BucketProfile] = {}
        # drift state per bucket: window of normalised hists + past distances
        self._windows: dict[str, deque] = {}
        self._distances: dict[str, list[float]] = {}
        self._threads: list[threading.Thread] = []

        r = self.obs
        self.m_waves = r.counter("prof.waves", "waves seen by the profiler")
        self.m_sampled = r.counter("prof.sampled", "shadow profile passes run")
        self.m_skipped = r.counter(
            "prof.skipped", "sampled waves skipped (pass already in flight)")
        self.m_records = r.counter("prof.records", "records shadow-profiled")
        self.m_errors = r.counter("prof.errors", "shadow passes that raised")
        self.m_drift = r.counter(
            "prof.drift_events", "leaf-histogram drift events", ("bucket",))
        self.m_exit_depth = r.histogram(
            "prof.exit_depth", "per-record traversal depth (measured)",
            boundaries=DEPTH_BOUNDARIES)
        self.m_active = r.histogram(
            "prof.active_fraction", "active-lane fraction per descent level",
            boundaries=DEFAULT_RATIO_BOUNDARIES)
        self.m_d_mu = r.gauge(
            "prof.d_mu", "measured mean traversal depth per bucket", ("bucket",))
        self.m_waste = r.gauge(
            "prof.waste_ratio", "speculation waste N/d_mu per bucket (§3.6)",
            ("bucket",))
        self.m_survival = r.gauge(
            "prof.survival", "measured cascade survival per bucket", ("bucket",))
        self.m_drift_dist = r.gauge(
            "prof.drift_distance", "latest leaf-histogram chi^2 distance",
            ("bucket",))

    # -- wave hook (request thread; must stay cheap) -------------------------

    def note_wave(self, key: str, batch) -> bool:
        """Engine wave-end hook; returns True when a shadow pass was started.

        Sampling is per bucket: wave counts are tracked per ``key`` and the
        first wave of every bucket profiles immediately (measured d_µ should
        exist before the bucket's first re-tune), then every
        ``sample_every``-th wave after that.  The sampled slice is copied
        before handing off — the engine may reuse its batch buffer.
        """
        pol = self.policy
        if pol.sample_every <= 0:
            return False
        self.m_waves.inc()
        with self._lock:
            n = self._wave_counts.get(key, 0) + 1
            self._wave_counts[key] = n
            if (n - 1) % pol.sample_every != 0:
                return False
            self._threads = [t for t in self._threads if t.is_alive()]
            if not pol.synchronous and len(self._threads) >= pol.max_concurrent:
                self.m_skipped.inc()
                return False
            snap = np.array(batch[: pol.sample_records], np.float32, copy=True)
            if pol.synchronous:
                worker = None
            else:
                worker = threading.Thread(
                    target=self._work, args=(key, snap),
                    name=f"profile:{key}", daemon=True)
                self._threads.append(worker)
        if worker is None:
            self._work(key, snap)
        else:
            worker.start()
        return True

    def drain(self, timeout: float = 10.0) -> None:
        """Join in-flight shadow passes (tests / engine shutdown)."""
        deadline = time.monotonic() + timeout
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(max(0.0, deadline - time.monotonic()))

    # -- feedback API (consumed by tune/dispatch.py) -------------------------

    def profile(self, key: str) -> Optional[BucketProfile]:
        """Latest :class:`BucketProfile` for ``key`` (None: never profiled)."""
        with self._lock:
            return self._profiles.get(key)

    def keys(self) -> list[str]:
        """Every bucket with at least one completed shadow pass, sorted."""
        with self._lock:
            return sorted(self._profiles)

    def d_mu(self, key: str) -> Optional[float]:
        """Measured d_µ for ``key``, or None when the bucket is unprofiled."""
        p = self.profile(key)
        return p.d_mu if p is not None else None

    def survival(self, key: str) -> Optional[float]:
        """Measured cascade survival for ``key`` (None when unprofiled/1-tree)."""
        p = self.profile(key)
        return p.survival if p is not None else None

    # -- shadow pass (worker thread unless policy.synchronous) ---------------

    def _work(self, key: str, snap: np.ndarray) -> None:
        try:
            with self.tracer.span("prof.shadow", cat="prof", bucket=key,
                                  engine=self.engine, records=snap.shape[0]):
                prof = self.profile_fn(snap)
            self._publish(key, snap, prof)
        except Exception:
            self.m_errors.inc()

    def _publish(self, key: str, snap: np.ndarray, prof) -> None:
        exit_depth = np.asarray(prof.exit_depth).ravel()
        node_hits = np.asarray(prof.node_hits)
        if hasattr(prof, "leaf_histogram"):           # ForestProfile
            leaf_hist = prof.leaf_histogram()
            level_active = prof.mean_level_active()
        else:                                         # TreeProfile
            leaf_hist = np.asarray(prof.leaf_hits)
            level_active = np.asarray(prof.level_active)
        d_mu = float(exit_depth.mean()) if exit_depth.size else 0.0
        n_nodes = self.n_nodes if self.n_nodes is not None else node_hits.shape[-1]
        waste = float(n_nodes) / max(d_mu, 1.0)
        survival = None
        if self.n_classes is not None:
            classes = np.asarray(prof.classes)
            survival = survival_from_classes(classes, self.n_classes)

        self.m_sampled.inc()
        self.m_records.inc(exit_depth.size)
        self.m_exit_depth.observe_many(exit_depth)
        self.m_active.observe_many(level_active)
        self.m_d_mu.labels(bucket=key).set(d_mu)
        self.m_waste.labels(bucket=key).set(waste)
        if survival is not None:
            self.m_survival.labels(bucket=key).set(survival)
        self.tracer.counter(f"prof.d_mu/{key}", d_mu, series="d_mu")
        self.tracer.counter(f"prof.waste/{key}", waste, series="waste_ratio")
        if survival is not None:
            self.tracer.counter(f"prof.survival/{key}", survival,
                                series="survival")

        drift_dist = self._note_drift(key, leaf_hist, snap)
        with self._lock:
            prev = self._profiles.get(key)
            self._profiles[key] = BucketProfile(
                d_mu=d_mu,
                waste_ratio=waste,
                survival=survival,
                samples=(prev.samples + 1) if prev else 1,
                records=(prev.records if prev else 0) + int(exit_depth.size),
                level_active=level_active,
                leaf_hist=leaf_hist,
            )
        if drift_dist is not None and self.on_drift is not None:
            self.on_drift(key, drift_dist, snap)

    def _note_drift(self, key: str, leaf_hist: np.ndarray,
                    snap: np.ndarray) -> Optional[float]:
        """Update the bucket's windowed leaf histograms; distance on drift.

        Baseline = elementwise mean of the window; distance = χ² of the new
        histogram against it.  Threshold is the policy floor until the
        bucket has ≥ 2 past distances, then ``max(floor, median + k·MAD)``
        of those — the perf-gate's noise-aware rule applied to drift.  On
        drift the window re-anchors on the new distribution, so a sustained
        shift fires once, not every pass thereafter.
        """
        total = float(np.asarray(leaf_hist, np.float64).sum())
        if total <= 0:
            return None
        hist = np.asarray(leaf_hist, np.float64) / total
        pol = self.policy
        with self._lock:
            window = self._windows.get(key)
            if window is None:
                window = self._windows[key] = deque(maxlen=pol.drift_window)
                self._distances[key] = []
            past = self._distances[key]
            if len(window) < pol.drift_min_samples:
                window.append(hist)
                return None
            baseline = np.mean(np.stack(list(window)), axis=0)
            dist = leaf_drift_distance(hist, baseline)
            if len(past) >= 2:
                med = statistics.median(past)
                mad = statistics.median(abs(d - med) for d in past)
                threshold = max(pol.drift_threshold, med + pol.drift_k_mad * mad)
            else:
                threshold = pol.drift_threshold
            self.m_drift_dist.labels(bucket=key).set(dist)
            if dist > threshold:
                window.clear()
                window.append(hist)
                past.clear()
                drifted = True
            else:
                window.append(hist)
                past.append(dist)
                drifted = False
        if drifted:
            self.m_drift.labels(bucket=key).inc()
            self.tracer.instant("prof.drift", cat="prof", bucket=key,
                                distance=dist, engine=self.engine)
            return dist
        return None
