"""Bench trajectory store + noise-aware perf-regression detection.

The paper's central claim is a *measured* one (a 25% speculative-vs-data-
parallel speedup on specific hardware), and every ``results/BENCH_*.json``
is a point-in-time overwrite — a PR that silently regresses the tuned path
would pass CI with the snapshot files alone.  This module gives the repo a
memory of its own performance:

* **history store** — :func:`append_history` turns one bench payload (the
  dict :func:`benchmarks.common.write_bench_json` writes) into a single
  JSONL line under ``results/history/<bench>.jsonl``: the env header, plus
  per-workload medians and dispersion extracted by :func:`extract_series`.
  Snapshots keep being overwritten; the trajectory only ever appends.
* **regression detector** — :func:`detect_regressions` compares the latest
  run against the median of the last ``window`` runs *from the same
  environment* (same backend / device kind / device count / interpret flag
  / jax version — cross-machine timings must never compare) and flags a
  series when its latest median exceeds the baseline by more than
  ``max(rel_threshold · baseline, k_mad · MAD)``.  The MAD term adapts the
  gate to each series' observed run-to-run noise; the relative floor keeps
  an all-identical history (MAD = 0) from flagging sub-noise jitter.

Stdlib-only on purpose: ``results/check_regressions.py`` (the CI
``perf-gate``) and ``results/make_table.py`` import this without jax.
"""

from __future__ import annotations

import dataclasses
import json
import statistics
from datetime import datetime, timezone
from pathlib import Path
from typing import Iterable, Optional

__all__ = [
    "ENV_KEYS",
    "Regression",
    "append_history",
    "check_history_dir",
    "detect_regressions",
    "env_key",
    "extract_series",
    "history_record",
    "load_history",
    "skipped_series",
]

#: Environment fields two runs must share before their timings may compare.
#: Intentionally excludes ``platform``/``python``: a kernel upgrade on the
#: same machine class should not orphan the whole baseline, but a different
#: backend, device kind, device count, interpret mode or jax version is a
#: different experiment.
ENV_KEYS = ("backend", "device_kind", "device_count", "pallas_interpret", "jax")

# Keys (in priority order) a bench entry may carry its headline median /
# dispersion under — the BENCH_*.json schemas are per-bench, the trajectory
# is not.
_MEDIAN_KEYS = ("median_ms", "tuned_ms", "forest_tuned_ms", "measured_ms")
_DISPERSION_KEYS = ("mad_ms", "tuned_mad_ms", "forest_tuned_mad_ms")


def env_key(env: dict) -> tuple:
    """The comparability key of one run's environment header."""
    return tuple(str(env.get(k)) for k in ENV_KEYS)


def _series_name(entry: dict) -> Optional[str]:
    """A stable trajectory id for one bench entry (None = not a timing row)."""
    base = entry.get("name") or entry.get("workload") or entry.get("mix")
    if not base:
        return None
    parts = [str(base)]
    mesh = entry.get("mesh")
    if mesh:
        parts.append("mesh" + "x".join(str(x) for x in mesh))
    for k in ("decomposition", "mode", "variant"):
        if entry.get(k):
            parts.append(str(entry[k]))
    if entry.get("stages") is not None:
        parts.append(f"s{entry['stages']}")
    if "bound" in entry:
        parts.append(f"b{entry['bound']}")
    return "/".join(parts)


def extract_series(payload: dict) -> dict[str, dict]:
    """Normalise one bench payload into ``{series: {median_ms[, mad_ms]}}``.

    Walks ``entries`` and ``forest_entries`` (the two timing lists the
    benches emit), derives a stable series name per row, and picks the
    row's headline median (``median_ms`` / ``tuned_ms`` / ``measured_ms``
    ...) plus its dispersion when recorded.  Rows without a recognisable
    median (accuracy-only or summary rows) are skipped.
    """
    out: dict[str, dict] = {}
    for group in ("entries", "forest_entries"):
        for entry in payload.get(group) or []:
            if not isinstance(entry, dict):
                continue
            name = _series_name(entry)
            if name is None:
                continue
            median = next(
                (entry[k] for k in _MEDIAN_KEYS
                 if isinstance(entry.get(k), (int, float))),
                None,
            )
            if median is None:
                continue
            rec: dict = {"median_ms": float(median)}
            disp = next(
                (entry[k] for k in _DISPERSION_KEYS
                 if isinstance(entry.get(k), (int, float))),
                None,
            )
            if disp is not None:
                rec["mad_ms"] = float(disp)
            key, i = name, 2
            while key in out:                  # defensive: never drop a row
                key, i = f"{name}#{i}", i + 1
            out[key] = rec
    return out


def history_record(bench: str, payload: dict, *, ts: Optional[str] = None,
                   source: str = "bench") -> dict:
    """One trajectory line: env header + normalised series of a bench run."""
    return {
        "bench": bench,
        "ts": ts or datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "source": source,
        "env": payload.get("env") or {},
        "series": extract_series(payload),
    }


def append_history(history_dir, bench: str, payload: dict, *,
                   ts: Optional[str] = None, source: str = "bench") -> Path:
    """Append one run to ``<history_dir>/<bench>.jsonl`` (created on demand)."""
    history_dir = Path(history_dir)
    history_dir.mkdir(parents=True, exist_ok=True)
    path = history_dir / f"{bench}.jsonl"
    line = json.dumps(history_record(bench, payload, ts=ts, source=source),
                      sort_keys=True)
    with open(path, "a") as f:
        f.write(line + "\n")
    return path


def load_history(path) -> list[dict]:
    """All runs of one trajectory file, oldest first (blank lines skipped)."""
    out = []
    text = Path(path).read_text()
    for i, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}:{i}: corrupt history line: {e}") from None
    return out


@dataclasses.dataclass(frozen=True)
class Regression:
    """One flagged series: latest median above the noise-aware threshold."""

    bench: str
    series: str
    latest_ms: float
    baseline_ms: float
    threshold_ms: float
    mad_ms: float
    n_baseline: int

    @property
    def ratio(self) -> float:
        return self.latest_ms / self.baseline_ms if self.baseline_ms else float("inf")

    def describe(self) -> str:
        return (
            f"{self.bench}/{self.series}: {self.latest_ms:.3f} ms vs baseline "
            f"{self.baseline_ms:.3f} ms (x{self.ratio:.2f}, threshold "
            f"{self.threshold_ms:.3f} ms over {self.n_baseline} run(s), "
            f"MAD {self.mad_ms:.3f} ms)"
        )


def baseline_pool(records: list[dict], *, window: int = 5) -> list[dict]:
    """The latest run's comparable predecessors: same env, last ``window``."""
    if len(records) < 2:
        return []
    key = env_key(records[-1].get("env") or {})
    pool = [r for r in records[:-1] if env_key(r.get("env") or {}) == key]
    return pool[-window:]


def detect_regressions(
    records: list[dict],
    *,
    bench: str = "?",
    window: int = 5,
    rel_threshold: float = 0.5,
    k_mad: float = 5.0,
) -> list[Regression]:
    """Flag series whose latest median regressed beyond the noise gate.

    Baseline per series = median of that series over the last ``window``
    same-environment runs preceding the latest; a series is flagged when

        latest > baseline + max(rel_threshold * baseline, k_mad * MAD)

    where MAD is the median absolute deviation of the baseline pool's
    medians.  Single-run histories, env-mismatched histories and series
    absent from the baseline contribute nothing (a new workload is not a
    regression).
    """
    latest = records[-1] if records else {}
    pool = baseline_pool(records, window=window)
    if not pool:
        return []
    out: list[Regression] = []
    for name, s in sorted((latest.get("series") or {}).items()):
        base_vals = [
            float(r["series"][name]["median_ms"])
            for r in pool
            if name in (r.get("series") or {})
        ]
        if not base_vals:
            continue
        baseline = statistics.median(base_vals)
        mad = statistics.median([abs(v - baseline) for v in base_vals])
        threshold = baseline + max(rel_threshold * baseline, k_mad * mad)
        latest_ms = float(s["median_ms"])
        if baseline > 0 and latest_ms > threshold:
            out.append(Regression(
                bench=bench, series=name, latest_ms=latest_ms,
                baseline_ms=baseline, threshold_ms=threshold,
                mad_ms=mad, n_baseline=len(base_vals),
            ))
    return out


def skipped_series(
    records: list[dict],
    *,
    window: int = 5,
    min_runs: int = 2,
) -> list[tuple[str, int]]:
    """Series in the latest run whose baseline is too thin to judge.

    Returns ``(series, n_baseline)`` for every series of the latest run
    backed by fewer than ``min_runs`` same-environment predecessor entries
    in the ``window``-run pool — including zero (a brand-new workload, or a
    history whose env just changed).  :func:`detect_regressions` silently
    contributes nothing for these; the CI gate wants them *reported*, so a
    run that checked nothing cannot read as a run that passed.
    """
    latest = records[-1] if records else {}
    pool = baseline_pool(records, window=window)
    out: list[tuple[str, int]] = []
    for name in sorted(latest.get("series") or {}):
        n = sum(1 for r in pool if name in (r.get("series") or {}))
        if n < min_runs:
            out.append((name, n))
    return out


def check_history_dir(
    history_dir,
    *,
    benches: Optional[Iterable[str]] = None,
    window: int = 5,
    rel_threshold: float = 0.5,
    k_mad: float = 5.0,
) -> dict[str, list[Regression]]:
    """Run :func:`detect_regressions` over every trajectory in a directory.

    Returns ``{bench: [Regression, ...]}`` with an entry for every file
    examined (empty list = healthy), so callers can distinguish "checked
    and clean" from "never checked".
    """
    history_dir = Path(history_dir)
    wanted = set(benches) if benches is not None else None
    out: dict[str, list[Regression]] = {}
    for path in sorted(history_dir.glob("*.jsonl")):
        bench = path.stem
        if wanted is not None and bench not in wanted:
            continue
        out[bench] = detect_regressions(
            load_history(path), bench=bench, window=window,
            rel_threshold=rel_threshold, k_mad=k_mad,
        )
    return out
