"""Exporters: JSON snapshot and Prometheus-style text exposition.

Two consumers, two formats:

* :func:`snapshot` — a JSON-ready dict of every series, histograms with
  derived p50/p95/p99, suitable for `results/`-style artifacts, tests and
  the serve engines' introspection endpoints;
* :func:`prometheus_text` — the text exposition format (``# TYPE`` headers,
  ``_bucket{le=...}``/``_sum``/``_count`` histogram triplets) a Prometheus
  scraper ingests directly.  Metric names are sanitised (dots → underscores)
  per the exposition grammar; the dotted originals stay in the snapshot.

Both are pure functions of a :class:`repro.obs.metrics.Registry` — stdlib
only, no jax — so the CI ``obs`` job can parse and assert on their output
without touching the accelerator stack.
"""

from __future__ import annotations

import json
import math
import re
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.obs.metrics import Registry

__all__ = ["prometheus_text", "snapshot", "series_name", "write_json_snapshot"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    out = _NAME_RE.sub("_", name)
    return out if not out[:1].isdigit() else "_" + out


def _prom_labels(labelnames, labelvalues) -> str:
    if not labelnames:
        return ""
    pairs = ",".join(  # OpenMetrics: no whitespace between label pairs
        f'{_prom_name(k)}="{_escape(v)}"' for k, v in zip(labelnames, labelvalues)
    )
    return "{" + pairs + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def series_name(name: str, labelnames, labelvalues) -> str:
    """Human/JSON series id: ``name{label="value",...}`` (dotted name kept)."""
    if not labelnames:
        return name
    pairs = ",".join(f'{k}="{_escape(v)}"' for k, v in zip(labelnames, labelvalues))
    return f"{name}{{{pairs}}}"


def snapshot(registry: "Registry") -> dict:
    """JSON-ready state of every series in ``registry``.

    Layout::

        {"counters":   {series: value, ...},
         "gauges":     {series: value, ...},
         "histograms": {series: {count, sum, min, max, p50, p95, p99,
                                 boundaries, bucket_counts}, ...}}

    Histogram percentiles are interpolated from the fixed buckets (see
    :meth:`repro.obs.metrics.Histogram.quantile`); an empty histogram
    reports ``null`` percentiles rather than NaN so the dict round-trips
    through strict JSON.
    """
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for metric in registry.metrics():
        for labelvalues, series in metric.series():
            key = series_name(metric.name, metric.labelnames, labelvalues)
            if metric.kind == "counter":
                out["counters"][key] = series.value
            elif metric.kind == "gauge":
                out["gauges"][key] = series.value
            elif metric.kind == "histogram":
                state = series.state()
                state.update(series.percentiles())
                out["histograms"][key] = state
    return out


def write_json_snapshot(registry: "Registry", path) -> None:
    """Serialise :func:`snapshot` to ``path`` (strict JSON, sorted keys)."""
    with open(path, "w") as f:
        json.dump(snapshot(registry), f, indent=1, sort_keys=True)
        f.write("\n")


def prometheus_text(registry: "Registry") -> str:
    """The Prometheus text exposition of every series in ``registry``."""
    lines: list[str] = []
    for metric in registry.metrics():
        pname = _prom_name(metric.name)
        if metric.help:
            lines.append(f"# HELP {pname} {metric.help}")
        lines.append(f"# TYPE {pname} {metric.kind}")
        for labelvalues, series in metric.series():
            labels = _prom_labels(metric.labelnames, labelvalues)
            if metric.kind in ("counter", "gauge"):
                lines.append(f"{pname}{labels} {_fmt(series.value)}")
                continue
            state = series.state()
            cum = 0
            for b, c in zip(state["boundaries"], state["bucket_counts"]):
                cum += c
                le = 'le="' + _fmt(b) + '"'
                lines.append(f"{pname}_bucket{_merge(labels, le)} {cum}")
            cum += state["bucket_counts"][-1]
            inf = 'le="+Inf"'
            lines.append(f"{pname}_bucket{_merge(labels, inf)} {cum}")
            lines.append(f"{pname}_sum{labels} {_fmt(state['sum'])}")
            lines.append(f"{pname}_count{labels} {state['count']}")
    return "\n".join(lines) + "\n"


def _merge(labels: str, extra: str) -> str:
    if not labels:
        return "{" + extra + "}"
    return labels[:-1] + "," + extra + "}"


def _fmt(v: float) -> str:
    if v != v or math.isinf(v):  # exposition format spells these out
        return "+Inf" if v > 0 else ("-Inf" if v < 0 else "NaN")
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))
