"""Thread-safe metrics registry: counters, gauges, fixed-boundary histograms.

The paper's argument is carried entirely by *measured* per-kernel times
(§4, Fig. 4); the serving stack needs the same discipline as a first-class
substrate rather than ad-hoc dataclass fields.  This module is that
substrate's storage layer:

* :class:`Counter` — monotonically increasing float (locked add);
* :class:`Gauge`   — last-write-wins float (locked set);
* :class:`Histogram` — fixed-boundary bucket counts with sum/min/max and
  p50/p95/p99 derivation by linear interpolation inside the bucket.

All instruments support *labels*: an instrument created with ``labelnames``
is a parent whose :meth:`~Instrument.labels` call returns (and memoises) a
child series per label-value tuple — the per-bucket wave-latency histograms
the serve engines keep are one parent with one child per shape bucket.

Concurrency: every mutation takes the instrument's own lock, so counters
shared between the request thread and the :class:`~repro.serve.engine.
BackgroundRetuner` worker cannot lose increments (the data race the old
``stats.retunes += 1`` dataclass field had).  Reads take the same lock and
therefore observe a consistent (count, sum, buckets) triple.

Cost when disabled: each mutation is one attribute load and a branch —
``Registry(enabled=False)`` makes the whole stack observation-free without
any call-site changes, which is what keeps the serve-path overhead budget
(<2%, measured in ``benchmarks/obs_overhead.py``) honest.

Duplicate protection: re-requesting an instrument with the identical
definition returns the existing one (engines and evaluators sharing a
registry deliberately share series); re-registering a name with a different
kind, help string, label set or boundaries raises
:class:`DuplicateMetricError` — the CI ``obs`` job asserts this fires.
"""

from __future__ import annotations

import threading
from typing import Iterator, Optional, Sequence

try:  # numpy is optional here: the registry itself stays stdlib-only, but
    # array-sized bulk observations (observe_many) vectorise when it exists
    import numpy as _np
except ImportError:  # pragma: no cover - numpy is always present in-repo
    _np = None

__all__ = [
    "Counter",
    "DEFAULT_MS_BOUNDARIES",
    "DEFAULT_RATIO_BOUNDARIES",
    "DuplicateMetricError",
    "Gauge",
    "Histogram",
    "Registry",
    "default_registry",
    "set_default_registry",
]


class DuplicateMetricError(ValueError):
    """A metric name was re-registered with a conflicting definition."""


# Latency histograms default to a geometric ms grid spanning sub-kernel
# dispatch (~50 µs) to multi-second waves; ratio histograms (overlap, pad
# fraction, confidence) to a uniform [0, 1] grid.
DEFAULT_MS_BOUNDARIES: tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
)
DEFAULT_RATIO_BOUNDARIES: tuple[float, ...] = tuple(i / 10.0 for i in range(11))


class Instrument:
    """Common parent/child plumbing for all instrument kinds.

    A parent (created through the registry) may carry ``labelnames``; its
    children (one per label-value tuple, via :meth:`labels`) do the actual
    recording.  An unlabelled instrument is its own single series.
    """

    kind = "instrument"

    def __init__(self, registry: "Registry", name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self._registry = registry
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], "Instrument"] = {}

    # -- labels -------------------------------------------------------------

    def _make_child(self) -> "Instrument":
        raise NotImplementedError

    def labels(self, **labelvalues: object) -> "Instrument":
        """The child series for these label values (created on first use)."""
        if tuple(sorted(labelvalues)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(sorted(labelvalues))}"
            )
        key = tuple(str(labelvalues[k]) for k in self.labelnames)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    def series(self) -> Iterator[tuple[tuple[str, ...], "Instrument"]]:
        """(label-values, series) pairs — the instrument itself if unlabelled."""
        if self.labelnames:
            with self._lock:
                items = sorted(self._children.items())
            yield from items
        else:
            yield (), self

    def _definition(self) -> tuple:
        return (self.kind, self.help, self.labelnames)


class Counter(Instrument):
    """Monotonically increasing value (float; ``inc`` by any amount ≥ 0)."""

    kind = "counter"

    def __init__(self, registry, name, help="", labelnames=()):
        super().__init__(registry, name, help, labelnames)
        self._value = 0.0

    def _make_child(self) -> "Counter":
        return Counter(self._registry, self.name, self.help)

    def inc(self, amount: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease ({amount})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(Instrument):
    """Last-write-wins value (``set``/``add``)."""

    kind = "gauge"

    def __init__(self, registry, name, help="", labelnames=()):
        super().__init__(registry, name, help, labelnames)
        self._value = 0.0

    def _make_child(self) -> "Gauge":
        return Gauge(self._registry, self.name, self.help)

    def set(self, value: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value = float(value)

    def add(self, amount: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(Instrument):
    """Fixed-boundary histogram with quantile derivation.

    ``boundaries`` are the ascending upper bucket edges; an implicit +Inf
    bucket catches overflow.  ``quantile(q)`` interpolates linearly inside
    the bucket holding the q-th observation — exact enough for p50/p95/p99
    over latency grids while storing O(len(boundaries)) state, never the
    raw samples.
    """

    kind = "histogram"

    def __init__(self, registry, name, help="", labelnames=(),
                 boundaries: Sequence[float] = DEFAULT_MS_BOUNDARIES):
        super().__init__(registry, name, help, labelnames)
        bs = tuple(float(b) for b in boundaries)
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(f"boundaries must be ascending and non-empty: {bs}")
        self.boundaries = bs
        self._counts = [0] * (len(bs) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def _make_child(self) -> "Histogram":
        return Histogram(self._registry, self.name, self.help,
                         boundaries=self.boundaries)

    def _definition(self) -> tuple:
        return (self.kind, self.help, self.labelnames, self.boundaries)

    def observe(self, value: float) -> None:
        if not self._registry.enabled:
            return
        v = float(value)
        i = 0
        for b in self.boundaries:          # ≤ ~17 comparisons; no bisect import
            if v <= b:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def observe_many(self, values) -> None:
        """Bulk-observe an iterable (e.g. per-record confidences or margins)
        under one lock acquisition — the hot-path form for array-sized
        observations; vectorised via numpy when available."""
        if not self._registry.enabled:
            return
        bs = self.boundaries
        if _np is not None:
            arr = _np.asarray(values, dtype=float).ravel()
            if arr.size == 0:
                return
            # searchsorted(side="left"): first index i with v <= bs[i] —
            # exactly observe()'s bucket rule; i == len(bs) is the overflow
            idx = _np.searchsorted(bs, arr, side="left")
            adds = _np.bincount(idx, minlength=len(bs) + 1)
            n, total = int(arr.size), float(arr.sum())
            mn, mx = float(arr.min()), float(arr.max())
        else:
            vs = [float(v) for v in values]
            if not vs:
                return
            adds = [0] * (len(bs) + 1)
            for v in vs:
                i = 0
                for b in bs:
                    if v <= b:
                        break
                    i += 1
                adds[i] += 1
            n, total = len(vs), sum(vs)
            mn, mx = min(vs), max(vs)
        with self._lock:
            for i, a in enumerate(adds):
                self._counts[i] += int(a)
            self._count += n
            self._sum += total
            if mn < self._min:
                self._min = mn
            if mx > self._max:
                self._max = mx

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> Optional[float]:
        """The q-quantile (0 ≤ q ≤ 1) by in-bucket interpolation; None if empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self._count == 0:
                return None
            counts, total = list(self._counts), self._count
            lo, hi = self._min, self._max
        rank = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if cum + c >= rank and c > 0:
                # bucket edges, clamped to the observed [min, max] range: a
                # bucket holding samples always has lo ≤ its samples ≤ hi
                lower = self.boundaries[i - 1] if i > 0 else lo
                upper = self.boundaries[i] if i < len(self.boundaries) else hi
                lower, upper = max(lower, lo), min(upper, hi)
                if upper <= lower:
                    return upper
                frac = (rank - cum) / c
                return lower + frac * (upper - lower)
            cum += c
        return hi

    def percentiles(self) -> dict[str, Optional[float]]:
        return {"p50": self.quantile(0.5), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def state(self) -> dict:
        """A consistent snapshot of the full histogram state."""
        with self._lock:
            counts = list(self._counts)
            count, s = self._count, self._sum
            mn = self._min if self._count else None
            mx = self._max if self._count else None
        return {"count": count, "sum": s, "min": mn, "max": mx,
                "boundaries": list(self.boundaries), "bucket_counts": counts}


class Registry:
    """One namespace of instruments; thread-safe get-or-create registration.

    ``enabled`` gates every mutation (reads always work): a disabled
    registry's instruments are inert no-ops, so components instrumented
    unconditionally cost one branch per would-be observation.  Flipping
    ``enabled`` later re-activates the same instruments — handles cached by
    components stay valid either way.
    """

    def __init__(self, *, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: dict[str, Instrument] = {}

    # -- lifecycle ----------------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # -- registration -------------------------------------------------------

    def _get_or_create(self, cls, name: str, help: str, labelnames, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                want = cls(self, name, help, labelnames, **kw)._definition()
                if existing._definition() != want:
                    raise DuplicateMetricError(
                        f"metric {name!r} already registered as {existing._definition()}, "
                        f"re-registered as {want}"
                    )
                return existing
            inst = cls(self, name, help, labelnames, **kw)
            self._metrics[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  boundaries: Sequence[float] = DEFAULT_MS_BOUNDARIES) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   boundaries=boundaries)

    # -- introspection ------------------------------------------------------

    def metrics(self) -> list[Instrument]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def get(self, name: str) -> Optional[Instrument]:
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> dict:
        """JSON-ready state of every series (see :mod:`repro.obs.export`)."""
        from repro.obs.export import snapshot  # local: export imports metrics

        return snapshot(self)


_DEFAULT = Registry()
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> Registry:
    """The process-wide default registry (cross-cutting tune/dist counters).

    Components that cannot be handed a registry explicitly (one-shot
    functional APIs, module-level tuner calls) record here; engines default
    to their own private registry so per-engine stats views stay exact.
    """
    return _DEFAULT


def set_default_registry(registry: Registry) -> Registry:
    """Swap the process default (tests); returns the previous one."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        prev, _DEFAULT = _DEFAULT, registry
    return prev
