"""Render the §Roofline markdown table from a dryrun JSON."""

import json
import sys


def main(path, mesh_filter=None):
    rows = json.load(open(path))
    out = []
    hdr = ("| arch | shape | step | mesh | compute s | memory s | collective s "
           "| dominant | useful | frac | fit GB (TPU) |")
    out.append(hdr)
    out.append("|" + "---|" * 11)
    for r in rows:
        if "skip" in r:
            if mesh_filter in (None, "16x16"):
                out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                           f"SKIP: sub-quadratic only | — | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ? | {r.get('mesh','?')} "
                       f"| ERROR | | | | | | |")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        fit = r.get("fit_bytes_tpu", 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step'].replace('_step','')} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {fit:.1f} |"
        )
    print("\n".join(out))


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2] if len(sys.argv) > 2 else None)
