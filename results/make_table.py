"""Render markdown reports from the repo's machine-readable result files.

Three renderers share one CLI:

  * ``roofline <dryrun.json> [mesh]``   — the §Roofline table (original use);
  * ``benchmarks [-o docs/benchmarks.md]`` — the benchmark report: every
    ``results/BENCH_*.json`` (tune sweep, dist sweep) rendered into
    markdown tables, deterministically (same JSONs ⇒ byte-identical
    output), so CI can regenerate and diff;
  * ``check-links <file.md ...>``       — verify that relative markdown
    links in the given files resolve to existing files/anchors-free paths.

Stdlib only — the docs CI job runs these without importing jax.

    python results/make_table.py benchmarks -o docs/benchmarks.md
    python results/make_table.py check-links README.md docs/*.md
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parent

# repro.obs.perf is deliberately stdlib-only (and src/repro is a namespace
# package with no jax-importing __init__), so the docs job can read the
# bench trajectory without a jax install.
sys.path.insert(0, str(RESULTS_DIR.parent / "src"))

from repro.obs.perf import baseline_pool, load_history  # noqa: E402


# ---------------------------------------------------------------------------
# roofline table (dryrun JSONs)
# ---------------------------------------------------------------------------


def render_roofline(path, mesh_filter=None) -> str:
    """The §Roofline markdown table from a dryrun JSON."""
    rows = json.load(open(path))
    out = []
    hdr = ("| arch | shape | step | mesh | compute s | memory s | collective s "
           "| dominant | useful | frac | fit GB (TPU) |")
    out.append(hdr)
    out.append("|" + "---|" * 11)
    for r in rows:
        if "skip" in r:
            if mesh_filter in (None, "16x16"):
                out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                           f"SKIP: sub-quadratic only | — | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ? | {r.get('mesh','?')} "
                       f"| ERROR | | | | | | |")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        fit = r.get("fit_bytes_tpu", 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step'].replace('_step','')} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {fit:.1f} |"
        )
    return "\n".join(out)


# ---------------------------------------------------------------------------
# benchmark report (results/BENCH_*.json)
# ---------------------------------------------------------------------------


def _ms(x) -> str:
    return f"{x:.3f}"


def _cost_cells(e: dict) -> str:
    """The two roofline columns (compiled-HLO MiB, achieved roofline
    fraction) of a tuned-sweep entry; em-dashes for pre-PR-8 artifacts.

    Tree kernels are compare/gather programs — the FLOP counter
    (dot/convolution only) reads ~0 for them, so the byte side carries the
    signal and the fraction is the memory-roofline one.  Peaks are TPU v5e
    (`launch/roofline.py`); on interpret-mode CPU artifacts the absolute
    fraction is tiny by construction and only the trend is meaningful.
    """
    b, frac = e.get("bytes"), e.get("roofline_frac")
    mib = f"{b / 2**20:.2f}" if isinstance(b, (int, float)) else "—"
    fr = f"{frac:.2e}" if isinstance(frac, (int, float)) else "—"
    return f" {mib} | {fr} |"


def _env_note(data: dict) -> list[str]:
    """Render the ``env`` header benchmarks/common.py stamps into each JSON."""
    env = data.get("env")
    if not env:
        return []
    mode = "interpret" if env.get("pallas_interpret") else "compiled"
    return [
        f"*Environment: jax {env.get('jax', '?')} on `{env.get('backend', '?')}` "
        f"({env.get('device_kind', '?')} ×{env.get('device_count', '?')}, "
        f"pallas {mode}), python {env.get('python', '?')}.*",
        "",
    ]


def render_tree_eval(data: dict) -> str:
    """BENCH_tree_eval.json → tuned-dispatch report (tree + forest levels)."""
    out = ["## Tree-eval autotuning (`BENCH_tree_eval.json`)", ""]
    out.extend(_env_note(data))
    out.append(f"Backend `{data.get('backend', '?')}`, jax {data.get('jax', '?')}, "
               f"{data.get('cache_entries', '?')} cache entries after the sweep.")
    out.append("")
    out.append("### Per-tree: tuned dispatch vs every fixed variant")
    out.append("")
    out.append("| workload | M | N | A | d | best variant | best fixed ms "
               "| tuned ms | tuned/best | within noise | HLO MiB | roofline |")
    out.append("|" + "---|" * 12)
    for e in data.get("entries", []):
        s = e["shape"]
        out.append(
            f"| {e['workload']} | {s['m']} | {s['n_nodes']} | {s['n_attrs']} "
            f"| {e['depth']} | `{e['best_variant']}` {e['best_params'] or ''} "
            f"| {_ms(e['best_fixed_interleaved_ms'])} | {_ms(e['tuned_ms'])} "
            f"| {e['tuned_vs_best_fixed']:.3f} "
            f"| {'yes' if e['tuned_within_noise_of_best'] else 'NO'} |"
            + _cost_cells(e)
        )
    out.append("")
    out.append("Per-variant best medians (min over each variant's parameter grid):")
    out.append("")
    for e in data.get("entries", []):
        out.append(f"* **{e['workload']}** — " + ", ".join(
            f"`{k}` {_ms(v)} ms" for k, v in sorted(e["fixed_variants_ms"].items())
        ))
    forest = data.get("forest_entries", [])
    if forest:
        out.append("")
        out.append("### Forest level: tuned family vs the per-tree path")
        out.append("")
        out.append("The forest tuner ranks three candidate families — per-tree "
                   "variant vectors, shared-variant vmap, fused stacked kernel "
                   "— per (T, M, N_max, A, depth-profile) bucket.")
        out.append("")
        out.append("| workload | T | M | depth profile | winning candidate "
                   "| forest tuned ms | per-tree ms | tuned/per-tree | not worse "
                   "| HLO MiB | roofline |")
        out.append("|" + "---|" * 11)
        for e in forest:
            s = e["shape"]
            out.append(
                f"| {e['workload']} | {s['t']} | {s['m']} "
                f"| d{s['depth_min']}–{s['depth_max']} "
                f"| `{e['best_variant']}` {e['best_params'] or ''} "
                f"| {_ms(e['forest_tuned_ms'])} | {_ms(e['per_tree_ms'])} "
                f"| {e['forest_tuned_vs_per_tree']:.3f} "
                f"| {'yes' if e['forest_tuned_not_worse'] else 'NO'} |"
                + _cost_cells(e)
            )
        out.append("")
        out.append("Per-candidate best medians:")
        out.append("")
        for e in forest:
            out.append(f"* **{e['workload']}** — " + ", ".join(
                f"`{k}` {_ms(v)} ms" for k, v in sorted(e["candidate_best_ms"].items())
            ))
    return "\n".join(out)


def render_dist(data: dict) -> str:
    """BENCH_dist.json → plan-predicted vs measured decomposition report."""
    out = ["## Sharded-forest decomposition sweep (`BENCH_dist.json`)", ""]
    out.extend(_env_note(data))
    out.append(f"Backend `{data.get('backend', '?')}`, jax {data.get('jax', '?')}, "
               f"{data.get('n_devices', '?')} forced host devices; "
               f"mesh shapes {data.get('mesh_shapes', '?')}.  Predicted costs are "
               f"model units (rank-valid, not milliseconds).")
    out.append("")
    out.append("### Per-mesh measurements")
    out.append("")
    out.append("| workload | mesh R×G | decomposition | shard algorithm "
               "| predicted (units) | measured ms |")
    out.append("|" + "---|" * 6)
    for e in data.get("entries", []):
        if e.get("mode"):
            continue
        r, g = e["mesh"]
        out.append(
            f"| {e['workload']} | {r}×{g} | {e['decomposition']} "
            f"| {e['shard_algorithm']} | {e['predicted_model_units']:.1f} "
            f"| {_ms(e['measured_ms'])} |"
        )
    out.append("")
    out.append("### Streaming chunker (double-buffered) vs monolithic")
    out.append("")
    out.append("Chunk sizes adapt online (throughput-feedback coalescing): "
               "`coalesced` is the effective chunk size after the sweep; "
               "`overlap` is the mean fraction of each chunk's submit→ready "
               "window shared with the previous in-flight chunk.")
    out.append("")
    out.append("| workload | mesh R×G | chunk records | coalesced | stream ms "
               "| monolithic ms | chunk median ms | overlap |")
    out.append("|" + "---|" * 8)
    for e in data.get("entries", []):
        if e.get("mode") != "stream_chunked":
            continue
        r, g = e["mesh"]
        out.append(
            f"| {e['workload']} | {r}×{g} | {e['chunk_records']} "
            f"| {e.get('coalesced_chunk_records', e['chunk_records'])} "
            f"| {_ms(e['measured_ms'])} | {_ms(e['monolithic_ms'])} "
            f"| {_ms(e['chunk_ms_median'])} "
            f"| {e.get('overlap_ratio_mean', 0.0):.2f} |"
        )
    out.append("")
    out.append("### Plan-predicted vs measured winners")
    out.append("")
    out.append(f"Crossover agreement: **{data.get('crossover_agreement', '?')}** "
               f"(predicted-best mesh == measured-best mesh per workload).")
    out.append("")
    out.append("| workload | M | T | d_µ | planner choice | predicted winner "
               "| measured winner | agree |")
    out.append("|" + "---|" * 8)
    for s in data.get("summaries", []):
        ws = s["workload_shape"]
        pc = s["planner_choice"]
        pw = "×".join(str(x) for x in s["predicted_winner_mesh"])
        mw = "×".join(str(x) for x in s["measured_winner_mesh"])
        pcm = "×".join(str(x) for x in pc["mesh"])
        out.append(
            f"| {s['workload']} | {ws['m']} | {ws['n_trees']} | {ws['d_mu']:.2f} "
            f"| {pcm} ({pc['decomposition']}) | {pw} | {mw} "
            f"| {'yes' if s['crossover_agreement'] else 'NO'} |"
        )
    return "\n".join(out)


def render_cascade(data: dict) -> str:
    """BENCH_cascade.json → early-exit cascade accuracy/latency report."""
    out = ["## Early-exit cascade sweep (`BENCH_cascade.json`)", ""]
    out.extend(_env_note(data))
    out.append(f"Backend `{data.get('backend', '?')}`, jax {data.get('jax', '?')}: "
               f"{data.get('n_trees', '?')}-tree bagged CART forest, "
               f"{data.get('n_classes', '?')} classes, M={data.get('m', '?')} per mix.  "
               "`bound=1.0` is the provable setting (early exits cannot be "
               "flipped by the unseen trees, so its accuracy delta is exactly "
               "0); relaxed bounds trade accuracy for latency; `bound=None` "
               "runs every stage (staging overhead floor).")
    out.append("")
    out.append("| mix | variant | stages | bound | median ms | Δaccuracy "
               "| mean trees | vs fused | vs vmap |")
    out.append("|" + "---|" * 9)
    for e in data.get("entries", []):
        bound = e.get("bound")
        out.append(
            f"| {e['mix']} | {e['variant']} | {e['stages']} "
            f"| {'—' if bound is None else bound} "
            f"| {_ms(e['median_ms'])} | {e['accuracy_delta']:.4f} "
            f"| {e['mean_trees_evaluated']:.2f} "
            f"| {'x{:.2f}'.format(e['speedup_vs_fused']) if 'speedup_vs_fused' in e else '—'} "
            f"| {'x{:.2f}'.format(e['speedup_vs_vmap']) if 'speedup_vs_vmap' in e else '—'} |"
        )
    s = data.get("summary", {})
    if s:
        out.append("")
        out.append(
            f"Skewed-mix provable cascade (bound=1.0, {s.get('skewed_provable_stages', '?')} "
            f"stages): **x{s.get('skewed_provable_speedup_vs_fused', 0):.2f}** vs the fused "
            f"stacked kernel (acceptance ≥1.5: "
            f"{'met' if s.get('meets_1p5x_vs_fused') else 'NOT MET'}), "
            f"x{s.get('skewed_provable_speedup_vs_vmap', 0):.2f} vs vmap, accuracy delta "
            f"{s.get('skewed_provable_accuracy_delta', 0):.4f} (budget ≤0.005: "
            f"{'met' if s.get('meets_accuracy_budget') else 'NOT MET'})."
        )
    return "\n".join(out)


def render_obs(data: dict) -> str:
    """BENCH_obs.json → observability overhead report (disabled vs enabled)."""
    out = ["## Observability overhead (`BENCH_obs.json`)", ""]
    out.extend(_env_note(data))
    out.append("The serve path (`ForestServeEngine`, streaming chunker + sharded "
               "executor) timed with obs disabled (`Registry(enabled=False)` + "
               "null tracer), metrics only, and metrics + span tracing.  "
               "Acceptance: metrics-enabled within 2% of disabled.")
    out.append("")
    out.append("| mode | median ms | MAD ms | mean ms | min ms | max ms |")
    out.append("|" + "---|" * 6)
    for e in data.get("entries", []):
        mad = e.get("mad_ms")
        out.append(
            f"| {e['name']} | {_ms(e['median_ms'])} "
            f"| {_ms(mad) if isinstance(mad, (int, float)) else '—'} "
            f"| {_ms(e['mean_ms'])} "
            f"| {_ms(e['min_ms'])} | {_ms(e['max_ms'])} |"
        )
    s = data.get("summary", {})
    if s:
        out.append("")
        profiled = (
            f", default-sampling profiler "
            f"{s.get('profiled_overhead_pct', 0):+.2f}%"
            if "profiled_overhead_pct" in s else ""
        )
        out.append(
            f"Metrics overhead **{s.get('metrics_overhead_pct', 0):+.2f}%**, "
            f"full tracing {s.get('full_overhead_pct', 0):+.2f}%{profiled} "
            f"vs disabled (target ≤{s.get('target_pct', 2.0):.0f}%: "
            f"{'met' if s.get('metrics_within_target') else 'NOT MET'}).  "
            "Negative overheads are run-to-run variance — the instrumented "
            "path measured no slower than the disabled one."
        )
    return "\n".join(out)


def render_profile(data: dict) -> str:
    """BENCH_profile.json → traversal-profiler report (cost + measured d_µ)."""
    out = ["## Traversal profiler sweep (`BENCH_profile.json`)", ""]
    out.extend(_env_note(data))
    s = data.get("summary", {})
    out.append(
        f"The paper workload served through `TreeServeEngine` "
        f"(N={s.get('n_nodes', '?')}, depth {s.get('depth', '?')}) with the "
        "shadow profiler off (`plain`), at its default 1-in-64 async "
        "sampling (`profiled_default`), and profiling every wave inline "
        "(`profiled_sync` — the worst-case upper bound, not a production "
        "setting)."
    )
    out.append("")
    out.append("| mode | median ms | MAD ms | mean ms | min ms | max ms |")
    out.append("|" + "---|" * 6)
    for e in data.get("entries", []):
        mad = e.get("mad_ms")
        out.append(
            f"| {e['name']} | {_ms(e['median_ms'])} "
            f"| {_ms(mad) if isinstance(mad, (int, float)) else '—'} "
            f"| {_ms(e['mean_ms'])} "
            f"| {_ms(e['min_ms'])} | {_ms(e['max_ms'])} |"
        )
    if s:
        out.append("")
        out.append(
            f"Default-sampling overhead **{s.get('default_overhead_pct', 0):+.2f}%**, "
            f"every-wave inline {s.get('sync_overhead_pct', 0):+.2f}% vs plain."
        )
        buckets = s.get("buckets") or []
        if buckets:
            out.append("")
            out.append(
                "Per-bucket mean traversal depth three ways — geometry prior, "
                "blocking host descent, shadow-measured — with the §3.6 "
                "speculation-waste ratio N/d_µ each would feed "
                "`predicted_times`:"
            )
            out.append("")
            out.append("| bucket | shadow passes | d_µ prior | d_µ sampled "
                       "| d_µ measured | waste prior | waste measured |")
            out.append("|" + "---|" * 7)
            for b in buckets:
                # bucket keys carry literal | separators; escape them or
                # they split the markdown table cells
                key = str(b["bucket"]).replace("|", "\\|")
                out.append(
                    f"| `{key}` | {b['samples']} "
                    f"| {b['d_mu_prior']:.2f} | {b['d_mu_sampled']:.2f} "
                    f"| {b['d_mu_measured']:.2f} | {b['waste_prior']:.2f} "
                    f"| {b['waste_measured']:.2f} |"
                )
    return "\n".join(out)


def render_layout(data: dict) -> str:
    """BENCH_layout.json → quantized node-table layout report."""
    out = ["## Quantized node-table layouts (`BENCH_layout.json`)", ""]
    out.extend(_env_note(data))
    out.append("The f32 fused tables (`PackedForest` — attr-select matrix + "
               "full-width node columns) vs the compact `QuantizedForest` "
               "SoA layouts (int8/int16 indices, bf16/f16 thresholds where "
               "the cast is exact, bit-packed leaf flags).  Every quantized "
               "run is asserted class-exact against the serial reference; "
               "latency ratios are paired per-round medians (interleaved "
               "sampling), so host drift divides out.")
    out.append("")
    out.append("| workload | T | M | layout | table bytes | B/node | reduction "
               "| median ms | vs f32 fused | not worse | thr stored |")
    out.append("|" + "---|" * 11)
    for e in data.get("entries", []):
        first = e["variant"] == "f32_fused"
        head = (f"| {e['workload']} | {e['t']} | {e['m']} " if first
                else "| | | ")
        out.append(
            head
            + f"| {e['variant']} | {e['table_bytes']} "
            f"| {e['bytes_per_node']} | {e['reduction_vs_f32']}x "
            f"| {_ms(e['median_ms'])} | {e['ratio_vs_f32_fused']:.3f} "
            f"| {'yes' if e['not_worse_than_f32'] else 'NO'} "
            f"| {e['thr_stored']} |"
        )
    s = data.get("summary", {})
    if s:
        out.append("")
        out.append(
            f"Wide-forest best reduction **x{s.get('wide_forest_best_reduction', 0):.1f}** "
            f"(acceptance ≥4×: {'met' if s.get('meets_4x_reduction') else 'NOT MET'}); "
            f"quantized latency within the ±{(s.get('noise_band', 1.05) - 1) * 100:.0f}% "
            f"band of f32 fused on at least one workload: "
            f"{'yes' if s.get('quant_not_worse_somewhere') else 'NO'}."
        )
    ss_rows = [e for e in data.get("entries", [])
               if "split_safe_table_bytes" in e]
    if ss_rows:
        out.append("")
        out.append("Split-safe calibrated rounding (batch as calibration set — "
                   "nodes whose routing interval admits a narrow threshold "
                   "store it narrow, the rest keep exact f32):")
        out.append("")
        out.append("| workload | layout | table bytes | thr stored | fallback nodes |")
        out.append("|" + "---|" * 5)
        for e in ss_rows:
            out.append(
                f"| {e['workload']} | {e['variant']} | {e['split_safe_table_bytes']} "
                f"| {e['split_safe_thr_stored']} | {e['split_safe_fallback_nodes']} |"
            )
    return "\n".join(out)


def render_trajectory(history_dir: Path) -> str:
    """results/history/*.jsonl → per-workload trajectory deltas.

    For every series: run count, baseline (median of the last 5
    same-environment prior runs — the same pool
    ``results/check_regressions.py`` gates on), latest median, and Δ%.
    Series whose latest run has no comparable predecessor (seed-only
    trajectories, env changes) show an em-dash delta.
    """
    import statistics

    out = ["## Bench trajectory (`results/history/*.jsonl`)", ""]
    out.append("Every bench run appends its medians here "
               "(`benchmarks/common.py`); the regression gate "
               "(`results/check_regressions.py`, CI `perf-gate`) compares "
               "the latest run against the median of the last 5 "
               "same-environment runs.  Δ% is latest vs that baseline — "
               "positive = slower.")
    out.append("")
    found = False
    for path in sorted(history_dir.glob("*.jsonl")):
        records = load_history(path)
        if not records:
            continue
        found = True
        latest = records[-1]
        pool = baseline_pool(records, window=5)
        out.append(f"### `{path.stem}` — {len(records)} run(s)")
        out.append("")
        out.append("| series | runs | baseline ms | latest ms | Δ% |")
        out.append("|" + "---|" * 5)
        for name, s in sorted((latest.get("series") or {}).items()):
            base_vals = [float(r["series"][name]["median_ms"]) for r in pool
                         if name in (r.get("series") or {})]
            n_runs = 1 + sum(1 for r in records[:-1]
                             if name in (r.get("series") or {}))
            latest_ms = float(s["median_ms"])
            if base_vals:
                base = statistics.median(base_vals)
                delta = f"{(latest_ms - base) / base * 100.0:+.1f}%" if base else "—"
                base_s = _ms(base)
            else:
                base_s, delta = "—", "—"
            out.append(f"| {name} | {n_runs} | {base_s} | {_ms(latest_ms)} | {delta} |")
        out.append("")
    if not found:
        out.append("*(no trajectories yet — run any bench to start one)*")
        out.append("")
    return "\n".join(out).rstrip()


_RENDERERS = {
    "BENCH_tree_eval.json": render_tree_eval,
    "BENCH_cascade.json": render_cascade,
    "BENCH_dist.json": render_dist,
    "BENCH_obs.json": render_obs,
    "BENCH_profile.json": render_profile,
    "BENCH_layout.json": render_layout,
}


def render_benchmarks(results_dir: Path = RESULTS_DIR) -> str:
    """The full docs/benchmarks.md body from every known BENCH_*.json.

    Deterministic: depends only on the JSON contents (no timestamps), so
    the CI docs job can regenerate and ``diff`` against the committed file.
    """
    out = [
        "# Benchmark report",
        "",
        "*Generated from `results/BENCH_*.json` by `results/make_table.py` — do "
        "not edit by hand.  Regenerate with:*",
        "",
        "```sh",
        "python results/make_table.py benchmarks -o docs/benchmarks.md",
        "```",
        "",
        "*The JSONs themselves are produced by the benches "
        "(`PYTHONPATH=src python -m benchmarks.run tune cascade dist_sweep`); "
        "see `docs/tuning.md` for how to read them.*",
        "",
    ]
    found = False
    for name, renderer in _RENDERERS.items():
        path = results_dir / name
        if not path.exists():
            continue
        found = True
        out.append(renderer(json.loads(path.read_text())))
        out.append("")
    if not found:
        out.append("*(no results/BENCH_*.json files found)*")
        out.append("")
    history = results_dir / "history"
    if history.is_dir():
        out.append(render_trajectory(history))
        out.append("")
    return "\n".join(out).rstrip() + "\n"


# ---------------------------------------------------------------------------
# markdown link checker
# ---------------------------------------------------------------------------

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links(paths: list[str]) -> list[str]:
    """Return a list of broken relative links across the given markdown files.

    External (``http(s)://``), mail and pure-anchor links are skipped; a
    relative link is resolved against the linking file's directory and must
    name an existing file or directory (any ``#fragment`` is ignored).
    """
    errors = []
    for p in paths:
        path = Path(p)
        text = path.read_text()
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.exists():
                errors.append(f"{p}: broken link -> {target}")
    return errors


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__,
                                     formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_roof = sub.add_parser("roofline", help="render the roofline table from a dryrun JSON")
    p_roof.add_argument("path")
    p_roof.add_argument("mesh", nargs="?", default=None)

    p_bench = sub.add_parser("benchmarks", help="render docs/benchmarks.md from BENCH_*.json")
    p_bench.add_argument("-o", "--output", default=None,
                         help="write here instead of stdout")
    p_bench.add_argument("--results-dir", default=str(RESULTS_DIR))

    p_links = sub.add_parser("check-links", help="verify relative markdown links resolve")
    p_links.add_argument("files", nargs="+")

    args = parser.parse_args(argv)
    if args.cmd == "roofline":
        print(render_roofline(args.path, args.mesh))
        return 0
    if args.cmd == "benchmarks":
        body = render_benchmarks(Path(args.results_dir))
        if args.output:
            Path(args.output).write_text(body)
            print(f"wrote {args.output}")
        else:
            sys.stdout.write(body)
        return 0
    if args.cmd == "check-links":
        errors = check_links(args.files)
        for e in errors:
            print(e, file=sys.stderr)
        print(f"{len(errors)} broken link(s) in {len(args.files)} file(s)")
        return 1 if errors else 0
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
