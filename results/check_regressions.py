#!/usr/bin/env python3
"""CI perf gate: flag noise-aware regressions in results/history/*.jsonl.

Reads every bench trajectory appended by ``benchmarks/common.py`` (one JSONL
line per run: env header + per-workload medians/MAD) and runs
:func:`repro.obs.perf.detect_regressions` — latest median vs the median of
the last K same-environment runs, flagged when it exceeds the baseline by
``max(rel_threshold · baseline, k_mad · MAD)``.  Runs from a different
backend / device / jax version never compare.

Exit status: 0 = clean, 1 = regression found.  ``--strict`` additionally
fails on structural problems — no history at all, an empty/corrupt
trajectory — so the CI ``perf-gate`` job can't silently pass by having
nothing to check.

Stdlib-only (imports ``repro.obs.perf`` off ``src/`` directly, no jax).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.perf import detect_regressions, load_history, skipped_series  # noqa: E402


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--history-dir",
                   default=str(Path(__file__).resolve().parent / "history"),
                   help="directory of <bench>.jsonl trajectories")
    p.add_argument("--bench", action="append", default=None,
                   help="restrict to these bench names (repeatable)")
    p.add_argument("--window", type=int, default=5,
                   help="baseline = median of the last K same-env runs")
    p.add_argument("--rel-threshold", type=float, default=0.5,
                   help="relative slack floor (0.5 = flag only >1.5x baseline)")
    p.add_argument("--k-mad", type=float, default=5.0,
                   help="noise slack: k x MAD of the baseline pool")
    p.add_argument("--min-runs", type=int, default=2,
                   help="series with fewer same-env baseline runs are "
                        "reported as skipped, not silently passed")
    p.add_argument("--strict", action="store_true",
                   help="also fail on missing/empty/corrupt history")
    p.add_argument("--json", action="store_true",
                   help="machine-readable report on stdout")
    args = p.parse_args(argv)

    history_dir = Path(args.history_dir)
    wanted = set(args.bench) if args.bench else None
    problems: list[str] = []
    regressions = []
    skipped: list[dict] = []
    checked = 0

    paths = sorted(history_dir.glob("*.jsonl")) if history_dir.is_dir() else []
    if wanted is not None:
        paths = [p_ for p_ in paths if p_.stem in wanted]
        missing = wanted - {p_.stem for p_ in paths}
        if missing:
            problems.append(f"no history for bench(es): {', '.join(sorted(missing))}")
    if not paths:
        problems.append(f"no trajectories under {history_dir}")

    for path in paths:
        try:
            records = load_history(path)
        except ValueError as e:
            problems.append(str(e))
            continue
        if not records:
            problems.append(f"{path}: empty trajectory")
            continue
        checked += 1
        regressions.extend(detect_regressions(
            records, bench=path.stem, window=args.window,
            rel_threshold=args.rel_threshold, k_mad=args.k_mad,
        ))
        skipped.extend(
            {"bench": path.stem, "series": name, "n_baseline": n}
            for name, n in skipped_series(
                records, window=args.window, min_runs=args.min_runs)
        )

    if args.json:
        print(json.dumps({
            "checked": checked,
            "regressions": [vars(r) | {"ratio": r.ratio} for r in regressions],
            "skipped": skipped,
            "problems": problems,
        }, indent=1, sort_keys=True))
    else:
        for r in regressions:
            print(f"REGRESSION  {r.describe()}")
        for s in skipped:
            print(f"SKIPPED  {s['bench']}/{s['series']}: insufficient history "
                  f"({s['n_baseline']} same-env run(s), need {args.min_runs})")
        for msg in problems:
            print(f"{'PROBLEM' if args.strict else 'WARNING'}  {msg}")
        print(f"checked {checked} trajectorie(s): "
              f"{len(regressions)} regression(s)"
              + (f", {len(skipped)} skipped" if skipped else "")
              + (f", {len(problems)} problem(s)" if problems else ""))

    if regressions:
        return 1
    if args.strict and problems:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
