"""Traversal profiler sweep: shadow-pass cost + prior-vs-measured d_µ.

The §3.6 cost model prices speculative evaluation with the *mean traversal
depth* d_µ — N/d_µ is the fraction of speculated node evaluations wasted on
records that already exited.  Until now dispatch estimated d_µ from tree
geometry (a balanced-tree prior) or a blocking host descent; the
:class:`repro.obs.TraversalProfiler` measures it from sampled shadow passes
off the request path.  This bench prices that machinery and quantifies what
the measurement buys:

* serve-pass timings for the paper workload under three policies —
  ``plain`` (profiling off), ``profiled_default`` (the shipped 1-in-64
  async sampling), ``profiled_sync`` (every wave, inline: the worst case,
  an upper bound no production policy pays);
* per-bucket d_µ three ways — geometry prior, host-sampled descent,
  profiler-measured — with the speculation-waste ratio N/d_µ each carries
  into ``predicted_times``.

Emits results/BENCH_profile.json (+ a ``profile`` history trajectory line).

    PYTHONPATH=src python -m benchmarks.profile_sweep
"""

from __future__ import annotations

WAVE_RECORDS = 2048
REQUESTS = 4


def main(iters: int = 20, warmup: int = 3) -> dict:
    import numpy as np

    from benchmarks.common import paper_workload, time_fn, write_bench_json
    from repro import obs
    from repro.core.analysis import (
        mean_traversal_depth,
        observed_depths,
        speculation_waste_ratio,
    )
    from repro.serve import TreeRequest, TreeServeEngine
    from repro.tune.heuristic import default_d_mu
    from repro.tune.space import WorkloadShape

    wl = paper_workload(n_records=WAVE_RECORDS * REQUESTS)
    rec = wl.records[: WAVE_RECORDS * REQUESTS].astype(np.float32)
    waves = [rec[i * WAVE_RECORDS:(i + 1) * WAVE_RECORDS] for i in range(REQUESTS)]
    print(f"tree: N={wl.enc.n_nodes} depth={wl.depth}; "
          f"{REQUESTS} requests x {WAVE_RECORDS} records per pass")

    policies = {
        "plain": None,
        "profiled_default": obs.ProfilePolicy(),
        "profiled_sync": obs.ProfilePolicy(sample_every=1, synchronous=True),
    }
    entries: list[dict] = []
    medians: dict[str, float] = {}
    sync_eng = None
    for mode, policy in policies.items():
        eng = TreeServeEngine(wl.enc, max_batch=WAVE_RECORDS, retune=None,
                              profile=policy)

        def serve_pass():
            reqs = [TreeRequest(uid=i, records=w) for i, w in enumerate(waves)]
            eng.run(reqs)

        # prime: the first sampled wave jit-compiles the shadow descent on
        # the worker thread; drain so the compile never bleeds into timing
        serve_pass()
        if eng.profiler is not None:
            eng.profiler.drain()
        t = time_fn(mode, serve_pass, iters=iters, warmup=warmup,
                    mode=mode, requests=REQUESTS, wave_records=WAVE_RECORDS)
        if eng.profiler is not None:
            eng.profiler.drain()  # shadow passes out of the next mode's timing
        medians[mode] = t.median_us / 1e3
        print(f"  {mode:18s} median {t.median_us / 1e3:9.3f} ms "
              f"(MAD {t.mad_us / 1e3:7.3f} ms)")
        entries.append({
            "name": mode,
            "median_ms": t.median_us / 1e3,
            "mad_ms": t.mad_us / 1e3,
            "mean_ms": t.mean_us / 1e3,
            "min_ms": t.min_us / 1e3,
            "max_ms": t.max_us / 1e3,
            "iters": t.n,
        })
        if mode == "profiled_sync":
            sync_eng = eng

    base = medians["plain"]
    overhead = {m: (medians[m] - base) / base * 100.0
                for m in ("profiled_default", "profiled_sync")}
    for m, pct in overhead.items():
        print(f"  {m:18s} overhead {pct:+6.2f}% vs plain")

    # d_µ accounting per profiled bucket: what the heuristic would have
    # assumed (geometry prior), what a blocking host descent sees, and what
    # the shadow pass measured — plus the waste ratio N/d_µ each implies.
    n = int(wl.enc.n_nodes)
    shape = WorkloadShape.of(waves[0], wl.enc)
    prior = default_d_mu(shape)
    sampled = mean_traversal_depth(observed_depths(wl.enc, rec[:2048]))
    buckets = []
    for key in sorted(sync_eng.profiler.keys()):
        p = sync_eng.profiler.profile(key)
        buckets.append({
            "bucket": key,
            "samples": p.samples,
            "d_mu_prior": prior,
            "d_mu_sampled": float(sampled),
            "d_mu_measured": p.d_mu,
            "waste_prior": speculation_waste_ratio(n, prior),
            "waste_sampled": speculation_waste_ratio(n, sampled),
            "waste_measured": p.waste_ratio,
            "level_active": [round(float(x), 4) for x in p.level_active],
        })
        print(f"  {key}: d_mu prior {prior:.2f} / sampled {sampled:.2f} / "
              f"measured {p.d_mu:.2f}; waste N/d_mu "
              f"{speculation_waste_ratio(n, prior):.2f} -> {p.waste_ratio:.2f}")

    summary = {
        "n_nodes": n,
        "depth": int(wl.depth),
        "default_overhead_pct": overhead["profiled_default"],
        "sync_overhead_pct": overhead["profiled_sync"],
        "buckets": buckets,
    }
    path = write_bench_json("profile", entries, summary=summary)
    print(f"wrote {path}")
    return summary


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description="traversal profiler sweep")
    p.add_argument("--iters", type=int, default=20)
    p.add_argument("--warmup", type=int, default=3)
    args = p.parse_args()
    main(iters=args.iters, warmup=args.warmup)
