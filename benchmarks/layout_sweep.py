"""Node-table layout sweep: f32 `PackedForest` vs quantized SoA layouts.

For the forest operating points the tune sweep uses, this bench compares
the full-width f32 fused tables (``PackedForest`` — attr-select matrix +
f32 node columns) against the compact :class:`QuantizedForest` layouts
(int8/int16 indices, bf16/f16 thresholds, bit-packed leaf flags):

  1. node-table bytes per layout and the reduction ratio vs f32;
  2. fused-kernel latency per layout, sampled interleaved so host drift
     can't masquerade as a layout effect (paired per-round ratios);
  3. class-exactness of every quantized evaluation against the serial
     reference (asserted, not tolerated);
  4. the split-safe calibrated rounding stats (how many thresholds fit the
     narrow dtype when a calibration set pins each node's routing interval).

Every (workload, layout) pair lands as one flat entry carrying
``median_ms``, so the perf history (`results/history/layout.jsonl`) tracks
each layout as its own series and the CI perf gate watches them all.

Acceptance (ISSUE 10): ≥4× byte reduction on the wide-forest workload and
quantized latency no worse than the f32 fused kernel on at least one
standard workload.

    PYTHONPATH=src python -m benchmarks.layout_sweep
"""

from __future__ import annotations

import zlib

import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json
from repro.core import breadth_first_encode, random_tree
from repro.core.forest import EncodedForest
from repro.kernels.tree_eval.ops import PackedForest, forest_eval_fused, forest_eval_fused_q
from repro.kernels.tree_eval.quant import THR_DTYPES, QuantizedForest
from repro.kernels.tree_eval.ref import forest_eval_ref
from repro.tune.measure import interleaved_samples

# Same forest operating points as benchmarks/tune_sweep.py (same seeds, so
# the latency columns are comparable across the two reports).  The wide
# forest is the acceptance workload: many shallow trees maximise the
# node-table share of the working set.
WORKLOADS = [
    # name, tree depths, M, A
    ("forest_uniform_t8_d6", [6] * 8, 4096, 19),
    ("forest_mixed_t8_d2-9", [2 + (i % 8) for i in range(8)], 4096, 19),
    ("forest_wide_t32_d4", [4] * 32, 1024, 19),
]

# The ISSUE acceptance pins these two workload roles.
WIDE_WORKLOAD = "forest_wide_t32_d4"
NOISE_BAND = 1.05   # paired-ratio band for "no worse than f32 fused"


def _build(name, depths, m, n_attrs):
    trees = [
        breadth_first_encode(
            random_tree(n_attrs=n_attrs, n_classes=7, max_depth=d, min_depth=d,
                        seed=100 + i, balance=1.0)
        )
        for i, d in enumerate(depths)
    ]
    forest = EncodedForest(trees)
    rec = jnp.asarray(
        np.random.default_rng(zlib.crc32(name.encode())).normal(size=(m, n_attrs)),
        jnp.float32,
    )
    return forest, rec


def _reference(rec, forest):
    return np.asarray(forest_eval_ref(
        rec,
        jnp.asarray(forest.attr_idx, jnp.int32),
        jnp.asarray(forest.threshold, jnp.float32),
        jnp.asarray(forest.child, jnp.int32),
        jnp.asarray(forest.class_val, jnp.int32),
        max_depth=max(int(forest.max_depth), 1),
    ))


def sweep_one(name, depths, m, n_attrs, *, iters, warmup) -> list[dict]:
    """One workload → flat per-layout entries (f32 baseline row first)."""
    forest, rec = _build(name, depths, m, n_attrs)
    ref = _reference(rec, forest)

    packed = PackedForest(forest, n_attrs)
    f32_bytes = int(packed.nbytes)
    n_pad_nodes = forest.n_trees * int(packed.attr_idx.shape[-1])

    quants: dict[str, QuantizedForest] = {
        td: QuantizedForest(forest, n_attrs, thr_dtype=td) for td in THR_DTYPES
    }
    # Every quantized layout must be class-exact — no tolerance.  Universal
    # mode guarantees it; this assert is the bench's conformance tripwire.
    for td, qf in quants.items():
        got = np.asarray(forest_eval_fused_q(rec, qf))
        if not np.array_equal(got, ref):
            raise AssertionError(f"{name}: quantized layout {td} diverged from ref")

    fns = {"f32_fused": lambda: forest_eval_fused(
        rec, packed, algorithm="speculative", jump_mode="gather")}
    for td, qf in quants.items():
        fns[f"quant_{td}"] = (lambda q: lambda: forest_eval_fused_q(rec, q))(qf)
    samples = interleaved_samples(fns, warmup=warmup, iters=iters)
    f32 = np.asarray(samples["f32_fused"])
    f32_ms = float(np.median(f32))

    base = {
        "workload": name,
        "t": forest.n_trees,
        "m": int(rec.shape[0]),
        "n_attrs": n_attrs,
        "n_nodes": int(forest.n_nodes),
    }
    rows = [{
        **base,
        "variant": "f32_fused",
        "median_ms": round(f32_ms, 6),
        "mad_ms": round(float(np.median(np.abs(f32 - f32_ms))), 6),
        "table_bytes": f32_bytes,
        "bytes_per_node": round(f32_bytes / n_pad_nodes, 3),
        "reduction_vs_f32": 1.0,
        "ratio_vs_f32_fused": 1.0,
        "not_worse_than_f32": True,
        "thr_stored": "float32",
        "fallback_nodes": 0,
        "exact": True,
    }]
    for td, qf in quants.items():
        q = np.asarray(samples[f"quant_{td}"])
        q_ms = float(np.median(q))
        ratio = float(np.median(q / f32))
        rep = qf.bytes_report()
        # Split-safe calibrated rounding on the same dtype: with the batch
        # as calibration set, every node whose routing interval admits a
        # narrow threshold stores it narrow; the rest keep exact f32.
        qs = QuantizedForest(forest, n_attrs, thr_dtype=td,
                             calibration=np.asarray(rec))
        if not np.array_equal(np.asarray(forest_eval_fused_q(rec, qs)), ref):
            raise AssertionError(f"{name}: split-safe {td} broke calibration routing")
        srep = qs.bytes_report()
        rows.append({
            **base,
            "variant": f"quant_{td}",
            "median_ms": round(q_ms, 6),
            "mad_ms": round(float(np.median(np.abs(q - q_ms))), 6),
            "table_bytes": int(qf.nbytes),
            "bytes_per_node": round(rep["bytes_per_node"], 3),
            "reduction_vs_f32": round(f32_bytes / qf.nbytes, 2),
            "ratio_vs_f32_fused": round(ratio, 4),
            "not_worse_than_f32": bool(ratio <= NOISE_BAND),
            "thr_stored": rep["thr_stored"],
            "fallback_nodes": rep["fallback_nodes"],
            "exact": True,
            "split_safe_table_bytes": int(qs.nbytes),
            "split_safe_thr_stored": srep["thr_stored"],
            "split_safe_fallback_nodes": srep["fallback_nodes"],
        })
        print(f"  [{name}] quant_{td}: {qf.nbytes} B ({f32_bytes / qf.nbytes:.1f}x "
              f"smaller), {q_ms:.3f} ms vs f32 {f32_ms:.3f} ms "
              f"(paired ratio {ratio:.3f}), thresholds stored {rep['thr_stored']}, "
              f"split-safe fallbacks {srep['fallback_nodes']}")
    return rows


def main(iters: int = 15, warmup: int = 2) -> dict:
    entries: list[dict] = []
    for name, depths, m, a in WORKLOADS:
        entries.extend(sweep_one(name, depths, m, a, iters=iters, warmup=warmup))

    wide_q = [e for e in entries
              if e["workload"] == WIDE_WORKLOAD and e["variant"] != "f32_fused"]
    best_wide_reduction = max(e["reduction_vs_f32"] for e in wide_q)
    not_worse_somewhere = any(
        e["not_worse_than_f32"] for e in entries if e["variant"] != "f32_fused"
    )
    summary = {
        "wide_workload": WIDE_WORKLOAD,
        "wide_forest_best_reduction": best_wide_reduction,
        "meets_4x_reduction": bool(best_wide_reduction >= 4.0),
        "quant_not_worse_somewhere": bool(not_worse_somewhere),
        "noise_band": NOISE_BAND,
        "all_exact": True,   # the asserts above would have raised otherwise
    }
    path = write_bench_json("layout", entries, summary=summary)
    print(f"\nwide-forest best reduction x{best_wide_reduction:.1f} "
          f"(acceptance >=4x: {'met' if summary['meets_4x_reduction'] else 'NOT MET'}); "
          f"quant not worse than f32 fused somewhere: "
          f"{'yes' if not_worse_somewhere else 'NO'}")
    print(f"wrote {path}")
    return {"entries": entries, "summary": summary, "path": str(path)}


if __name__ == "__main__":
    main()
