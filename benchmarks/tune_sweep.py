"""Autotuner sweep: populate the tune cache, then score tuned dispatch.

For a set of distinct workload shapes (record count × tree geometry ×
attribute width) this bench:

  1. runs :func:`repro.tune.tune_workload` — timing every registered kernel
     variant (the fixed strategies a caller could have hardcoded) and
     persisting the per-bucket winner into the tune cache;
  2. times ``TunedEvaluator`` dispatch end-to-end against the warm cache;
  3. repeats both steps at the *forest* level: every candidate family
     (per-tree variant vector, shared-variant vmap, fused stacked kernel)
     is measured by :func:`repro.tune.tune_forest_workload`, then
     forest-level tuned dispatch is raced against the per-tree path;
  4. emits ``results/BENCH_tree_eval.json`` comparing tuned dispatch with
     every fixed variant (tree ``entries`` + ``forest_entries``), flagging
     whether tuned is within noise of the best.

    PYTHONPATH=src python -m benchmarks.tune_sweep
"""

from __future__ import annotations

import dataclasses
import zlib

import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json
from repro.core import breadth_first_encode, paper_tree, perfect_tree, random_tree, tree_depth
from repro.core.forest import EncodedForest
from repro.kernels.tree_eval.ops import PER_TREE_FAMILY, get_variant
from repro.tune import (
    ForestShape,
    ForestTunedEvaluator,
    TuneCache,
    TunedEvaluator,
    WorkloadShape,
    tune_forest_workload,
    tune_workload,
)
from repro.tune.measure import interleaved_samples, roofline_fraction


def _winner_cost(measurements, achieved_ms: float) -> dict:
    """flops / bytes / roofline_frac of the sweep winner's compiled HLO.

    The static cost comes from the winning measurement; the roofline
    fraction is recomputed against the *dispatch* median actually reported
    (``achieved_ms``), so the column grades what the bench publishes.
    """
    ok = [m for m in measurements if not m.failed]
    best = min(ok, key=lambda m: m.median_ms) if ok else None
    cost = (best.cost if best is not None else None) or {}
    flops, bytes_ = cost.get("flops"), cost.get("bytes")
    frac = (roofline_fraction(flops, bytes_, achieved_ms)
            if flops is not None else None)
    return {"flops": flops, "bytes": bytes_,
            "roofline_frac": round(frac, 6) if frac is not None else None}

# Distinct operating points (paper §5–§6: the winner depends on where you sit).
WORKLOADS = [
    # name, tree builder, M, A
    ("paper_d11_n31", lambda: paper_tree(), 16384, 19),
    ("deep_perfect_d8_n511", lambda: perfect_tree(8, 19, 7, seed=1), 2048, 19),
    ("wide_shallow_d4_a130", lambda: random_tree(
        n_attrs=130, n_classes=7, max_depth=4, min_depth=4, seed=2, balance=1.0), 8192, 130),
]

# Forest operating points: homogeneous (stacked families should win — zero
# depth-padding waste) vs heterogeneous (the per-tree family's territory).
FOREST_WORKLOADS = [
    # name, tree depths, M, A
    ("forest_uniform_t8_d6", [6] * 8, 4096, 19),
    ("forest_mixed_t8_d2-9", [2 + (i % 8) for i in range(8)], 4096, 19),
    ("forest_wide_t32_d4", [4] * 32, 1024, 19),
]


def sweep_one(name, build_tree, m, n_attrs, *, cache, iters, warmup):
    enc = breadth_first_encode(build_tree())
    rec = jnp.asarray(
        np.random.default_rng(zlib.crc32(name.encode())).normal(size=(m, n_attrs)),
        jnp.float32,
    )
    shape = WorkloadShape.of(rec, enc)
    print(f"\n[{name}] shape={shape} bucket={shape.bucket()}")

    entry, measurements = tune_workload(
        rec, enc, cache=cache, iters=iters, warmup=warmup, verbose=True
    )

    # Best median per variant (min over its parameter grid) = the fixed
    # strategies tuned dispatch competes against.
    fixed: dict[str, float] = {}
    for meas in measurements:
        if meas.failed:
            continue
        v = meas.candidate.variant
        fixed[v] = min(fixed.get(v, float("inf")), meas.median_ms)
    best_fixed_ms = min(fixed.values())

    # Tuned dispatch end-to-end against the warm cache (resolution memo +
    # bucket padding included — what a serving call actually pays), sampled
    # interleaved with the winning fixed variant so host-load drift can't
    # masquerade as dispatch overhead.
    ev = TunedEvaluator(enc, cache=cache)
    spec = get_variant(entry.variant)
    depth = max(tree_depth(enc), 1)
    samples = interleaved_samples(
        {
            "fixed": lambda: spec.fn(rec, enc, max_depth=depth, **entry.params),
            "tuned": lambda: ev(rec),
        },
        warmup=warmup,
        iters=max(iters, 15),
    )
    tuned_ms = float(np.median(samples["tuned"]))
    best_fixed_interleaved_ms = float(np.median(samples["fixed"]))
    # paired per-round ratio: both contenders ran adjacently inside each
    # round, so host-load drift divides out of the verdict
    ratio = float(np.median(np.asarray(samples["tuned"]) / np.asarray(samples["fixed"])))
    ok = ratio <= 1.25
    print(f"  tuned {tuned_ms:.3f} ms vs best fixed {best_fixed_interleaved_ms:.3f} ms, "
          f"paired ratio {ratio:.3f} "
          f"({entry.variant} {entry.params}) -> {'OK' if ok else 'REGRESSION'}")

    return {
        "workload": name,
        "shape": dataclasses.asdict(shape),
        "bucket": dataclasses.asdict(shape.bucket()),
        "depth": int(max(tree_depth(enc), 1)),
        "fixed_variants_ms": {k: round(v, 6) for k, v in sorted(fixed.items())},
        "best_fixed_ms": round(best_fixed_ms, 6),
        "best_fixed_interleaved_ms": round(best_fixed_interleaved_ms, 6),
        "best_variant": entry.variant,
        "best_params": entry.params,
        "tuned_ms": round(tuned_ms, 6),
        "tuned_mad_ms": round(
            float(np.median(np.abs(np.asarray(samples["tuned"]) - tuned_ms))), 6),
        "tuned_vs_best_fixed": round(ratio, 4),
        "tuned_within_noise_of_best": bool(ok),
        **_winner_cost(measurements, tuned_ms),
    }


def sweep_forest(name, depths, m, n_attrs, *, cache, iters, warmup):
    """Measure every forest candidate family, then race forest-level tuned
    dispatch against the per-tree path (the PR 3 baseline)."""
    trees = [
        breadth_first_encode(
            random_tree(n_attrs=n_attrs, n_classes=7, max_depth=d, min_depth=d,
                        seed=100 + i, balance=1.0)
        )
        for i, d in enumerate(depths)
    ]
    forest = EncodedForest(trees)
    rec = jnp.asarray(
        np.random.default_rng(zlib.crc32(name.encode())).normal(size=(m, n_attrs)),
        jnp.float32,
    )
    shape = ForestShape.of(rec, forest)
    print(f"\n[{name}] shape={shape} bucket={shape.bucket()}")

    # autotune_trees: the per_tree family is priced at its tuned best (the
    # PR 3 baseline), with the per-tree winners persisted so the raced
    # per-tree dispatcher below replays them
    entry, measurements = tune_forest_workload(
        rec, forest, cache=cache, iters=iters, warmup=warmup, verbose=True,
        autotune_trees=True,
    )

    # Best median per candidate family/variant (min over its parameter grid).
    family_best: dict[str, float] = {}
    for meas in measurements:
        if meas.failed:
            continue
        v = meas.candidate.variant
        family_best[v] = min(family_best.get(v, float("inf")), meas.median_ms)

    # Forest-level tuned dispatch (warm cache, whatever family won) raced
    # interleaved against the forced per-tree path — the question this
    # bench answers: what does promoting tuning to the forest level buy
    # over PR 3's tree-by-tree dispatch?
    ev_tuned = ForestTunedEvaluator(forest, cache=cache)
    ev_per_tree = ForestTunedEvaluator(forest, cache=cache, families=(PER_TREE_FAMILY,))
    samples = interleaved_samples(
        {
            "forest_tuned": lambda: ev_tuned(rec),
            "per_tree": lambda: ev_per_tree(rec),
        },
        warmup=warmup,
        iters=max(iters, 15),
    )
    tuned_ms = float(np.median(samples["forest_tuned"]))
    per_tree_ms = float(np.median(samples["per_tree"]))
    ratio = float(np.median(np.asarray(samples["forest_tuned"]) / np.asarray(samples["per_tree"])))
    cand, source = ev_tuned.resolve(rec)
    print(f"  forest tuned {tuned_ms:.3f} ms vs per-tree {per_tree_ms:.3f} ms, "
          f"paired ratio {ratio:.3f} (winner {entry.variant} {entry.params}, "
          f"dispatch source {source})")

    return {
        "workload": name,
        "shape": dataclasses.asdict(shape),
        "bucket": dataclasses.asdict(shape.bucket()),
        "candidate_best_ms": {k: round(v, 6) for k, v in sorted(family_best.items())},
        "best_variant": entry.variant,
        "best_params": entry.params,
        "forest_tuned_ms": round(tuned_ms, 6),
        "forest_tuned_mad_ms": round(
            float(np.median(np.abs(np.asarray(samples["forest_tuned"]) - tuned_ms))), 6),
        "per_tree_ms": round(per_tree_ms, 6),
        "forest_tuned_vs_per_tree": round(ratio, 4),
        "forest_tuned_not_worse": bool(ratio <= 1.25),
        **_winner_cost(measurements, tuned_ms),
    }


def main(iters: int = 7, warmup: int = 2, cache_path=None) -> dict:
    cache = TuneCache(cache_path)
    entries = [
        sweep_one(name, build, m, a, cache=cache, iters=iters, warmup=warmup)
        for name, build, m, a in WORKLOADS
    ]
    forest_entries = [
        sweep_forest(name, depths, m, a, cache=cache, iters=iters, warmup=warmup)
        for name, depths, m, a in FOREST_WORKLOADS
    ]
    path = write_bench_json(
        "tree_eval", entries, cache_path=str(cache.path), cache_entries=len(cache),
        forest_entries=forest_entries,
    )
    n_ok = sum(e["tuned_within_noise_of_best"] for e in entries)
    n_fok = sum(e["forest_tuned_not_worse"] for e in forest_entries)
    print(f"\ntuned within noise of best fixed on {n_ok}/{len(entries)} tree shapes; "
          f"forest tuned not worse than per-tree on {n_fok}/{len(forest_entries)} forests")
    print(f"wrote {path}")
    return {"entries": entries, "forest_entries": forest_entries, "path": str(path)}


if __name__ == "__main__":
    main()
