"""Paper Table 1: outer and inner times for the three evaluation algorithms.

Algorithms (paper §4.2.2):
  EvalTree            — Procedure 2, serial branchless on the host (numpy);
                        no inner time (no transfers needed).
  EvalTreeBySample    — Procedure 3, data decomposition (the Pallas
                        data-parallel kernel; jitted jnp fallback measured
                        too for the no-kernel path).
  EvalTreeByNode      — Procedure 5, improved speculative decomposition
                        (Pallas speculative kernel: MXU one-hot node eval +
                        pointer jumping, multi-jump=2, leaf paths static).

Inner = device-resident eval only; outer = + host↔device transfers.
The paper's headline: speculative beats data decomposition on kernel (inner)
time by ~25 % on SIMD hardware, while the host serial algorithm wins outer
time end-to-end on small trees — both effects are reproduced (see
EXPERIMENTS.md §Paper-claims for this container's CPU numbers).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Timing, header, paper_workload, time_fn
from repro.core import eval_serial
from repro.core.eval_dataparallel import eval_data_parallel
from repro.core.eval_speculative import eval_speculative
from repro.kernels.tree_eval import PackedTree, tree_eval


def run(iters: int = 30, n_records: int | None = None) -> list[Timing]:
    w = paper_workload(n_records=n_records)
    enc, rec = w.enc, w.records
    depth = max(w.depth, 1)
    out: list[Timing] = []

    # --- serial host (Procedure 2) ---
    small = rec[:2048]   # full 65k serial numpy would dominate the harness
    t = time_fn("EvalTree(host,2048rec)", lambda: eval_serial(enc, small), iters=5)
    scale = rec.shape[0] / small.shape[0]
    out.append(Timing("EvalTree(host,scaled)", t.mean_us * scale, t.min_us * scale,
                      t.max_us * scale, t.std_us * scale, t.n))

    # --- device-resident buffers for inner timings ---
    tree_args = (
        jnp.asarray(enc.attr_idx), jnp.asarray(enc.threshold),
        jnp.asarray(enc.child), jnp.asarray(enc.class_val),
    )
    rec_dev = jnp.asarray(rec)

    dp = jax.jit(lambda r: eval_data_parallel(r, *tree_args, max_depth=depth))
    sp = jax.jit(lambda r: eval_speculative(r, *tree_args, max_depth=depth,
                                            jumps_per_round=2, use_onehot_matmul=True))
    out.append(time_fn("EvalTreeBySample(inner)",
                       lambda: jax.block_until_ready(dp(rec_dev)), iters=iters))
    out.append(time_fn("EvalTreeByNode(inner)",
                       lambda: jax.block_until_ready(sp(rec_dev)), iters=iters))

    # --- outer: include host->device of records and device->host of classes ---
    def outer(fn):
        def call():
            r = jnp.asarray(rec)            # H2D
            np.asarray(fn(r))               # eval + D2H
        return call

    out.append(time_fn("EvalTreeBySample(outer)", outer(dp), iters=iters))
    out.append(time_fn("EvalTreeByNode(outer)", outer(sp), iters=iters))

    # --- Pallas kernels (interpret mode on CPU; the TPU-target artifacts) ---
    packed = PackedTree(enc, 19)
    ksp = lambda: jax.block_until_ready(
        tree_eval(rec_dev, packed, algorithm="speculative", jump_mode="gather"))
    kdp = lambda: jax.block_until_ready(
        tree_eval(rec_dev, packed, algorithm="data_parallel"))
    out.append(time_fn("PallasByNode(interpret)", ksp, iters=max(3, iters // 10)))
    out.append(time_fn("PallasBySample(interpret)", kdp, iters=max(3, iters // 10)))
    return out


def main(iters: int = 30, n_records: int | None = None):
    rows = run(iters=iters, n_records=n_records)
    print("Table 1 — outer and inner evaluation times (µs)")
    print(header())
    for t in rows:
        print(t.row())
    by = {t.name: t for t in rows}
    dp_i, sp_i = by["EvalTreeBySample(inner)"], by["EvalTreeByNode(inner)"]
    gain = (dp_i.mean_us - sp_i.mean_us) / dp_i.mean_us * 100
    print(f"\nspeculative inner-time gain vs data decomposition: {gain:+.1f}% "
          f"(paper reports +25% on CUDA)")
    return rows


if __name__ == "__main__":
    main()
