"""Beyond-paper benchmark: tree router vs softmax router in the MoE hot path.

The paper's workload transposed to the LM serving stack: per-token expert
classification.  Compares (a) learned softmax router (matmul + top-k), (b)
the hardened speculative tree router (Procedure 4/5: one one-hot MXU matmul
+ log2(depth) pointer jumps — no top-k sort on the serving path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import header, time_fn
from repro.configs.registry import get_smoke_config
from repro.models.api import build_model
from repro.models.layers import moe as moel


def run(iters: int = 15, tokens: int = 8192):
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])["moe"]
    e_pad = lp["wi"].shape[0]
    x = jax.random.normal(jax.random.key(1), (1, tokens, cfg.d_model), jnp.float32)

    hard = jax.jit(lambda x_: moel.hard_tree_route(lp, x_, cfg=cfg, e_pad=e_pad))
    soft = jax.jit(lambda x_: jax.lax.top_k(
        moel.router_probs(lp, x_, cfg=cfg, e_pad=e_pad), cfg.moe.top_k)[1])
    out = [
        time_fn("tree_router(speculative)", lambda: jax.block_until_ready(hard(x)), iters=iters),
        time_fn("soft_router(topk)", lambda: jax.block_until_ready(soft(x)), iters=iters),
    ]
    # full layer: serving MoE with hard routing vs soft
    layer_hard = jax.jit(lambda x_: moel.moe_apply(
        lp, x_, cfg=cfg, axes=model.axes, serve_hard_tree=True)[0])
    layer_soft = jax.jit(lambda x_: moel.moe_apply(
        lp, x_, cfg=cfg, axes=model.axes, serve_hard_tree=False)[0])
    out.append(time_fn("moe_layer(tree-served)",
                       lambda: jax.block_until_ready(layer_hard(x)), iters=iters))
    out.append(time_fn("moe_layer(soft-served)",
                       lambda: jax.block_until_ready(layer_soft(x)), iters=iters))
    return out


def main():
    rows = run()
    print("MoE routing hot path, 8192 tokens (µs)")
    print(header())
    for t in rows:
        print(t.row())
    return rows


if __name__ == "__main__":
    main()
