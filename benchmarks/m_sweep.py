"""Paper §4.3 m-amortization: records-per-group sweep.

The paper found m=1 ties the two decompositions and m=32 amortizes the
speculative kernel's static-table loads; here the analogue is the record
batch per kernel launch — tiny batches pay fixed dispatch overhead, large
batches amortize it."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import header, paper_workload, time_fn
from repro.core.eval_speculative import eval_speculative
from repro.core.eval_dataparallel import eval_data_parallel


def run(iters: int = 20):
    w = paper_workload(n_records=16_384)
    enc = w.enc
    depth = max(w.depth, 1)
    tree_args = (
        jnp.asarray(enc.attr_idx), jnp.asarray(enc.threshold),
        jnp.asarray(enc.child), jnp.asarray(enc.class_val),
    )
    sp = jax.jit(lambda r: eval_speculative(r, *tree_args, max_depth=depth,
                                            jumps_per_round=2, use_onehot_matmul=True))
    dp = jax.jit(lambda r: eval_data_parallel(r, *tree_args, max_depth=depth))
    out = []
    for m in (32, 256, 2048, 16_384):
        rec = jnp.asarray(w.records[:m])
        ts = time_fn(f"speculative m={m}", lambda: jax.block_until_ready(sp(rec)), iters=iters)
        td = time_fn(f"data_parallel m={m}", lambda: jax.block_until_ready(dp(rec)), iters=iters)
        out += [ts, td]
        out.append(type(ts)(f"  us/record m={m}", ts.mean_us / m, td.mean_us / m, 0, 0, iters))
    return out


def main():
    rows = run()
    print("m-amortization sweep (µs; last row pair = per-record costs spec/dp)")
    print(header())
    for t in rows:
        print(t.row())
    return rows


if __name__ == "__main__":
    main()
