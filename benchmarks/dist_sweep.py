"""Sharded-forest decomposition sweep: plan-predicted vs measured crossover.

For each workload × mesh shape this bench pins the (records × trees)
factorization, runs the ``repro.dist`` executor, and records the planner's
predicted cost (model units — rank-valid, not milliseconds) next to the
measured median.  The interesting question is the *crossover*: does the
decomposition the §3.6-extended model ranks first actually win on the
forced-8-host-device mesh?  The JSON records both winners per workload so
the agreement is diffable across PRs.

A streaming entry per workload times the chunked (double-buffered) path on
the planner's chosen plan against the monolithic call.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.dist_sweep

Run without the flag, it re-execs itself in a subprocess with 8 forced host
devices (jax locks the device count at first init).

Emits ``results/BENCH_dist.json``.
"""

from __future__ import annotations

import os
import subprocess
import sys

N_DEVICES = 8
_CHILD_ENV = "REPRO_DIST_SWEEP_CHILD"

# (records, trees) mesh factorizations of 8: all three decomposition
# families across four mesh shapes.
MESHES = [(8, 1), (4, 2), (2, 4), (1, 8)]

# Distinct operating points: record-heavy (the paper's segmentation scale)
# and tree-heavy (wide forests, e.g. top-k routing ensembles).
WORKLOADS = [
    # name, trees (count, max_depth), M, A
    ("record_heavy_t8_m32768", 8, 8, 32768, 19),
    ("balanced_t16_m4096", 16, 6, 4096, 19),
    ("tree_heavy_t64_m512", 64, 5, 512, 19),
]


def _reexec_with_devices() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + f" --xla_force_host_platform_device_count={N_DEVICES}"
    ).strip()
    env[_CHILD_ENV] = "1"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    subprocess.run(
        [sys.executable, "-m", "benchmarks.dist_sweep"], env=env, cwd=repo, check=True
    )


def _sweep(iters: int, warmup: int) -> dict:
    import dataclasses
    import zlib

    import jax
    import numpy as np

    from benchmarks.common import time_fn, write_bench_json
    from repro.core import EncodedForest, breadth_first_encode, random_tree
    from repro.dist import (
        ForestWorkload,
        MeshCostModel,
        ShardedForestEvaluator,
        StreamingChunker,
        make_plan,
        plan_forest,
    )
    from repro.tune import TuneCache

    mesh_cost = MeshCostModel()
    entries, summaries = [], []
    for name, n_trees, depth, m, a in WORKLOADS:
        trees = [
            breadth_first_encode(
                random_tree(n_attrs=a, n_classes=7, max_depth=2 + (i % depth), seed=i)
            )
            for i in range(n_trees)
        ]
        forest = EncodedForest(trees)
        rec = np.random.default_rng(zlib.crc32(name.encode())).normal(size=(m, a)).astype(np.float32)
        cache = TuneCache()  # shared across plans: per-shard winners accumulate
        wl = ForestWorkload.of(forest, rec)
        print(f"\n[{name}] {wl}")

        measured: dict[tuple[int, int], float] = {}
        for r, g in MESHES:
            if r > m or g > n_trees:
                print(f"  mesh ({r},{g}): infeasible for this workload, skipped")
                continue
            plan = make_plan(wl, r, g, mesh_cost)
            ev = ShardedForestEvaluator(forest, plan=plan, cache=cache)
            # fetch to host so the monolithic timing is apples-to-apples with
            # the streaming path, whose eval() returns host arrays
            t = time_fn(
                f"{name}/mesh{r}x{g}",
                lambda: np.asarray(jax.block_until_ready(ev(rec))),
                iters=iters,
                warmup=warmup,
                workload=name,
                mesh=[r, g],
                decomposition=plan.decomposition,
                predicted_model_units=round(plan.predicted, 3),
                shard_algorithm=plan.algorithm,
            )
            measured[(r, g)] = t.median_us / 1e3
            print(
                f"  mesh ({r},{g}) {plan.decomposition:8s} "
                f"predicted {plan.predicted:12.1f} u  measured {t.median_us/1e3:9.3f} ms"
            )
            entries.append({
                "workload": name,
                "mesh": [r, g],
                "decomposition": plan.decomposition,
                "shard_algorithm": plan.algorithm,
                "predicted_model_units": round(plan.predicted, 3),
                "measured_ms": round(t.median_us / 1e3, 6),
            })

        chosen = plan_forest(wl, N_DEVICES, mesh_cost=mesh_cost)
        pred_key = (chosen.record_shards, chosen.tree_shards)
        meas_key = min(measured, key=measured.get)
        feasible = {
            (r, g): make_plan(wl, r, g, mesh_cost).predicted for (r, g) in measured
        }
        pred_among_meshes = min(feasible, key=feasible.get)
        summaries.append({
            "workload": name,
            "workload_shape": dataclasses.asdict(wl),
            "planner_choice": {
                "mesh": list(pred_key),
                "decomposition": chosen.decomposition,
                "predicted_model_units": round(chosen.predicted, 3),
            },
            "predicted_winner_mesh": list(pred_among_meshes),
            "measured_winner_mesh": list(meas_key),
            "crossover_agreement": pred_among_meshes == meas_key,
        })
        print(
            f"  predicted winner {pred_among_meshes}, measured winner {meas_key}"
            f" -> {'AGREE' if pred_among_meshes == meas_key else 'DISAGREE'}"
        )

        # streaming chunker on the measured-best mesh: overlapped vs monolithic
        best_plan = make_plan(wl, *meas_key, mesh_cost)
        ev = ShardedForestEvaluator(forest, plan=best_plan, cache=cache)
        chunker = StreamingChunker(ev, chunk_records=max(m // 4, 1))
        # warmup must cover the coalescing ladder (two evals per explored
        # size: one compile, one measurement) so iters time the steady state
        t_stream = time_fn(
            f"{name}/stream",
            lambda: chunker.eval(rec),
            iters=iters,
            warmup=max(warmup, 6),
            workload=name,
            mesh=list(meas_key),
            mode="stream_chunked",
        )
        # re-time the monolithic call back-to-back on the *same* evaluator
        # (same compiled program, same machine state) — the mesh-loop number
        # above was taken minutes earlier and drifts by more than the
        # chunked-vs-monolithic difference
        t_mono = time_fn(
            f"{name}/monolithic",
            lambda: np.asarray(jax.block_until_ready(ev(rec))),
            iters=iters,
            warmup=warmup,
            workload=name,
            mesh=list(meas_key),
            mode="monolithic",
        )
        entries.append({
            "workload": name,
            "mesh": list(meas_key),
            "decomposition": best_plan.decomposition,
            "mode": "stream_chunked",
            "chunk_records": chunker.chunk_records,
            "measured_ms": round(t_stream.median_us / 1e3, 6),
            "monolithic_ms": round(t_mono.median_us / 1e3, 6),
            "chunk_ms_median": round(float(np.median(chunker.stats.chunk_ms)), 6),
            "overlap_ratio_mean": round(float(np.mean(chunker.stats.overlap_ratio)), 4),
            "coalesced_chunk_records": int(chunker.stats.coalesced_chunk_records
                                           or chunker.chunk_records),
        })
        print(
            f"  stream ({chunker.chunk_records}/chunk, coalesced to "
            f"{chunker.stats.coalesced_chunk_records or chunker.chunk_records}) "
            f"{t_stream.median_us/1e3:9.3f} ms"
            f" vs monolithic {t_mono.median_us/1e3:9.3f} ms"
        )

    from benchmarks import common

    common.drain_records()  # time_fn entries are folded into our richer JSON
    n_agree = sum(s["crossover_agreement"] for s in summaries)
    path = write_bench_json(
        "dist",
        entries,
        n_devices=N_DEVICES,
        mesh_shapes=[list(x) for x in MESHES],
        summaries=summaries,
        crossover_agreement=f"{n_agree}/{len(summaries)}",
    )
    print(f"\npredicted/measured decomposition winners agree on "
          f"{n_agree}/{len(summaries)} workloads")
    print(f"wrote {path}")
    return {"entries": entries, "summaries": summaries, "path": str(path)}


def main(iters: int = 7, warmup: int = 2) -> dict | None:
    import jax

    if jax.device_count() < N_DEVICES:
        if os.environ.get(_CHILD_ENV):
            raise SystemExit(
                f"forced host device count did not take effect "
                f"({jax.device_count()} < {N_DEVICES})"
            )
        print(f"re-exec with {N_DEVICES} forced host devices ...")
        _reexec_with_devices()
        return None
    return _sweep(iters, warmup)


if __name__ == "__main__":
    main()
