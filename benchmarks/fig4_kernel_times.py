"""Paper Figure 4: GPU-time summary — kernel time + memcpyHtoD + memcpyDtoH
per algorithm, the decomposition the paper uses to show measurement
methodology matters (inner vs outer)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import header, paper_workload, time_fn
from repro.core.eval_dataparallel import eval_data_parallel
from repro.core.eval_speculative import eval_speculative


def run(iters: int = 20):
    w = paper_workload()
    enc, rec = w.enc, w.records
    depth = max(w.depth, 1)
    args = (jnp.asarray(enc.attr_idx), jnp.asarray(enc.threshold),
            jnp.asarray(enc.child), jnp.asarray(enc.class_val))
    sp = jax.jit(lambda r: eval_speculative(r, *args, max_depth=depth,
                                            jumps_per_round=2, use_onehot_matmul=True))
    dp = jax.jit(lambda r: eval_data_parallel(r, *args, max_depth=depth))

    rec_dev = jnp.asarray(rec)
    h2d = time_fn("memcpyHtoD(records)",
                  lambda: jax.block_until_ready(jnp.asarray(rec)), iters=iters)
    cls = np.asarray(sp(rec_dev))
    d2h = time_fn("memcpyDtoH(classes)",
                  lambda: np.asarray(sp(rec_dev)), iters=iters)  # includes eval
    k_sp = time_fn("kernel EvalTreeByNode",
                   lambda: jax.block_until_ready(sp(rec_dev)), iters=iters)
    k_dp = time_fn("kernel EvalTreeBySample",
                   lambda: jax.block_until_ready(dp(rec_dev)), iters=iters)
    d2h_only = type(d2h)("memcpyDtoH(classes,net)",
                         max(d2h.mean_us - k_sp.mean_us, 0.0), 0, 0, 0, iters)
    return [k_dp, k_sp, h2d, d2h_only]


def main(iters: int = 20):
    rows = run(iters=iters)
    print("Figure 4 — kernel vs transfer time decomposition (µs)")
    print(header())
    for t in rows:
        print(t.row())
    k_dp, k_sp = rows[0], rows[1]
    print(f"\nkernel-time improvement (ByNode vs BySample): "
          f"{(k_dp.mean_us - k_sp.mean_us) / k_dp.mean_us * 100:+.1f}%  "
          f"(paper: +25%, 353µs vs 485µs)")
    return rows


if __name__ == "__main__":
    main()
