"""Benchmark harness: one module per paper table/figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1     # one

Each bench also emits a machine-readable ``results/BENCH_<name>.json``
(per-variant median ms + metadata, collected by ``common.time_fn``) so perf
is tracked across PRs, not just eyeballed in stdout tables.
"""

from __future__ import annotations

import sys
import time


BENCHES = ["table1", "fig4", "analysis", "m_sweep", "geometry", "moe_router", "tune",
           "cascade", "dist_sweep", "obs", "profile", "layout"]


def _run(name: str) -> None:
    from benchmarks import common

    t0 = time.perf_counter()
    print(f"\n=== {name} " + "=" * max(1, 66 - len(name)))
    common.drain_records()  # start the bench with an empty perf buffer
    if name == "table1":
        from benchmarks.table1_eval_times import main
        main(iters=10)
    elif name == "fig4":
        from benchmarks.fig4_kernel_times import main
        main(iters=10)
    elif name == "analysis":
        from benchmarks.analysis_curves import main
        main()
    elif name == "m_sweep":
        from benchmarks.m_sweep import main
        main()
    elif name == "geometry":
        from benchmarks.geometry_sweep import main
        main()
    elif name == "moe_router":
        from benchmarks.moe_router_bench import main
        main()
    elif name == "tune":
        from benchmarks.tune_sweep import main
        main()
    elif name == "cascade":
        from benchmarks.cascade_sweep import main
        main()
    elif name == "dist_sweep":
        from benchmarks.dist_sweep import main
        main()
    elif name == "obs":
        from benchmarks.obs_overhead import main
        main()
    elif name == "profile":
        from benchmarks.profile_sweep import main
        main()
    elif name == "layout":
        from benchmarks.layout_sweep import main
        main()
    else:
        raise SystemExit(f"unknown bench {name!r}; available: {BENCHES}")
    entries = common.drain_records()
    if entries and name not in ("tune", "cascade", "dist_sweep", "obs", "profile", "layout"):  # richer reports
        path = common.write_bench_json(name, entries)
        print(f"--- wrote {path}")
    print(f"--- {name} done in {time.perf_counter() - t0:.1f}s")


def main() -> None:
    names = sys.argv[1:] or BENCHES
    for n in names:
        _run(n)


if __name__ == "__main__":
    main()
