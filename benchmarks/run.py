"""Benchmark harness: one module per paper table/figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1     # one
"""

from __future__ import annotations

import sys
import time


BENCHES = ["table1", "fig4", "analysis", "m_sweep", "geometry", "moe_router"]


def _run(name: str) -> None:
    t0 = time.perf_counter()
    print(f"\n=== {name} " + "=" * max(1, 66 - len(name)))
    if name == "table1":
        from benchmarks.table1_eval_times import main
        main(iters=10)
    elif name == "fig4":
        from benchmarks.fig4_kernel_times import main
        main(iters=10)
    elif name == "analysis":
        from benchmarks.analysis_curves import main
        main()
    elif name == "m_sweep":
        from benchmarks.m_sweep import main
        main()
    elif name == "geometry":
        from benchmarks.geometry_sweep import main
        main()
    elif name == "moe_router":
        from benchmarks.moe_router_bench import main
        main()
    else:
        raise SystemExit(f"unknown bench {name!r}; available: {BENCHES}")
    print(f"--- {name} done in {time.perf_counter() - t0:.1f}s")


def main() -> None:
    names = sys.argv[1:] or BENCHES
    for n in names:
        _run(n)


if __name__ == "__main__":
    main()
