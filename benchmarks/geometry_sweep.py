"""Paper §6 future work: tree-geometry sweep (depth × balance) and record
distribution (ordered vs random) effects on the two decompositions."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import header, time_fn
from repro.core import breadth_first_encode, random_tree, tree_depth
from repro.core.eval_dataparallel import eval_data_parallel
from repro.core.eval_speculative import eval_speculative


def run(iters: int = 15, m: int = 8192):
    rng = np.random.default_rng(0)
    rec_random = rng.normal(size=(m, 12)).astype(np.float32)
    rec_ordered = np.sort(rec_random, axis=0)          # paper: ordered records
    out = []
    for depth, balance, tag in [
        (4, 1.0, "shallow/balanced"),
        (8, 1.0, "mid/balanced"),
        (12, 0.45, "deep/straggly"),
        (16, 0.35, "verydeep/straggly"),
    ]:
        enc = breadth_first_encode(
            random_tree(n_attrs=12, n_classes=7, max_depth=depth, seed=depth, balance=balance)
        )
        d = max(tree_depth(enc), 1)
        args = (jnp.asarray(enc.attr_idx), jnp.asarray(enc.threshold),
                jnp.asarray(enc.child), jnp.asarray(enc.class_val))
        sp = jax.jit(lambda r, a=args, d=d: eval_speculative(
            r, *a, max_depth=d, jumps_per_round=2, use_onehot_matmul=True))
        dp = jax.jit(lambda r, a=args, d=d: eval_data_parallel(r, *a, max_depth=d))
        for dist, rr in (("rand", rec_random), ("sort", rec_ordered)):
            rj = jnp.asarray(rr)
            out.append(time_fn(f"spec {tag} N={enc.n_nodes} d={d} {dist}",
                               lambda: jax.block_until_ready(sp(rj)), iters=iters))
            out.append(time_fn(f"dp   {tag} N={enc.n_nodes} d={d} {dist}",
                               lambda: jax.block_until_ready(dp(rj)), iters=iters))
    return out


def main():
    rows = run()
    print("tree-geometry × record-distribution sweep (µs)")
    print(header())
    for t in rows:
        print(t.row())
    return rows


if __name__ == "__main__":
    main()
