"""Shared benchmark utilities: the paper's experimental setup, timed runs.

The paper's workload (§4): UCI Image Segmentation (19 attrs / 7 classes),
classifier with N=31 / 16 leaves / depth 11, dataset of 65 536 records
(256×256 image), 500 timed iterations.  We reproduce it with the synthetic
UCI twin + a CART tree constrained into the same geometry class, falling
back to the deterministic paper-geometry tree when CART lands elsewhere.

Timing conventions mirror the paper:
  * inner time  — the evaluation call only (records already device-resident),
    the analogue of the paper's kernel-only time;
  * outer time  — includes host→device transfer of the record batch and
    device→host transfer of the class assignments (the paper's full-call
    time with cudaMemcpy).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.paper_segmentation import CONFIG as PAPER
from repro.core import (
    CartConfig, breadth_first_encode, eval_serial, paper_tree, train_cart, tree_depth,
)
from repro.data.segmentation import make_segmentation, replicated_dataset


@dataclasses.dataclass
class Workload:
    enc: object          # EncodedTree
    records: np.ndarray  # (65536, 19) float32
    labels: np.ndarray
    depth: int
    d_mu: float


def paper_workload(seed: int = 0, n_records: int | None = None) -> Workload:
    data = make_segmentation(seed)
    root = train_cart(
        data.x_train, data.y_train, PAPER.n_classes,
        CartConfig(max_depth=12, min_samples_split=8, min_gain=4e-3),
    )
    enc = breadth_first_encode(root)
    if not (15 <= enc.n_nodes <= 63):
        enc = breadth_first_encode(paper_tree())
    rec, lab = replicated_dataset(data, n_records or PAPER.dataset_records)
    from repro.core.analysis import mean_traversal_depth, observed_depths

    d_mu = mean_traversal_depth(observed_depths(enc, rec[:2048]))
    return Workload(enc=enc, records=rec, labels=lab, depth=tree_depth(enc), d_mu=d_mu)


@dataclasses.dataclass
class Timing:
    name: str
    mean_us: float
    min_us: float
    max_us: float
    std_us: float
    n: int

    def row(self) -> str:
        return (f"{self.name:32s} {self.mean_us:12.1f} {self.min_us:12.1f} "
                f"{self.max_us:12.1f} {self.std_us:10.2f}")


def time_fn(name: str, fn, *, iters: int = 50, warmup: int = 3) -> Timing:
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e6)
    a = np.asarray(samples)
    return Timing(name, float(a.mean()), float(a.min()), float(a.max()),
                  float(a.std()), iters)


def header() -> str:
    return (f"{'algorithm':32s} {'mean_us':>12s} {'min_us':>12s} "
            f"{'max_us':>12s} {'std':>10s}")
