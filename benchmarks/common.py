"""Shared benchmark utilities: the paper's experimental setup, timed runs.

The paper's workload (§4): UCI Image Segmentation (19 attrs / 7 classes),
classifier with N=31 / 16 leaves / depth 11, dataset of 65 536 records
(256×256 image), 500 timed iterations.  We reproduce it with the synthetic
UCI twin + a CART tree constrained into the same geometry class, falling
back to the deterministic paper-geometry tree when CART lands elsewhere.

Timing conventions mirror the paper:
  * inner time  — the evaluation call only (records already device-resident),
    the analogue of the paper's kernel-only time;
  * outer time  — includes host→device transfer of the record batch and
    device→host transfer of the class assignments (the paper's full-call
    time with cudaMemcpy).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.paper_segmentation import CONFIG as PAPER
from repro.core import (
    CartConfig, breadth_first_encode, eval_serial, paper_tree, train_cart, tree_depth,
)
from repro.data.segmentation import make_segmentation, replicated_dataset


@dataclasses.dataclass
class Workload:
    enc: object          # EncodedTree
    records: np.ndarray  # (65536, 19) float32
    labels: np.ndarray
    depth: int
    d_mu: float


def paper_workload(seed: int = 0, n_records: int | None = None) -> Workload:
    data = make_segmentation(seed)
    root = train_cart(
        data.x_train, data.y_train, PAPER.n_classes,
        CartConfig(max_depth=12, min_samples_split=8, min_gain=4e-3),
    )
    enc = breadth_first_encode(root)
    if not (15 <= enc.n_nodes <= 63):
        enc = breadth_first_encode(paper_tree())
    rec, lab = replicated_dataset(data, n_records or PAPER.dataset_records)
    from repro.core.analysis import mean_traversal_depth, observed_depths

    d_mu = mean_traversal_depth(observed_depths(enc, rec[:2048]))
    return Workload(enc=enc, records=rec, labels=lab, depth=tree_depth(enc), d_mu=d_mu)


@dataclasses.dataclass
class Timing:
    name: str
    mean_us: float
    min_us: float
    max_us: float
    std_us: float
    n: int
    median_us: float = 0.0
    mad_us: float = 0.0  # median absolute deviation — the dispersion the
                         # regression gate trusts (std is outlier-hostage)

    def row(self) -> str:
        return (f"{self.name:32s} {self.mean_us:12.1f} {self.min_us:12.1f} "
                f"{self.max_us:12.1f} {self.std_us:10.2f}")


# Machine-readable perf records: every time_fn call lands here (plus any
# caller-supplied metadata) and run.py drains the buffer into a
# results/BENCH_<name>.json after each bench, so the perf trajectory is
# diffable across PRs instead of living only in stdout tables.
_RECORDS: list[dict] = []


def record_timing(t: Timing, **meta) -> None:
    _RECORDS.append({
        "name": t.name,
        "median_ms": t.median_us / 1e3,
        "mad_ms": t.mad_us / 1e3,
        "mean_ms": t.mean_us / 1e3,
        "min_ms": t.min_us / 1e3,
        "max_ms": t.max_us / 1e3,
        "std_ms": t.std_us / 1e3,
        "iters": t.n,
        "backend": jax.default_backend(),
        **meta,
    })


def drain_records() -> list[dict]:
    out, _RECORDS[:] = list(_RECORDS), []
    return out


def bench_json_path(name: str) -> Path:
    root = Path(os.environ.get("REPRO_BENCH_DIR",
                               Path(__file__).resolve().parent.parent / "results"))
    return root / f"BENCH_{name}.json"


def env_header() -> dict:
    """The environment stamp every committed BENCH_*.json carries.

    A number without its environment is unreproducible: the same bench
    differs by orders of magnitude between a TPU run and interpret-mode
    Pallas on CPU.  This header makes each artifact self-describing —
    rendered by ``results/make_table.py`` above every table.
    """
    import platform

    from repro.kernels.tree_eval import ops as _ops

    dev = jax.devices()[0]
    return {
        "env": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": getattr(dev, "device_kind", str(dev)),
            "device_count": jax.device_count(),
            "pallas_interpret": not _ops.on_tpu(),
            "python": platform.python_version(),
            "platform": platform.platform(),
        }
    }


def history_dir() -> Path:
    """Where the bench trajectory lives (sibling of the BENCH snapshots)."""
    return bench_json_path("_").parent / "history"


def write_bench_json(name: str, entries: list[dict], **header) -> Path:
    path = bench_json_path(name)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "bench": name,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        **env_header(),
        **header,
        "entries": entries,
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    # The snapshot above is overwritten every run; the trajectory only ever
    # appends — results/check_regressions.py gates CI on it.
    from repro.obs.perf import append_history

    append_history(history_dir(), name, payload)
    return path


def time_fn(name: str, fn, *, iters: int = 50, warmup: int = 3, **meta) -> Timing:
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e6)
    a = np.asarray(samples)
    med = float(np.median(a))
    t = Timing(name, float(a.mean()), float(a.min()), float(a.max()),
               float(a.std()), iters, med,
               float(np.median(np.abs(a - med))))
    record_timing(t, **meta)
    return t


def header() -> str:
    return (f"{'algorithm':32s} {'mean_us':>12s} {'min_us':>12s} "
            f"{'max_us':>12s} {'std':>10s}")
