"""Early-exit cascade sweep: accuracy vs latency vs stage count on easy/hard mixes.

The cascade's operating claim is workload-dependent: staged evaluation with
margin-bound early exit wins when most records are *easy* (the stage-1 trees
already agree) and degrades gracefully toward the full-forest cost as the
mix hardens.  This bench makes that trade-off diffable:

* a 16-tree bagged CART forest on the paper's segmentation data — real
  bootstrap-correlated trees, so real rows are genuinely easy (≈96% per-tree
  agreement with the majority) and feature-matched noise rows are hard;
* three record mixes — all-easy, all-hard, and a skewed 90/10 easy/hard
  stream (the serving-shaped case the cascade targets);
* the full sweep: exit bound ∈ {None, 1.0, 0.5, 0.25} × stage count ∈ {2, 3}
  against the fused stacked kernel and the vmap forest baselines.

``bound=1.0`` is the provable setting (bit-exact with the full majority, so
its accuracy delta is identically 0); relaxed bounds trade measured accuracy
for latency.  Emits ``results/BENCH_cascade.json`` with an acceptance
summary: on the skewed mix the provable cascade must be ≥1.5× faster than
``forest_fused`` at ≤0.5% accuracy delta.

    PYTHONPATH=src python -m benchmarks.cascade_sweep
"""

from __future__ import annotations

BOUNDS = (None, 1.0, 0.5, 0.25)
STAGE_COUNTS = (2, 3)
N_TREES = 16
N_CLASSES = 7


def _bagged_forest(seed: int = 0):
    import numpy as np

    from repro.core import CartConfig, EncodedForest, breadth_first_encode, train_cart
    from repro.data.segmentation import make_segmentation

    data = make_segmentation(seed)
    rng = np.random.default_rng(seed)
    trees = []
    for _ in range(N_TREES):
        idx = rng.integers(0, data.x_train.shape[0], data.x_train.shape[0])
        root = train_cart(
            data.x_train[idx], data.y_train[idx], N_CLASSES,
            CartConfig(max_depth=8, min_samples_split=16, min_gain=4e-3),
        )
        trees.append(breadth_first_encode(root))
    return EncodedForest(trees), data


def _mixes(data, m: int, seed: int = 1):
    import numpy as np

    easy = np.tile(data.x_test, (m // data.x_test.shape[0] + 1, 1))[:m]
    easy = easy.astype(np.float32)
    rng = np.random.default_rng(seed)
    hard = rng.normal(loc=easy.mean(0), scale=easy.std(0) + 1e-6,
                      size=(m, easy.shape[1])).astype(np.float32)
    n_hard = m // 10
    skew = easy.copy()
    pos = rng.permutation(m)[:n_hard]
    skew[pos] = hard[:n_hard]
    return {"easy": easy, "hard": hard, "skewed_90_10": skew}


def main(iters: int = 7, warmup: int = 2, m: int = 4096) -> dict:
    import numpy as np
    import jax

    from benchmarks import common
    from benchmarks.common import time_fn, write_bench_json
    from repro.core import majority_vote
    from repro.kernels.tree_eval import CascadeEvaluator, plan_cascade
    from repro.kernels.tree_eval.ops import get_forest_variant

    forest, data = _bagged_forest()
    mixes = _mixes(data, m)
    depth = forest.max_depth
    print(f"bagged CART forest: T={forest.n_trees} n_nodes={forest.n_nodes} "
          f"depth={depth}; m={m} per mix")

    entries, baselines = [], {}
    for mix, rec in mixes.items():
        per_tree = np.asarray(
            get_forest_variant("forest_vmap_speculative_gather").fn(
                rec, forest, max_depth=depth)
        )
        exact = np.asarray(majority_vote(jax.numpy.asarray(per_tree), N_CLASSES))
        base_ms = {}
        for vname, label in (("forest_fused_speculative_gather", "forest_fused"),
                             ("forest_vmap_speculative_gather", "forest_vmap")):
            fn = get_forest_variant(vname).fn
            t = time_fn(
                f"{mix}/{label}",
                lambda fn=fn: jax.block_until_ready(
                    majority_vote(fn(rec, forest, max_depth=depth), N_CLASSES)),
                iters=iters, warmup=warmup, mix=mix, variant=label,
            )
            base_ms[label] = t.median_us / 1e3
            entries.append({
                "mix": mix, "variant": label, "bound": None, "stages": 1,
                "median_ms": round(base_ms[label], 6),
                "accuracy_delta": 0.0,
                "mean_trees_evaluated": float(forest.n_trees),
            })
            print(f"  [{mix}] {label:24s} {base_ms[label]:9.3f} ms")
        baselines[mix] = base_ms

        calib = rec[:512]
        for stages in STAGE_COUNTS:
            plan = plan_cascade(forest, calib, n_classes=N_CLASSES,
                                stages=stages, bound=1.0)
            for bound in BOUNDS:
                ev = CascadeEvaluator(forest, plan, n_classes=N_CLASSES,
                                      bound=bound, engine="jnp")
                t = time_fn(
                    f"{mix}/cascade_s{stages}_b{bound}",
                    lambda ev=ev: ev(rec),
                    iters=iters, warmup=warmup, mix=mix,
                    variant="cascade", stages=stages,
                    bound=(None if bound is None else float(bound)),
                )
                res = ev(rec)
                cls = np.asarray(res.classes)
                delta = float((cls != exact).mean())
                mean_trees = float(np.asarray(res.trees_evaluated).mean())
                med = t.median_us / 1e3
                entries.append({
                    "mix": mix, "variant": "cascade",
                    "bound": (None if bound is None else float(bound)),
                    "stages": stages,
                    "median_ms": round(med, 6),
                    "accuracy_delta": round(delta, 6),
                    "mean_trees_evaluated": round(mean_trees, 3),
                    "stage_survivors": [int(s) for s in res.stage_survivors],
                    "speedup_vs_fused": round(base_ms["forest_fused"] / med, 3),
                    "speedup_vs_vmap": round(base_ms["forest_vmap"] / med, 3),
                })
                print(f"  [{mix}] cascade s={stages} b={str(bound):4s} "
                      f"{med:9.3f} ms  Δacc {delta:7.4f}  "
                      f"trees {mean_trees:5.2f}  "
                      f"x{base_ms['forest_fused']/med:.2f} fused / "
                      f"x{base_ms['forest_vmap']/med:.2f} vmap")

    # acceptance: the provable cascade (bound=1.0, best stage count) on the
    # skewed mix beats the fused kernel by >=1.5x at <=0.5% accuracy delta
    provable = [e for e in entries
                if e["mix"] == "skewed_90_10" and e["variant"] == "cascade"
                and e["bound"] == 1.0]
    best = max(provable, key=lambda e: e["speedup_vs_fused"])
    summary = {
        "skewed_provable_speedup_vs_fused": best["speedup_vs_fused"],
        "skewed_provable_speedup_vs_vmap": best["speedup_vs_vmap"],
        "skewed_provable_accuracy_delta": best["accuracy_delta"],
        "skewed_provable_stages": best["stages"],
        "meets_1p5x_vs_fused": best["speedup_vs_fused"] >= 1.5,
        "meets_accuracy_budget": best["accuracy_delta"] <= 0.005,
    }
    common.drain_records()  # time_fn entries are folded into our richer JSON
    path = write_bench_json(
        "cascade", entries,
        n_trees=forest.n_trees, n_classes=N_CLASSES, m=m,
        bounds=[None if b is None else float(b) for b in BOUNDS],
        stage_counts=list(STAGE_COUNTS), summary=summary,
    )
    print(f"\nskewed-mix provable cascade: x{best['speedup_vs_fused']:.2f} vs fused "
          f"(need >=1.5), Δacc {best['accuracy_delta']:.4f} (need <=0.005)")
    print(f"wrote {path}")
    return {"entries": entries, "summary": summary, "path": str(path)}


if __name__ == "__main__":
    main()
