"""Observability overhead: the serve path with obs disabled vs enabled.

The repro.obs contract is "near-zero cost when disabled, cheap when on":
every instrument mutation behind one enabled-flag branch, spans behind a
shared no-op context manager.  This bench prices the contract on the real
serve path — a :class:`repro.serve.ForestServeEngine` pushing record waves
through the streaming chunker and the sharded executor — in three modes:

* ``obs_off``      — ``Registry(enabled=False)`` + the null tracer (every
  call site still runs, the branches just fall through);
* ``obs_metrics``  — registry enabled, tracing off (the steady-state
  production setting);
* ``obs_full``     — registry + span tracer enabled (the debugging setting).

Acceptance: ``obs_metrics`` wall-clock within 2% of ``obs_off`` (the
number published in docs/observability.md).  Emits results/BENCH_obs.json.

    PYTHONPATH=src python -m benchmarks.obs_overhead
"""

from __future__ import annotations

N_TREES = 8
N_CLASSES = 7
WAVE_RECORDS = 2048
REQUESTS = 8


def _forest(seed: int = 0):
    import numpy as np

    from repro.core import CartConfig, EncodedForest, breadth_first_encode, train_cart
    from repro.data.segmentation import make_segmentation

    data = make_segmentation(seed)
    rng = np.random.default_rng(seed)
    trees = []
    for _ in range(N_TREES):
        idx = rng.integers(0, data.x_train.shape[0], data.x_train.shape[0])
        root = train_cart(
            data.x_train[idx], data.y_train[idx], N_CLASSES,
            CartConfig(max_depth=8, min_samples_split=16, min_gain=4e-3),
        )
        trees.append(breadth_first_encode(root))
    return EncodedForest(trees), data


def _engine(forest, mode: str):
    from repro import obs
    from repro.serve import ForestServeEngine

    if mode == "obs_off":
        registry, tracer = obs.Registry(enabled=False), obs.NULL_TRACER
    elif mode == "obs_metrics":
        registry, tracer = obs.Registry(), obs.NULL_TRACER
    elif mode == "obs_full":
        registry, tracer = obs.Registry(), obs.Tracer()
    else:
        raise ValueError(mode)
    # retune=None: a background measurement mid-iteration would dominate the
    # timing and measure the tuner, not the observation cost
    return ForestServeEngine(
        forest, max_batch=WAVE_RECORDS, chunk_records=WAVE_RECORDS // 4,
        n_classes=N_CLASSES, retune=None, registry=registry, tracer=tracer,
    )


def main(iters: int = 30, warmup: int = 5) -> dict:
    import numpy as np

    from benchmarks.common import time_fn, write_bench_json
    from repro.serve import TreeRequest

    forest, data = _forest()
    rec = np.tile(data.x_test, (WAVE_RECORDS // data.x_test.shape[0] + 1, 1))
    rec = rec[:WAVE_RECORDS].astype(np.float32)
    print(f"forest: T={forest.n_trees} n_nodes={forest.n_nodes}; "
          f"{REQUESTS} requests x {WAVE_RECORDS} records per pass")

    medians: dict[str, float] = {}
    entries: list[dict] = []
    for mode in ("obs_off", "obs_metrics", "obs_full"):
        eng = _engine(forest, mode)

        def serve_pass():
            reqs = [TreeRequest(uid=i, records=rec) for i in range(REQUESTS)]
            eng.run(reqs)

        t = time_fn(mode, serve_pass, iters=iters, warmup=warmup,
                    mode=mode, requests=REQUESTS, wave_records=WAVE_RECORDS)
        medians[mode] = t.median_us / 1e3
        print(f"  {mode:12s} median {t.median_us / 1e3:9.3f} ms")
        entries.append({
            "name": mode,
            "median_ms": t.median_us / 1e3,
            "mean_ms": t.mean_us / 1e3,
            "min_ms": t.min_us / 1e3,
            "max_ms": t.max_us / 1e3,
            "iters": t.n,
        })

    base = medians["obs_off"]
    overhead = {
        m: (medians[m] - base) / base * 100.0
        for m in ("obs_metrics", "obs_full")
    }
    for m, pct in overhead.items():
        print(f"  {m:12s} overhead {pct:+6.2f}% vs obs_off")
    summary = {
        "baseline_ms": base,
        "metrics_overhead_pct": overhead["obs_metrics"],
        "full_overhead_pct": overhead["obs_full"],
        "target_pct": 2.0,
        "metrics_within_target": overhead["obs_metrics"] <= 2.0,
    }
    path = write_bench_json("obs", entries, summary=summary)
    print(f"wrote {path}")
    return summary


if __name__ == "__main__":
    main()
