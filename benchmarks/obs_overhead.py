"""Observability overhead: the serve path with obs disabled vs enabled.

The repro.obs contract is "near-zero cost when disabled, cheap when on":
every instrument mutation behind one enabled-flag branch, spans behind a
shared no-op context manager.  This bench prices the contract on the real
serve path — a :class:`repro.serve.ForestServeEngine` pushing record waves
through the streaming chunker and the sharded executor — in three modes:

* ``obs_off``      — ``Registry(enabled=False)`` + the null tracer (every
  call site still runs, the branches just fall through);
* ``obs_metrics``  — registry enabled, tracing off (the steady-state
  production setting);
* ``obs_full``     — registry + span tracer enabled (the debugging setting);
* ``obs_profiled`` — registry + the traversal profiler at its default
  sampling policy (1-in-64 waves shadow-profiled off the request path) —
  prices the :class:`repro.obs.TraversalProfiler` the serve engines now
  run by default.

Acceptance: ``obs_metrics`` and ``obs_profiled`` wall-clock within 2% of
``obs_off`` (the numbers published in docs/observability.md).  Emits
results/BENCH_obs.json.  ``--enforce`` turns the budget into an exit code
for CI — the threshold is noise-aware (``max(2%, 3·MAD(obs_off)/baseline)``),
because on a loaded CPU runner the run-to-run MAD routinely exceeds the 2%
budget and a fixed gate would flap.

    PYTHONPATH=src python -m benchmarks.obs_overhead [--enforce]
"""

from __future__ import annotations

N_TREES = 8
N_CLASSES = 7
WAVE_RECORDS = 2048
REQUESTS = 8


def _forest(seed: int = 0):
    import numpy as np

    from repro.core import CartConfig, EncodedForest, breadth_first_encode, train_cart
    from repro.data.segmentation import make_segmentation

    data = make_segmentation(seed)
    rng = np.random.default_rng(seed)
    trees = []
    for _ in range(N_TREES):
        idx = rng.integers(0, data.x_train.shape[0], data.x_train.shape[0])
        root = train_cart(
            data.x_train[idx], data.y_train[idx], N_CLASSES,
            CartConfig(max_depth=8, min_samples_split=16, min_gain=4e-3),
        )
        trees.append(breadth_first_encode(root))
    return EncodedForest(trees), data


def _engine(forest, mode: str):
    from repro import obs
    from repro.serve import ForestServeEngine

    profile = None
    if mode == "obs_off":
        registry, tracer = obs.Registry(enabled=False), obs.NULL_TRACER
    elif mode == "obs_metrics":
        registry, tracer = obs.Registry(), obs.NULL_TRACER
    elif mode == "obs_full":
        registry, tracer = obs.Registry(), obs.Tracer()
    elif mode == "obs_profiled":
        # default sampling policy: what the engines ship with out of the box
        registry, tracer = obs.Registry(), obs.NULL_TRACER
        profile = obs.ProfilePolicy()
    else:
        raise ValueError(mode)
    # retune=None: a background measurement mid-iteration would dominate the
    # timing and measure the tuner, not the observation cost
    return ForestServeEngine(
        forest, max_batch=WAVE_RECORDS, chunk_records=WAVE_RECORDS // 4,
        n_classes=N_CLASSES, retune=None, profile=profile,
        registry=registry, tracer=tracer,
    )


def main(iters: int = 30, warmup: int = 5) -> dict:
    import numpy as np

    from benchmarks.common import time_fn, write_bench_json
    from repro.serve import TreeRequest

    forest, data = _forest()
    rec = np.tile(data.x_test, (WAVE_RECORDS // data.x_test.shape[0] + 1, 1))
    rec = rec[:WAVE_RECORDS].astype(np.float32)
    print(f"forest: T={forest.n_trees} n_nodes={forest.n_nodes}; "
          f"{REQUESTS} requests x {WAVE_RECORDS} records per pass")

    medians: dict[str, float] = {}
    mads: dict[str, float] = {}
    entries: list[dict] = []
    for mode in ("obs_off", "obs_metrics", "obs_full", "obs_profiled"):
        eng = _engine(forest, mode)

        def serve_pass():
            reqs = [TreeRequest(uid=i, records=rec) for i in range(REQUESTS)]
            eng.run(reqs)

        # prime: the first sampled wave jit-compiles the shadow descent on
        # the worker thread; drain so the compile never bleeds into timing
        serve_pass()
        if eng.profiler is not None:
            eng.profiler.drain()
        t = time_fn(mode, serve_pass, iters=iters, warmup=warmup,
                    mode=mode, requests=REQUESTS, wave_records=WAVE_RECORDS)
        if eng.profiler is not None:
            eng.profiler.drain()  # shadow passes out of the next mode's timing
        medians[mode] = t.median_us / 1e3
        mads[mode] = t.mad_us / 1e3
        print(f"  {mode:12s} median {t.median_us / 1e3:9.3f} ms "
              f"(MAD {t.mad_us / 1e3:7.3f} ms)")
        entries.append({
            "name": mode,
            "median_ms": t.median_us / 1e3,
            "mad_ms": t.mad_us / 1e3,
            "mean_ms": t.mean_us / 1e3,
            "min_ms": t.min_us / 1e3,
            "max_ms": t.max_us / 1e3,
            "iters": t.n,
        })

    base = medians["obs_off"]
    overhead = {
        m: (medians[m] - base) / base * 100.0
        for m in ("obs_metrics", "obs_full", "obs_profiled")
    }
    for m, pct in overhead.items():
        print(f"  {m:12s} overhead {pct:+6.2f}% vs obs_off")
    # The enforceable budget: the documented 2%, widened to the measured
    # noise floor when the host is noisier than the budget itself.
    noise_pct = 3.0 * mads["obs_off"] / base * 100.0 if base else 0.0
    enforce_pct = max(2.0, noise_pct)
    summary = {
        "baseline_ms": base,
        "baseline_mad_ms": mads["obs_off"],
        "metrics_overhead_pct": overhead["obs_metrics"],
        "full_overhead_pct": overhead["obs_full"],
        "profiled_overhead_pct": overhead["obs_profiled"],
        "target_pct": 2.0,
        "noise_floor_pct": noise_pct,
        "enforce_threshold_pct": enforce_pct,
        "metrics_within_target": overhead["obs_metrics"] <= enforce_pct,
        "profiled_within_target": overhead["obs_profiled"] <= enforce_pct,
    }
    path = write_bench_json("obs", entries, summary=summary)
    print(f"wrote {path}")
    return summary


if __name__ == "__main__":
    import argparse
    import sys

    p = argparse.ArgumentParser(description="obs overhead bench")
    p.add_argument("--iters", type=int, default=30)
    p.add_argument("--warmup", type=int, default=5)
    p.add_argument("--enforce", action="store_true",
                   help="exit 1 if metrics-enabled overhead exceeds the "
                        "noise-aware budget (CI gate)")
    args = p.parse_args()
    s = main(iters=args.iters, warmup=args.warmup)
    if args.enforce and not s["metrics_within_target"]:
        print(f"FAIL: obs_metrics overhead {s['metrics_overhead_pct']:+.2f}% "
              f"exceeds budget {s['enforce_threshold_pct']:.2f}%")
        sys.exit(1)
    if args.enforce and not s["profiled_within_target"]:
        print(f"FAIL: obs_profiled overhead {s['profiled_overhead_pct']:+.2f}% "
              f"exceeds budget {s['enforce_threshold_pct']:.2f}%")
        sys.exit(1)
