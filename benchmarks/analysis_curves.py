"""Paper §3.6 curves: S₃(P), S₅(P), efficiency, and the equation-(1)
crossover — the theoretical model the experiments then contradict on SIMD."""

from __future__ import annotations

import math

from repro.core import analysis


def run():
    m, d_mu = 65_536, 8.6           # paper-scale workload
    rows = []
    for p in (1, 4, 16, 64, 192, 256, 1024):
        cm_free = analysis.CostModel()                 # free memory
        cm_mem = analysis.CostModel(sigma=0.05)        # memory-bound machine
        rows.append({
            "P": p,
            "S3_free": analysis.s3_speedup(m, d_mu, p, cm_free),
            "S5_free_p16": analysis.s5_speedup(m, d_mu, p, 16, cm_free),
            "S3_mem": analysis.s3_speedup(m, d_mu, p, cm_mem),
            "S5_mem_p16": analysis.s5_speedup(m, d_mu, p, 16, cm_mem),
            "E3_free": analysis.e3_efficiency(m, d_mu, p, cm_free),
        })
    return rows


def main():
    rows = run()
    print("§3.6 speedup models (M=65536, d_mu=8.6, record group p=16)")
    hdr = ["P", "S3_free", "S5_free_p16", "S3_mem", "S5_mem_p16", "E3_free"]
    print(" ".join(f"{h:>12s}" for h in hdr))
    for r in rows:
        print(" ".join(f"{r[h]:12.3f}" if h != "P" else f"{r[h]:12d}" for h in hdr))
    print("\nEquation (1) crossover p* = 2d/(1+log2 d):")
    for d in (2, 4, 8, 11, 16, 32, 64):
        p_star = analysis.crossover_group_size(d)
        print(f"  d_mu={d:3d}  p* = {p_star:6.2f}  "
              f"(speculative wins iff record group p < p*)")
    print("\npaper setting d_mu≈11, p=16 → model predicts data decomposition wins;")
    print("SIMD experiments show speculative +25% — the model's independent-")
    print("processor assumption is what fails on real hardware (paper §5).")
    return rows


if __name__ == "__main__":
    main()
