"""Perf observability: the bench trajectory store, the noise-aware
regression detector (and its CLI gate), kernel cost/roofline accounting on
the tuner's measurement path, cascade host-compaction metrics, and the SLO
flight recorder's debug bundles.
"""

import importlib.util
import json
import pathlib
import sys
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import breadth_first_encode, paper_tree, random_tree
from repro.core.forest import EncodedForest, eval_forest_cascade
from repro.obs.perf import (
    ENV_KEYS,
    append_history,
    baseline_pool,
    detect_regressions,
    env_key,
    extract_series,
    load_history,
    skipped_series,
)
from repro.serve import TreeRequest, TreeServeEngine
from repro.tune import TuneCache

REPO = pathlib.Path(__file__).resolve().parent.parent

ENV = {
    "backend": "cpu",
    "device_kind": "cpu",
    "device_count": 1,
    "pallas_interpret": "true",
    "jax": "0.4.37",
}


def _run(medians, env=ENV):
    """One trajectory record with the given {series: median_ms}."""
    return {
        "bench": "t",
        "ts": "2026-01-01T00:00:00+00:00",
        "source": "test",
        "env": dict(env),
        "series": {k: {"median_ms": float(v)} for k, v in medians.items()},
    }


def _records(m, a, seed=0):
    return np.random.default_rng(seed).normal(size=(m, a)).astype(np.float32)


# ---------------------------------------------------------------------------
# regression detector
# ---------------------------------------------------------------------------


class TestRegressionDetector:
    def test_single_run_history_never_flags(self):
        assert detect_regressions([_run({"w": 1.0})]) == []
        assert detect_regressions([]) == []

    def test_env_mismatch_never_compares(self):
        # 10x slower on a different backend is a different experiment, not a
        # regression — the baseline pool must come up empty.
        hist = [_run({"w": 1.0}) for _ in range(4)]
        tpu = dict(ENV, backend="tpu", device_kind="TPU v5e")
        hist.append(_run({"w": 10.0}, env=tpu))
        assert baseline_pool(hist) == []
        assert detect_regressions(hist) == []
        # same-env latest still compares against same-env predecessors only
        hist.append(_run({"w": 10.0}))
        pool = baseline_pool(hist)
        assert len(pool) == 4 and all(env_key(r["env"]) == env_key(ENV) for r in pool)
        flagged = detect_regressions(hist)
        assert [r.series for r in flagged] == ["w"]

    def test_mad_zero_identical_history(self):
        # All-identical history: MAD = 0, so the relative floor carries the
        # gate alone — an equal latest passes, sub-threshold jitter passes,
        # a 2x latest is flagged.
        hist = [_run({"w": 1.0}) for _ in range(5)]
        assert detect_regressions(hist + [_run({"w": 1.0})]) == []
        assert detect_regressions(hist + [_run({"w": 1.4})]) == []
        flagged = detect_regressions(hist + [_run({"w": 2.0})])
        assert len(flagged) == 1
        r = flagged[0]
        assert r.series == "w" and r.mad_ms == 0.0
        assert r.baseline_ms == pytest.approx(1.0)
        assert r.ratio == pytest.approx(2.0)
        assert r.threshold_ms == pytest.approx(1.5)
        assert "x2.00" in r.describe()

    def test_mad_widens_gate_on_noisy_series(self):
        # baseline median 12, MAD 2: k_mad*MAD = 10 beats the relative floor
        # (6), so a 20 ms latest — over 1.5x baseline — still passes.
        hist = [_run({"w": v}) for v in (10.0, 14.0, 10.0, 14.0, 12.0)]
        assert detect_regressions(hist + [_run({"w": 20.0})]) == []
        flagged = detect_regressions(hist + [_run({"w": 23.0})])
        assert [r.series for r in flagged] == ["w"]

    def test_synthetic_2x_regression_flagged(self):
        hist = [_run({"fast": 1.0, "slow": 8.0}) for _ in range(5)]
        flagged = detect_regressions(hist + [_run({"fast": 2.0, "slow": 8.0})])
        assert [(r.series, round(r.ratio, 2)) for r in flagged] == [("fast", 2.0)]

    def test_new_series_is_not_a_regression(self):
        hist = [_run({"w": 1.0}) for _ in range(3)]
        assert detect_regressions(hist + [_run({"w": 1.0, "brand_new": 99.0})]) == []

    def test_window_bounds_the_pool(self):
        hist = [_run({"w": float(i)}) for i in range(10)]
        pool = baseline_pool(hist, window=3)
        assert [r["series"]["w"]["median_ms"] for r in pool] == [6.0, 7.0, 8.0]


class TestSkippedSeries:
    """Series detect_regressions silently skips must still be reportable."""

    def test_thin_baseline_is_reported_with_its_count(self):
        # seed run only: the series has zero same-env predecessors
        assert skipped_series([_run({"w": 1.0})]) == [("w", 0)]
        # one predecessor: still below the default min_runs=2
        hist = [_run({"w": 1.0}), _run({"w": 1.0, "new": 5.0})]
        assert skipped_series(hist) == [("new", 0), ("w", 1)]
        # enough history: nothing to report
        assert skipped_series([_run({"w": 1.0}) for _ in range(3)]) == []
        assert skipped_series([]) == []

    def test_env_change_orphans_the_baseline(self):
        # same trick as test_env_mismatch_never_compares: a backend switch
        # empties the pool, so every series of the latest run shows up skipped
        tpu = dict(ENV, backend="tpu", device_kind="TPU v5e")
        hist = [_run({"w": 1.0}) for _ in range(4)] + [_run({"w": 1.0}, env=tpu)]
        assert skipped_series(hist) == [("w", 0)]

    def test_min_runs_raises_the_bar(self):
        hist = [_run({"w": 1.0}) for _ in range(4)]
        assert skipped_series(hist, min_runs=3) == []
        assert skipped_series(hist, min_runs=4) == [("w", 3)]


# ---------------------------------------------------------------------------
# history store
# ---------------------------------------------------------------------------


class TestHistoryStore:
    def test_extract_series_names_and_fallbacks(self):
        payload = {
            "entries": [
                {"name": "w", "median_ms": 1.5, "mad_ms": 0.1},
                {"workload": "x", "tuned_ms": 2.0, "tuned_mad_ms": 0.2,
                 "variant": "fused"},
                {"name": "acc_only", "accuracy": 0.9},  # no median -> skipped
                {"name": "w", "median_ms": 9.0},        # collision -> suffixed
            ],
            "forest_entries": [
                {"name": "f", "forest_tuned_ms": 3.0, "stages": 2, "bound": 0.25},
            ],
        }
        series = extract_series(payload)
        assert series["w"] == {"median_ms": 1.5, "mad_ms": 0.1}
        assert series["x/fused"] == {"median_ms": 2.0, "mad_ms": 0.2}
        assert series["w#2"] == {"median_ms": 9.0}
        assert series["f/s2/b0.25"] == {"median_ms": 3.0}
        assert "acc_only" not in series

    def test_append_load_roundtrip(self, tmp_path):
        payload = {"env": dict(ENV),
                   "entries": [{"name": "w", "median_ms": 1.0, "mad_ms": 0.05}]}
        append_history(tmp_path, "toy", payload, ts="2026-01-01T00:00:00+00:00")
        append_history(tmp_path, "toy", payload)
        records = load_history(tmp_path / "toy.jsonl")
        assert len(records) == 2
        assert records[0]["ts"] == "2026-01-01T00:00:00+00:00"
        assert records[0]["series"]["w"]["median_ms"] == 1.0
        assert env_key(records[0]["env"]) == env_key(ENV)
        assert all(k in records[0]["env"] for k in ENV_KEYS)

    def test_corrupt_line_raises_with_location(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text('{"ok": 1}\nnot json at all\n')
        with pytest.raises(ValueError, match="bad.jsonl:2"):
            load_history(path)

    def test_write_bench_json_appends_history(self, tmp_path, monkeypatch):
        # the benches' own writer must leave a trajectory line behind
        monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
        monkeypatch.syspath_prepend(str(REPO))
        from benchmarks.common import write_bench_json

        entries = [{"name": "w", "median_ms": 1.25, "mad_ms": 0.01}]
        write_bench_json("toybench", entries)
        write_bench_json("toybench", entries)
        records = load_history(tmp_path / "history" / "toybench.jsonl")
        assert len(records) == 2
        assert records[-1]["source"] == "bench"
        assert records[-1]["series"]["w"] == {"median_ms": 1.25, "mad_ms": 0.01}
        assert records[-1]["env"].get("backend")  # real env header attached


# ---------------------------------------------------------------------------
# check_regressions.py CLI (the CI perf gate)
# ---------------------------------------------------------------------------


def _cli():
    spec = importlib.util.spec_from_file_location(
        "check_regressions", REPO / "results" / "check_regressions.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestCheckRegressionsCLI:
    def _write(self, d, runs):
        d.mkdir(parents=True, exist_ok=True)
        with open(d / "toy.jsonl", "w") as f:
            for r in runs:
                f.write(json.dumps(r, sort_keys=True) + "\n")

    def test_injected_2x_slowdown_exits_nonzero(self, tmp_path, capsys):
        self._write(tmp_path, [_run({"w": 1.0}) for _ in range(4)]
                    + [_run({"w": 2.0})])
        rc = _cli().main(["--history-dir", str(tmp_path)])
        assert rc == 1
        assert "REGRESSION" in capsys.readouterr().out

    def test_clean_history_exits_zero(self, tmp_path):
        self._write(tmp_path, [_run({"w": 1.0}) for _ in range(5)])
        assert _cli().main(["--history-dir", str(tmp_path), "--strict"]) == 0

    def test_committed_history_is_clean(self, capsys):
        # the repo's own trajectory must pass the exact gate CI runs
        assert _cli().main(["--strict"]) == 0
        out = capsys.readouterr().out
        assert "0 regression(s)" in out

    def test_skipped_series_reported_not_failed(self, tmp_path, capsys):
        # one predecessor for "w", none for "fresh": both below min_runs=2,
        # so the gate reports them without failing — even under --strict
        self._write(tmp_path, [_run({"w": 1.0}), _run({"w": 1.0, "fresh": 2.0})])
        rc = _cli().main(["--history-dir", str(tmp_path), "--strict"])
        out = capsys.readouterr().out
        assert rc == 0
        assert ("SKIPPED  toy/fresh: insufficient history "
                "(0 same-env run(s), need 2)") in out
        assert ("SKIPPED  toy/w: insufficient history "
                "(1 same-env run(s), need 2)") in out
        assert "2 skipped" in out

    def test_skipped_series_in_json_and_min_runs(self, tmp_path, capsys):
        self._write(tmp_path, [_run({"w": 1.0}) for _ in range(3)])
        rc = _cli().main(["--history-dir", str(tmp_path), "--json"])
        data = json.loads(capsys.readouterr().out)
        assert rc == 0 and data["skipped"] == []
        # raising the bar makes the same history insufficient
        rc = _cli().main(["--history-dir", str(tmp_path), "--json",
                          "--min-runs", "5"])
        data = json.loads(capsys.readouterr().out)
        assert rc == 0
        assert data["skipped"] == [
            {"bench": "toy", "series": "w", "n_baseline": 2}]

    def test_empty_history_file_is_a_problem_not_a_crash(self, tmp_path, capsys):
        (tmp_path / "toy.jsonl").write_text("")
        rc = _cli().main(["--history-dir", str(tmp_path), "--json"])
        data = json.loads(capsys.readouterr().out)
        assert rc == 0                      # lax mode: warn only
        assert data["checked"] == 0 and data["regressions"] == []
        assert any("toy" in p for p in data["problems"])
        assert _cli().main(["--history-dir", str(tmp_path), "--strict"]) == 1

    def test_strict_fails_on_missing_or_corrupt(self, tmp_path):
        cli = _cli()
        empty = tmp_path / "empty"
        empty.mkdir()
        assert cli.main(["--history-dir", str(empty)]) == 0  # lax: warn only
        assert cli.main(["--history-dir", str(empty), "--strict"]) == 1
        bad = tmp_path / "bad"
        bad.mkdir()
        (bad / "toy.jsonl").write_text("garbage\n")
        assert cli.main(["--history-dir", str(bad), "--strict"]) == 1
        missing = ["--history-dir", str(tmp_path), "--bench", "nope", "--strict"]
        assert cli.main(missing) == 1


# ---------------------------------------------------------------------------
# candidate cost / roofline accounting
# ---------------------------------------------------------------------------


class TestCandidateCost:
    def test_roofline_fraction_math(self):
        from repro.launch.roofline import HBM_BW, PEAK_FLOPS
        from repro.tune.measure import roofline_fraction

        # memory-bound: floor = bytes/BW; 1 s of HBM traffic in 2 s -> 0.5
        assert roofline_fraction(0.0, HBM_BW, 2000.0) == pytest.approx(0.5)
        # compute-bound: floor = flops/peak
        assert roofline_fraction(PEAK_FLOPS, 0.0, 1000.0) == pytest.approx(1.0)
        assert roofline_fraction(1.0, 1.0, 0.0) == 0.0
        assert roofline_fraction(1.0, 1.0, float("inf")) == 0.0

    def test_measure_candidate_carries_cost(self):
        import jax.numpy as jnp

        from repro.tune.measure import bucket_pad_records, measure_candidate
        from repro.tune.space import WorkloadShape, search_space

        enc = breadth_first_encode(paper_tree())
        rec = jnp.asarray(_records(64, 19))
        shape = WorkloadShape.of(rec, enc)
        rec = bucket_pad_records(rec, shape.bucket().m)
        cand = next(iter(search_space(shape)))
        m = measure_candidate(cand, rec, enc, max_depth=shape.depth,
                              warmup=1, iters=2)
        assert not m.failed
        assert m.cost is not None
        # tree kernels are compare/gather programs: bytes carry the signal,
        # dot/conv FLOPs are ~0 — assert the memory side, not the flop side
        assert m.cost["bytes"] > 0
        assert m.cost["flops"] >= 0
        assert m.cost["roofline_frac"] >= 0
        assert m.mad_ms >= 0.0

    def test_tune_workload_publishes_cost_gauges(self, tmp_path):
        from repro.tune import tune_workload

        enc = breadth_first_encode(paper_tree())
        r = obs.Registry()
        entry, ms = tune_workload(_records(64, 19), enc,
                                  cache=TuneCache(tmp_path / "c.json"),
                                  warmup=0, iters=1, registry=r)
        assert any(m.cost is not None for m in ms if not m.failed)
        snap = obs.snapshot(r)
        byte_series = {k: v for k, v in snap["gauges"].items()
                       if k.startswith("tune.candidate_bytes")}
        roof_series = [k for k in snap["gauges"] if k.startswith("tune.roofline_frac")]
        assert byte_series and roof_series
        assert any(v > 0 for v in byte_series.values())
        assert any(f'variant="{entry.variant}"' in k for k in byte_series)


# ---------------------------------------------------------------------------
# cascade host-compaction instrumentation
# ---------------------------------------------------------------------------


class TestCascadeCompaction:
    def test_registry_and_tracer_thread_through(self):
        trees = [breadth_first_encode(random_tree(n_attrs=9, n_classes=6,
                                                  max_depth=2 + (i % 4), seed=i))
                 for i in range(8)]
        forest = EncodedForest(trees)
        rec = _records(256, 9)
        r, t = obs.Registry(), obs.Tracer()
        res = eval_forest_cascade(forest, rec, n_classes=6, stages=3,
                                  bound=1.0, registry=r, tracer=t)
        assert np.asarray(res.classes).shape == (256,)
        snap = obs.snapshot(r)
        compact = {k: v for k, v in snap["histograms"].items()
                   if k.startswith("cascade.compact_ms")}
        assert compact, "cascade.compact_ms never observed"
        assert sum(v["count"] for v in compact.values()) >= 1
        spans = [ev for ev in t.chrome_trace()["traceEvents"]
                 if ev.get("name") == "cascade.compact"]
        phases = {ev.get("args", {}).get("phase") for ev in spans}
        assert {"gather", "scatter"} <= phases


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


class TestFlightRecorder:
    def test_ring_breach_and_manual_dump(self, tmp_path):
        r = obs.Registry()
        pol = obs.FlightPolicy(slo_ms=5.0, capacity=4, out_dir=str(tmp_path),
                               min_dump_interval_s=0.0, dump_on_breach=False)
        fr = obs.FlightRecorder(pol, registry=r, engine="unit")
        assert fr.note_wave(latency_ms=1.0, bucket="b") is False
        for i in range(6):
            assert fr.note_wave(latency_ms=10.0 + i, records=8) is True
        waves = fr.waves()
        assert len(waves) == 4  # ring bounded by capacity
        assert all(w["breach"] for w in waves)
        snap = obs.snapshot(r)
        assert snap["counters"]['flight.slo_breaches{engine="unit"}'] == 6
        out = fr.dump("manual")
        bundle = json.loads((out / "flight.json").read_text())
        assert bundle["reason"] == "manual" and len(bundle["waves"]) == 4
        assert bundle["policy"]["slo_ms"] == 5.0
        assert bundle["metrics"]["counters"]['flight.slo_breaches{engine="unit"}'] == 6

    def test_no_slo_means_no_breach(self, tmp_path):
        fr = obs.FlightRecorder(obs.FlightPolicy(out_dir=str(tmp_path)))
        assert fr.note_wave(latency_ms=1e9) is False
        assert not list(tmp_path.glob("flight-*"))

    def test_exception_dumps_bundle(self, tmp_path):
        fr = obs.FlightRecorder(
            obs.FlightPolicy(out_dir=str(tmp_path), min_dump_interval_s=0.0),
            engine="unit")
        fr.note_exception(ValueError("boom"))
        bundles = list(tmp_path.glob("flight-unit-*-exception"))
        assert len(bundles) == 1
        bundle = json.loads((bundles[0] / "flight.json").read_text())
        assert bundle["waves"][-1]["exception"] == "ValueError"
        assert bundle["waves"][-1]["message"] == "boom"

    def test_dump_rate_limit(self, tmp_path):
        fr = obs.FlightRecorder(
            obs.FlightPolicy(slo_ms=0.001, out_dir=str(tmp_path),
                             min_dump_interval_s=3600.0),
            engine="unit")
        for _ in range(5):
            fr.note_wave(latency_ms=100.0)
        assert len(list(tmp_path.glob("flight-unit-*"))) == 1

    def test_rate_limit_survives_simultaneous_breaches(self, tmp_path):
        # two request threads breach at once: both breaches count, but the
        # window admits exactly one bundle — no dir collision, no double dump
        r = obs.Registry()
        fr = obs.FlightRecorder(
            obs.FlightPolicy(slo_ms=0.001, out_dir=str(tmp_path),
                             min_dump_interval_s=3600.0),
            registry=r, engine="unit")
        barrier = threading.Barrier(2)
        breached = []

        def breach():
            barrier.wait()
            breached.append(fr.note_wave(latency_ms=100.0, bucket="b"))

        ts = [threading.Thread(target=breach) for _ in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert breached == [True, True]
        bundles = list(tmp_path.glob("flight-unit-*"))
        assert len(bundles) == 1
        json.loads((bundles[0] / "flight.json").read_text())  # intact bundle
        snap = obs.snapshot(r)
        assert snap["counters"]['flight.slo_breaches{engine="unit"}'] == 2
        dumps = {k: v for k, v in snap["counters"].items()
                 if k.startswith("flight.dumps")}
        assert sum(dumps.values()) == 1
        # both waves still made the ring, dumped or not
        assert sum(1 for w in fr.waves() if w.get("breach")) == 2

    def test_drift_rides_the_ring_without_dumping(self, tmp_path):
        fr = obs.FlightRecorder(
            obs.FlightPolicy(out_dir=str(tmp_path), min_dump_interval_s=0.0),
            engine="unit")
        fr.note_drift(bucket="b", distance=0.42, engine="tree")
        assert not list(tmp_path.glob("flight-*"))      # context, not a dump
        w = fr.waves()[-1]
        assert w["drift"] is True and w["distance"] == 0.42
        assert w["bucket"] == "b" and w["engine"] == "tree"

    def test_serve_engine_slo_breach_produces_loadable_bundle(self, tmp_path):
        # the acceptance path: an unmeetable SLO on a real serve engine must
        # count breaches and drop a bundle whose Perfetto trace parses
        enc = breadth_first_encode(paper_tree())
        r, t = obs.Registry(), obs.Tracer()
        pol = obs.FlightPolicy(slo_ms=1e-6, out_dir=str(tmp_path / "fl"),
                               min_dump_interval_s=0.0)
        eng = TreeServeEngine(enc, max_batch=64,
                              cache=TuneCache(tmp_path / "c.json"),
                              retune=None, registry=r, tracer=t, flight=pol)
        reqs = [TreeRequest(uid=i, records=_records(50, 19, seed=i))
                for i in range(3)]
        out = eng.run(reqs)
        assert len(out) == 3

        snap = obs.snapshot(r)
        assert snap["counters"]['flight.slo_breaches{engine="tree"}'] > 0
        bundles = sorted((tmp_path / "fl").glob("flight-tree-*-slo_breach"))
        assert bundles
        flight = json.loads((bundles[-1] / "flight.json").read_text())
        assert flight["engine"] == "tree" and flight["reason"] == "slo_breach"
        assert flight["waves"] and flight["waves"][-1]["breach"] is True
        assert flight["waves"][-1]["records"] > 0
        trace = json.loads((bundles[-1] / "trace.json").read_text())
        events = trace["traceEvents"]
        assert events and all("ph" in ev for ev in events)
        assert all("ts" in ev for ev in events if ev["ph"] != "M")
        assert any(ev.get("name") == "serve.wave" for ev in events)
        # dump counters name the trigger
        snap = obs.snapshot(r)
        dumps = {k: v for k, v in snap["counters"].items()
                 if k.startswith("flight.dumps")}
        assert any('reason="slo_breach"' in k for k in dumps)
        # the explicit dump path works and bypasses nothing
        manual = eng.dump_flight("debug")
        assert (manual / "flight.json").exists()

    def test_dump_flight_without_recorder_raises(self, tmp_path):
        enc = breadth_first_encode(paper_tree())
        eng = TreeServeEngine(enc, max_batch=64,
                              cache=TuneCache(tmp_path / "c.json"), retune=None)
        with pytest.raises(RuntimeError):
            eng.dump_flight()
