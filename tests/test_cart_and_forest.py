"""CART trainer, forests, soft trees, and the §3.6 analysis models."""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    CartConfig,
    EncodedForest,
    SoftTreeConfig,
    accuracy,
    analysis,
    breadth_first_encode,
    eval_forest,
    eval_serial,
    harden,
    init_soft_tree,
    leaf_probs,
    load_balance_loss,
    majority_vote,
    output_probs,
    route_topk,
    train_cart,
    tree_depth,
    validate_encoding,
)
from repro.core.eval_speculative import eval_speculative
from repro.data.segmentation import make_segmentation, replicated_dataset


class TestCart:
    def test_separable_data_trains_to_high_accuracy(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(800, 10))
        y = ((x[:, 2] > 0.3).astype(int) * 2 + (x[:, 7] > -0.5).astype(int))
        root = train_cart(x, y, 4)
        enc = breadth_first_encode(root)
        validate_encoding(enc)
        assert accuracy(eval_serial(enc, x.astype(np.float32)), y) > 0.97

    def test_depth_limit_respected(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(500, 5))
        y = rng.integers(0, 4, size=500)
        root = train_cart(x, y, 4, CartConfig(max_depth=3))
        assert root.depth() <= 3

    def test_segmentation_twin_matches_paper_cardinalities(self):
        data = make_segmentation(seed=0)
        assert data.x_train.shape == (2310, 19)
        assert data.x_test.shape == (2099, 19)
        assert set(np.unique(data.y_train)) <= set(range(7))
        xr, yr = replicated_dataset(data)
        assert xr.shape == (65_536, 19)

    def test_segmentation_tree_geometry_class(self):
        """Trained tree lands in the paper's geometry class (N≈31, depth≈11)."""
        data = make_segmentation(seed=0)
        root = train_cart(
            data.x_train, data.y_train, 7,
            CartConfig(max_depth=12, min_samples_split=8, min_gain=4e-3),
        )
        enc = breadth_first_encode(root)
        validate_encoding(enc)
        assert 15 <= enc.n_nodes <= 127
        assert 4 <= tree_depth(enc) <= 12
        acc = accuracy(eval_serial(enc, data.x_test), data.y_test)
        assert acc > 0.75   # generalizes: classes are separable mixtures


class TestForest:
    def test_majority_vote(self):
        rng = np.random.default_rng(2)
        x = rng.normal(size=(600, 8))
        y = (x[:, 0] > 0).astype(int)
        roots = [
            train_cart(x[i::3], y[i::3], 2, CartConfig(max_depth=4)) for i in range(3)
        ]
        forest = EncodedForest.from_nodes(roots)
        per_tree = eval_forest(forest, x.astype(np.float32))
        assert per_tree.shape == (3, 600)
        vote = majority_vote(per_tree, 2)
        assert accuracy(np.asarray(vote), y) > 0.9

    def test_route_topk_shape(self):
        rng = np.random.default_rng(3)
        x = rng.normal(size=(100, 8))
        roots = [
            train_cart(x, rng.integers(0, 8, 100), 8, CartConfig(max_depth=3))
            for _ in range(4)
        ]
        forest = EncodedForest.from_nodes(roots)
        routes = route_topk(eval_forest(forest, x.astype(np.float32)))
        assert routes.shape == (100, 4)
        assert int(jnp.max(routes)) < 8


class TestSoftTree:
    def test_leaf_probs_normalize(self):
        cfg = SoftTreeConfig(depth=3, in_features=16, n_outputs=8)
        params = init_soft_tree(cfg, jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (32, 16))
        lp = leaf_probs(cfg, params, x)
        assert lp.shape == (32, 8)
        np.testing.assert_allclose(np.asarray(lp.sum(-1)), 1.0, rtol=1e-5)

    def test_hardened_tree_matches_soft_argmax_at_low_temperature(self):
        cfg = SoftTreeConfig(depth=3, in_features=8, n_outputs=8, temperature=1e-4)
        params = init_soft_tree(cfg, jax.random.key(2))
        x = jax.random.normal(jax.random.key(3), (200, 8))
        soft_choice = np.asarray(jnp.argmax(output_probs(cfg, params, x), -1))
        enc = harden(cfg, params)
        validate_encoding(enc)
        z = np.asarray(x @ params.proj)
        hard_choice = np.asarray(eval_serial(enc, z))
        assert np.array_equal(soft_choice, hard_choice)

    def test_hardened_speculative_equals_serial(self):
        cfg = SoftTreeConfig(depth=4, in_features=12, n_outputs=16)
        params = init_soft_tree(cfg, jax.random.key(4))
        x = jax.random.normal(jax.random.key(5), (128, 12))
        enc = harden(cfg, params)
        z = np.asarray(x @ params.proj, np.float32)
        ref = eval_serial(enc, z)
        out = eval_speculative(
            jnp.asarray(z), jnp.asarray(enc.attr_idx), jnp.asarray(enc.threshold),
            jnp.asarray(enc.child), jnp.asarray(enc.class_val),
            max_depth=4, use_onehot_matmul=True,
        )
        assert np.array_equal(np.asarray(out), ref)

    def test_load_balance_loss_uniform_is_minimal(self):
        uniform = jnp.full((64, 8), 1 / 8)
        skewed = jnp.zeros((64, 8)).at[:, 0].set(1.0)
        assert float(load_balance_loss(uniform)) < float(load_balance_loss(skewed))


class TestAnalysis:
    """§3.6 closed forms + equation (1) crossover."""

    def test_serial_time_linear_in_m_and_depth(self):
        assert analysis.t2_serial(100, 5) == 2 * analysis.t2_serial(50, 5)
        assert analysis.t2_serial(100, 10) == 2 * analysis.t2_serial(100, 5)

    def test_s3_speedup_approaches_p_with_free_memory(self):
        s = analysis.s3_speedup(10_000, 11, 64)
        assert abs(s - 64) < 1e-6

    def test_s3_saturates_with_slow_memory(self):
        cm = analysis.CostModel(sigma=10.0)
        assert analysis.s3_speedup(10_000, 11, 1024, cm) < 3

    def test_crossover_equation_1(self):
        # p < 2 d / (1 + log2 d)
        for d in (4, 11, 64):
            bound = analysis.crossover_group_size(d)
            assert abs(bound - 2 * d / (1 + math.log2(d))) < 1e-9
            assert analysis.speculative_wins(d, bound - 0.01)
            assert not analysis.speculative_wins(d, bound + 0.01)

    def test_paper_conclusion_p16_d11_loses_in_theory(self):
        """Paper §3.6: at p=16, d_µ=11 the idealized model says speculative
        should NOT win — the SIMD experiments then show it does (§4.3), which
        is the entire point of the paper."""
        assert not analysis.speculative_wins(11.0, 16)

    def test_t5_vs_t3_closed_form(self):
        t3 = analysis.t3_data_parallel(65_536, 11, 256)
        t5 = analysis.t5_speculative(65_536, 11, 256, 16)
        s3 = analysis.s3_speedup(65_536, 11, 256)
        s5 = analysis.s5_speedup(65_536, 11, 256, 16)
        assert t3 < t5              # independent-processor model favors P3
        assert s5 < s3

    def test_observed_depths(self):
        enc = breadth_first_encode(
            train_cart(*_toy_xy(), 2, CartConfig(max_depth=5))
        )
        rec = _toy_xy()[0].astype(np.float32)
        depths = analysis.observed_depths(enc, rec)
        assert depths.min() >= 1
        assert depths.max() <= tree_depth(enc)
        d_mu = analysis.mean_traversal_depth(depths)
        assert 1 <= d_mu <= tree_depth(enc)


def _toy_xy():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(300, 6))
    y = (x[:, 1] > 0).astype(int)
    return x, y
