"""Layer-level unit tests: attention (blockwise vs direct), RoPE/M-RoPE,
SSM scan vs recurrence, mLSTM chunked vs step, MoE dispatch, schema."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, XLSTMConfig
from repro.models import schema as sch
from repro.models.layers import attention as attn
from repro.models.layers import moe as moel
from repro.models.layers import ssm as ssml
from repro.models.layers import xlstm as xl
from repro.models.layers.rope import apply_mrope, apply_rope, positions_for
from repro.parallel.sharding import single_device_axes

AXES = single_device_axes()


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


class TestAttention:
    def _qkv(self, cfg, sq=64, sk=64, seed=0):
        k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
        hd = cfg.head_dim_
        q = jax.random.normal(k1, (2, sq, cfg.n_heads, hd), jnp.float32)
        k = jax.random.normal(k2, (2, sk, cfg.n_kv_heads, hd), jnp.float32)
        v = jax.random.normal(k3, (2, sk, cfg.n_kv_heads, hd), jnp.float32)
        return q, k, v

    def test_blockwise_equals_direct_causal(self):
        cfg = _cfg()
        q, k, v = self._qkv(cfg)
        mask = attn.causal_mask(64, 64)[None, None, None]
        ref = attn._grouped_attention(q, k, v, mask, cfg)
        for bk in (8, 16, 64):
            out = attn.blockwise_attention(q, k, v, cfg=cfg, causal=True, kv_block=bk)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_blockwise_sliding_window(self):
        cfg = _cfg(sliding_window=16)
        q, k, v = self._qkv(cfg)
        mask = attn.causal_mask(64, 64, window=16)[None, None, None]
        ref = attn._grouped_attention(q, k, v, mask, cfg)
        out = attn.blockwise_attention(q, k, v, cfg=cfg, causal=True, window=16, kv_block=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_is_global_flag_lifts_window(self):
        cfg = _cfg(sliding_window=8)
        q, k, v = self._qkv(cfg)
        full = attn.blockwise_attention(q, k, v, cfg=cfg, causal=True, window=8,
                                        is_global=jnp.asarray(True), kv_block=16)
        ref = attn.blockwise_attention(q, k, v, cfg=cfg, causal=True, window=0, kv_block=16)
        np.testing.assert_allclose(np.asarray(full), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_decode_matches_full_attention_row(self):
        cfg = _cfg()
        axes = AXES
        params = sch.init_params(attn.attn_schema(cfg, axes), jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (2, 10, cfg.d_model), jnp.float32)
        pos = positions_for(2, 10, style="rope")
        full = attn.attention(params, x, cfg=cfg, positions=pos)
        # replay the last token through the decode path
        cache = attn.KVCache(
            k=jnp.zeros((2, 16, cfg.n_kv_heads, cfg.head_dim_)),
            v=jnp.zeros((2, 16, cfg.n_kv_heads, cfg.head_dim_)),
        )
        xs, _, _ = attn._project_qkv(params, x[:, :9], None, cfg, pos[:, :9])
        _, k9, v9 = attn._project_qkv(params, x[:, :9], None, cfg, pos[:, :9])
        cache = attn.KVCache(k=cache.k.at[:, :9].set(k9), v=cache.v.at[:, :9].set(v9))
        out, _ = attn.attention_decode(
            params, x[:, 9:10], cache, jnp.asarray(9, jnp.int32),
            cfg=cfg, positions=pos[:, 9:10],
        )
        np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, 9]),
                                   rtol=1e-4, atol=1e-4)

    def test_gqa_grouping_matches_repeated_heads(self):
        """GQA einsum == full MHA with repeated KV heads."""
        cfg = _cfg(n_heads=4, n_kv_heads=2)
        q, k, v = self._qkv(cfg, sq=16, sk=16)
        out = attn.grouped_attention(q, k, v, cfg=cfg, causal=True)
        cfg_full = _cfg(n_heads=4, n_kv_heads=4)
        k_rep = jnp.repeat(k, 2, axis=2)
        v_rep = jnp.repeat(v, 2, axis=2)
        ref = attn.grouped_attention(q, k_rep, v_rep, cfg=cfg_full, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestRope:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.key(0), (2, 8, 4, 32))
        pos = positions_for(2, 8, style="rope")
        y = apply_rope(x, pos, theta=10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)

    def test_rope_relative_property(self):
        """<R(p)q, R(k)k'> depends only on p-k."""
        hd = 32
        q = jax.random.normal(jax.random.key(1), (1, 1, 1, hd))
        k = jax.random.normal(jax.random.key(2), (1, 1, 1, hd))
        def dot_at(pq, pk):
            pos_q = jnp.full((1, 1), pq, jnp.int32)
            pos_k = jnp.full((1, 1), pk, jnp.int32)
            qr = apply_rope(q, pos_q, theta=1e4)
            kr = apply_rope(k, pos_k, theta=1e4)
            return float(jnp.sum(qr * kr))
        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
        assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6

    def test_mrope_text_positions_reduce_to_rope(self):
        x = jax.random.normal(jax.random.key(3), (2, 8, 4, 32))
        pos1 = positions_for(2, 8, style="rope")
        pos3 = positions_for(2, 8, style="mrope")
        a = apply_rope(x, pos1, theta=1e4)
        b = apply_mrope(x, pos3, theta=1e4, sections=(8, 4, 4))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


class TestSSM:
    def test_scan_matches_stepwise_decode(self):
        cfg = _cfg(family="hybrid", ssm=SSMConfig(state_dim=4, conv_width=4, expand=2))
        params = sch.init_params(ssml.ssm_schema(cfg, AXES), jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model), jnp.float32) * 0.5
        full = ssml.ssm_apply(params, x, cfg=cfg, axes=AXES, chunk=4)
        # stepwise
        d_in = cfg.ssm.expand * cfg.d_model
        state = ssml.SSMState(conv=jnp.zeros((2, 3, d_in)),
                              h=jnp.zeros((2, d_in, 4)))
        outs = []
        for t in range(12):
            o, state = ssml.ssm_decode(params, x[:, t:t+1], state, cfg=cfg)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=5e-3, atol=5e-3)

    def test_chunk_size_invariance(self):
        cfg = _cfg(family="hybrid", ssm=SSMConfig(state_dim=4))
        params = sch.init_params(ssml.ssm_schema(cfg, AXES), jax.random.key(2))
        x = jax.random.normal(jax.random.key(3), (1, 16, cfg.d_model)) * 0.5
        a = ssml.ssm_apply(params, x, cfg=cfg, axes=AXES, chunk=4)
        b = ssml.ssm_apply(params, x, cfg=cfg, axes=AXES, chunk=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


class TestXLSTM:
    def test_mlstm_chunked_matches_decode(self):
        cfg = _cfg(family="ssm", d_ff=0, n_heads=4, n_kv_heads=4,
                   xlstm=XLSTMConfig(conv_width=4))
        params = sch.init_params(xl.mlstm_schema(cfg, AXES), jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model)) * 0.5
        full = xl.mlstm_apply(params, x, cfg=cfg, axes=AXES, chunk=4)
        d_in, h, dh = xl._mdims(cfg)
        state = xl.MLSTMState(
            c=jnp.zeros((2, h, dh, dh)), n=jnp.zeros((2, h, dh)),
            m=jnp.full((2, h), -1e30), conv=jnp.zeros((2, 3, d_in)))
        outs = []
        for t in range(12):
            o, state = xl.mlstm_decode(params, x[:, t:t+1], state, cfg=cfg)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=2e-2, atol=2e-2)

    def test_mlstm_return_state_seeds_decode(self):
        cfg = _cfg(family="ssm", d_ff=0, n_heads=4, n_kv_heads=4,
                   xlstm=XLSTMConfig(conv_width=4))
        params = sch.init_params(xl.mlstm_schema(cfg, AXES), jax.random.key(2))
        x = jax.random.normal(jax.random.key(3), (1, 8, cfg.d_model)) * 0.5
        x_next = jax.random.normal(jax.random.key(4), (1, 1, cfg.d_model)) * 0.5
        _, state = xl.mlstm_apply(params, x, cfg=cfg, axes=AXES, chunk=4, return_state=True)
        out_a, _ = xl.mlstm_decode(params, x_next, state, cfg=cfg)
        # reference: run 9 tokens stepwise
        d_in, h, dh = xl._mdims(cfg)
        st = xl.MLSTMState(c=jnp.zeros((1, h, dh, dh)), n=jnp.zeros((1, h, dh)),
                           m=jnp.full((1, h), -1e30), conv=jnp.zeros((1, 3, d_in)))
        for t in range(8):
            _, st = xl.mlstm_decode(params, x[:, t:t+1], st, cfg=cfg)
        out_b, _ = xl.mlstm_decode(params, x_next, st, cfg=cfg)
        np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), rtol=2e-2, atol=2e-2)

    def test_slstm_return_state(self):
        cfg = _cfg(family="ssm", d_ff=0, xlstm=XLSTMConfig())
        params = sch.init_params(xl.slstm_schema(cfg, AXES), jax.random.key(5))
        x = jax.random.normal(jax.random.key(6), (2, 6, cfg.d_model)) * 0.5
        out, state = xl.slstm_apply(params, x, cfg=cfg, axes=AXES, return_state=True)
        x_next = jax.random.normal(jax.random.key(7), (2, 1, cfg.d_model)) * 0.5
        o1, _ = xl.slstm_decode(params, x_next, state, cfg=cfg)
        # stepwise reference
        st = xl.SLSTMState(c=jnp.zeros((2, cfg.d_model)), n=jnp.zeros((2, cfg.d_model)),
                           h=jnp.zeros((2, cfg.d_model)), m=jnp.full((2, cfg.d_model), -1e30))
        for t in range(6):
            _, st = xl.slstm_decode(params, x[:, t:t+1], st, cfg=cfg)
        o2, _ = xl.slstm_decode(params, x_next, st, cfg=cfg)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)


class TestMoE:
    def _setup(self, router="softmax", e=4, k=2):
        cfg = _cfg(family="moe", d_ff=0,
                   moe=MoEConfig(n_experts=e, top_k=k, d_ff=64, router=router,
                                 capacity_factor=8.0))
        params = sch.init_params(moel.moe_schema(cfg, AXES), jax.random.key(0))
        return cfg, params

    def test_output_shape_and_finite(self):
        cfg, params = self._setup()
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
        y, aux = moel.moe_apply(params, x, cfg=cfg, axes=AXES, group_size=16)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        assert float(aux) >= 0

    def test_uncapped_capacity_routes_all_tokens(self):
        """With generous capacity, combine weights sum to ~1 per token."""
        cfg, params = self._setup()
        x = jax.random.normal(jax.random.key(2), (1, 32, cfg.d_model), jnp.float32)
        probs = moel.router_probs(params, x.reshape(1, 32, -1), cfg=cfg, e_pad=4)
        np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-4)

    def test_tree_router_probs_match_soft_tree(self):
        cfg, params = self._setup(router="tree")
        x = jax.random.normal(jax.random.key(3), (1, 8, cfg.d_model), jnp.float32)
        probs = moel.router_probs(params, x, cfg=cfg, e_pad=4)
        np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-4)

    def test_hard_tree_route_in_range(self):
        cfg, params = self._setup(router="tree", e=8, k=2)
        x = jax.random.normal(jax.random.key(4), (2, 64, cfg.d_model), jnp.float32)
        experts = moel.hard_tree_route(params, x, cfg=cfg, e_pad=8)
        assert experts.shape == (2, 64)
        assert int(jnp.min(experts)) >= 0 and int(jnp.max(experts)) < 8


class TestSchema:
    def test_param_count_matches_materialized(self):
        cfg = _cfg()
        from repro.models.api import build_model
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        n_live = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        n_schema = sch.param_count(m.schema())
        assert n_live == n_schema

    def test_cast_for_compute_keeps_f32_by_design(self):
        params = {
            "w": jnp.ones((4, 4), jnp.float32),
            "a_log": jnp.ones((4, 4), jnp.float32),
            "scale": jnp.ones((4,), jnp.float32),
        }
        out = sch.cast_for_compute(params, jnp.bfloat16)
        assert out["w"].dtype == jnp.bfloat16
        assert out["a_log"].dtype == jnp.float32
        assert out["scale"].dtype == jnp.float32
