"""Multi-device tests (sharding, collectives, elastic re-mesh, compression).

Each test runs in a fresh subprocess so XLA_FLAGS can force host devices
without contaminating the main pytest process (jax locks device count at
first init)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout=420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """The 2×2-mesh train step computes the same loss as one device."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
        from repro.models.api import build_model
        from repro.parallel import sharding as shd
        from repro.train.step import make_train_step
        from repro.optim.adamw import adamw_init
        from repro.data.pipeline import pipeline_for

        # vocab 512 pads identically on 1 device and on the 2-wide model
        # axis (lcm of 128 and 256), so both models share init shapes/values
        cfg = ModelConfig(name='t', family='dense', n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=512,
                          dtype='float32')
        pipe = pipeline_for(cfg, ShapeConfig('s', 16, 4, 'train'))
        batch = jax.tree.map(jnp.asarray, pipe(0))
        tcfg = TrainConfig(lr=1e-3, warmup_steps=0)

        # single-device reference
        m1 = build_model(cfg)
        p1 = m1.init(jax.random.key(0))
        s1 = jax.jit(make_train_step(m1, tcfg))
        _, _, met1 = s1(p1, adamw_init(p1), batch)

        mesh = jax.make_mesh((2, 2), ('data', 'model'))
        axes = shd.from_mesh(mesh)
        m2 = build_model(cfg, axes)
        with mesh:
            p2 = m2.init(jax.random.key(0))
            sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                        is_leaf=lambda s: isinstance(s, P))
            p2 = jax.device_put(p2, sh(m2.param_specs()))
            step = jax.jit(make_train_step(m2, tcfg))
            _, _, met2 = step(p2, adamw_init(p2), batch)
        l1, l2 = float(met1['loss']), float(met2['loss'])
        assert abs(l1 - l2) / abs(l1) < 1e-4, (l1, l2)
        print('OK', l1, l2)
    """)
    assert "OK" in out


def test_multipod_mesh_and_tree_eval_sharded():
    """Paper evaluators under a (pod, data, model) mesh shard records."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core import breadth_first_encode, paper_tree, eval_serial
        from repro.core.eval_speculative import shard_eval_speculative
        from repro.core.eval_dataparallel import shard_eval_data_parallel

        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        enc = breadth_first_encode(paper_tree())
        rec = np.random.default_rng(0).normal(size=(64, 19)).astype(np.float32)
        ref = eval_serial(enc, rec)
        with mesh:
            out1 = shard_eval_speculative(enc, rec, max_depth=11, mesh=mesh)
            out2 = shard_eval_data_parallel(enc, rec, max_depth=11, mesh=mesh)
        assert np.array_equal(np.asarray(out1), ref)
        assert np.array_equal(np.asarray(out2), ref)
        print('OK')
    """)
    assert "OK" in out


def test_gradient_compression_cross_pod():
    """int8 compressed cross-pod mean: bounded error + error feedback
    converges the running average to the true mean."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.parallel.compression import cross_pod_compressed_mean, init_error_feedback

        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        rng = np.random.default_rng(0)
        # per-pod distinct gradients, replicated within pod
        g_np = rng.normal(size=(2, 64)).astype(np.float32)
        full = jnp.asarray(np.concatenate([g_np, g_np], 0).reshape(2, 2, 64).transpose(0,1,2))
        grads = {'w': jax.device_put(jnp.asarray(np.stack([g_np[0], g_np[1]])).repeat(2, 0).reshape(2,2,64)[:, 0],
                                      NamedSharding(mesh, P('pod')))}
        # simpler: value differs along pod axis only
        err = {'w': jnp.zeros((2, 64))}
        specs = {'w': P('pod')}
        true_mean = g_np.mean(0)
        acc = np.zeros(64)
        e = err
        for i in range(30):
            mean, e = cross_pod_compressed_mean(mesh, grads, e, specs)
            m = np.asarray(mean['w'])[0]
            acc += m
            # single-round error bounded by quantization step
            scale = np.abs(g_np).max() / 127
            assert np.abs(m - true_mean).max() < 2 * scale + 1e-6
        # error feedback: long-run average converges tighter
        assert np.abs(acc / 30 - true_mean).max() < 0.5 * scale + 1e-6
        print('OK')
    """)
    assert "OK" in out


def test_elastic_remesh_resharding():
    """Checkpoint restored onto a different mesh via device_put resharding."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.ckpt import checkpoint as ckpt
        from repro.train.loop import resize_mesh

        tree = {'w': jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        mesh_a = jax.make_mesh((8, 1), ('data', 'model'))
        mesh_b = jax.make_mesh((2, 4), ('data', 'model'))
        sharded = jax.device_put(tree, {'w': NamedSharding(mesh_a, P('data', None))})
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 0, sharded)
            restored, _ = ckpt.restore(
                d, 0, tree,
                shardings={'w': NamedSharding(mesh_b, P('data', 'model'))})
        assert restored['w'].sharding.mesh.shape == {'data': 2, 'model': 4}
        np.testing.assert_array_equal(np.asarray(restored['w']), np.asarray(tree['w']))
        # in-memory path
        moved = resize_mesh(sharded, {'w': NamedSharding(mesh_b, P(None, 'model'))})
        np.testing.assert_array_equal(np.asarray(moved['w']), np.asarray(tree['w']))
        print('OK')
    """)
    assert "OK" in out


def test_zero1_spec_shards_unsharded_dim():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import MeshAxes, zero1_spec

    axes = MeshAxes(batch=("data",), model="model", sizes={"data": 16, "model": 16})
    # replicated 2-D param gains a 'data' slice on its largest divisible dim
    out = zero1_spec(P(None, None), (64, 4096), axes)
    assert out == P(None, "data")
    # already-data-sharded spec is unchanged
    assert zero1_spec(P("data", None), (64, 64), axes) == P("data", None)
    # indivisible dims stay replicated
    assert zero1_spec(P(None,), (30,), axes) == P(None,)


def test_batch_axes_for_prefix_logic():
    from repro.parallel.sharding import MeshAxes

    axes = MeshAxes(batch=("pod", "data", "model"), model="model",
                    sizes={"pod": 2, "data": 16, "model": 16})
    # best-subset (not prefix): 256 prefers (data, model) over (pod, data)=32
    assert axes.batch_axes_for(256) == ("data", "model")
    assert axes.batch_axes_for(512) == ("pod", "data", "model")
    assert axes.batch_axes_for(32) == ("pod", "data")
    assert axes.batch_axes_for(1) is None
    assert axes.batch_axes_for(6) == ("pod",)
