"""repro.tune: cache round-trip, bucketing, heuristic vs §4 analysis, dispatch.

Dispatch correctness is the load-bearing property: whatever variant the
tuner or heuristic picks, ``tuned_eval`` must return class assignments
bit-identical to the branchless serial reference (Procedure 2).
"""

import json

import numpy as np
import pytest

from repro.core import Node, breadth_first_encode, eval_serial, paper_tree, random_tree
from repro.core.analysis import CostModel, speculative_wins
from repro.core.forest import EncodedForest
from repro.kernels.tree_eval import (
    FOREST_VARIANTS,
    PER_TREE_FAMILY,
    VARIANTS,
    get_forest_variant,
    get_variant,
)
from repro.tune import (
    Candidate,
    ForestShape,
    ForestTunedEvaluator,
    TuneCache,
    TuneEntry,
    TunedEvaluator,
    WorkloadShape,
    backend_tag,
    forest_heuristic_candidate,
    forest_search_space,
    heuristic_candidate,
    measured_d_mu,
    predicted_times,
    registry_fingerprint,
    search_space,
    tune_forest_workload,
    tuned_eval,
    tuned_eval_forest,
    tune_workload,
)

# hypothesis is optional: the shim runs a deterministic fixed-example sweep
# when the real package is not installed (see hypothesis_compat.py).
from hypothesis_compat import given, settings, st


def _records(m, a, seed=0):
    return np.random.default_rng(seed).normal(size=(m, a)).astype(np.float32)


# ---------------------------------------------------------------------------
# Shape bucketing
# ---------------------------------------------------------------------------


class TestShapeBucketing:
    def test_bucket_rounds_up(self):
        b = WorkloadShape(m=100, n_nodes=31, n_attrs=19, depth=11).bucket()
        assert b == WorkloadShape(m=128, n_nodes=128, n_attrs=128, depth=16)

    def test_bucket_idempotent(self):
        s = WorkloadShape(m=100, n_nodes=31, n_attrs=19, depth=11)
        assert s.bucket().bucket() == s.bucket()

    def test_nearby_shapes_share_bucket(self):
        a = WorkloadShape(m=100, n_nodes=31, n_attrs=19, depth=11)
        b = WorkloadShape(m=127, n_nodes=40, n_attrs=25, depth=9)
        assert a.key("cpu") == b.key("cpu")

    def test_distinct_shapes_distinct_keys(self):
        a = WorkloadShape(m=128, n_nodes=31, n_attrs=19, depth=11)
        b = WorkloadShape(m=129, n_nodes=31, n_attrs=19, depth=11)  # next pow2
        assert a.key("cpu") != b.key("cpu")
        assert a.key("cpu") != a.key("tpu")

    def test_of_derives_from_records_and_tree(self):
        enc = breadth_first_encode(paper_tree())
        s = WorkloadShape.of(_records(50, 19), enc)
        assert s == WorkloadShape(m=50, n_nodes=31, n_attrs=19, depth=11)


# ---------------------------------------------------------------------------
# Multi-backend cache keys: backend + device kind + topology
# ---------------------------------------------------------------------------


class TestBackendTag:
    def test_tag_carries_backend_kind_and_count(self):
        import jax

        tag = backend_tag()
        backend, kind, count = tag.split(":")
        assert backend == jax.default_backend()
        assert kind and "|" not in kind and " " not in kind
        assert count == f"x{jax.device_count()}"

    def test_key_defaults_to_backend_tag(self):
        s = WorkloadShape(m=100, n_nodes=31, n_attrs=19, depth=11)
        assert s.key() == s.key(backend_tag())
        # distinct topologies key distinct rows in one shared file
        assert s.key("tpu:v5e:x8") != s.key("tpu:v5p:x8") != s.key("cpu:cpu:x1")

    def test_dispatch_stores_under_backend_tag(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json")
        enc = breadth_first_encode(paper_tree())
        ev = TunedEvaluator(enc, cache=cache, autotune=True,
                            measure_kw={"warmup": 1, "iters": 2})
        ev(_records(32, 19, seed=21))
        assert len(cache) == 1
        assert cache.keys()[0].startswith(backend_tag() + "|")


# ---------------------------------------------------------------------------
# Search space
# ---------------------------------------------------------------------------


class TestSearchSpace:
    def test_candidates_only_registered_variants(self):
        shape = WorkloadShape(m=256, n_nodes=31, n_attrs=19, depth=6)
        cands = list(search_space(shape))
        assert cands, "search space must not be empty"
        for c in cands:
            assert c.variant in VARIANTS
            spec = get_variant(c.variant)
            assert set(c.param_dict) <= set(spec.tunables)

    def test_onehot_excluded_for_huge_trees(self):
        shape = WorkloadShape(m=256, n_nodes=100_000, n_attrs=19, depth=17)
        for c in search_space(shape):
            assert get_variant(c.variant).jump_mode != "onehot"

    def test_engine_filter(self):
        shape = WorkloadShape(m=256, n_nodes=31, n_attrs=19, depth=6)
        for c in search_space(shape, engines=("pallas",)):
            assert get_variant(c.variant).engine == "pallas"


# ---------------------------------------------------------------------------
# Cache: write → reload → hit
# ---------------------------------------------------------------------------


class TestCache:
    def test_round_trip(self, tmp_path):
        path = tmp_path / "cache.json"
        cache = TuneCache(path)
        entry = TuneEntry(
            variant="jnp_data_parallel", params={}, median_ms=1.25,
            shape={"m": 128, "n_nodes": 31, "n_attrs": 19, "depth": 11},
            backend="cpu",
        )
        cache.store("cpu|M128|N128|A128|d16", entry)
        assert path.exists()

        reloaded = TuneCache(path)
        hit = reloaded.lookup("cpu|M128|N128|A128|d16")
        assert hit is not None
        assert hit.variant == entry.variant
        assert hit.median_ms == entry.median_ms
        assert hit.shape == entry.shape
        assert reloaded.lookup("cpu|M999|N128|A128|d16") is None

    def test_params_preserved(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json")
        cache.store("k", TuneEntry(variant="jnp_speculative_gather",
                                   params={"jumps_per_round": 3}, median_ms=0.5))
        hit = TuneCache(tmp_path / "c.json").lookup("k")
        assert hit.params == {"jumps_per_round": 3}

    def test_corrupt_file_tolerated(self, tmp_path):
        path = tmp_path / "c.json"
        path.write_text("{not json")
        cache = TuneCache(path)
        assert len(cache) == 0
        cache.store("k", TuneEntry(variant="jnp_data_parallel", params={}, median_ms=1.0))
        assert TuneCache(path).lookup("k") is not None

    def test_version_mismatch_discarded(self, tmp_path):
        path = tmp_path / "c.json"
        path.write_text(json.dumps({"version": 999, "entries": {"k": {"variant": "x"}}}))
        assert TuneCache(path).lookup("k") is None

    def test_lru_front_bounded(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json", lru_size=2)
        for i in range(5):
            cache.store(f"k{i}", TuneEntry(variant="jnp_data_parallel",
                                           params={}, median_ms=float(i)))
        assert len(cache._lru) <= 2
        # evicted keys still resolve from the table
        assert cache.lookup("k0").median_ms == 0.0


# ---------------------------------------------------------------------------
# Registry-fingerprint invalidation: kernel rewrites drop stored winners
# ---------------------------------------------------------------------------


class TestRegistryInvalidation:
    ENTRY = TuneEntry(variant="jnp_data_parallel", params={}, median_ms=1.0)

    def test_fingerprint_stable_and_nonempty(self):
        assert registry_fingerprint()
        assert registry_fingerprint() == registry_fingerprint()

    def test_same_registry_round_trips(self, tmp_path):
        TuneCache(tmp_path / "c.json", registry="fp_a").store("k", self.ENTRY)
        assert TuneCache(tmp_path / "c.json", registry="fp_a").lookup("k") is not None

    def test_changed_registry_discards_entries(self, tmp_path):
        """A kernel rewrite (new fingerprint) must orphan every stored
        winner: its medians priced code that no longer exists."""
        TuneCache(tmp_path / "c.json", registry="fp_a").store("k", self.ENTRY)
        stale = TuneCache(tmp_path / "c.json", registry="fp_b")
        assert len(stale) == 0
        assert stale.lookup("k") is None
        # re-tuning on the new registry overwrites the file cleanly
        stale.store("k", self.ENTRY)
        assert TuneCache(tmp_path / "c.json", registry="fp_b").lookup("k") is not None
        assert TuneCache(tmp_path / "c.json", registry="fp_a").lookup("k") is None

    def test_default_registry_is_live_fingerprint(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json")
        assert cache.registry == registry_fingerprint()
        cache.store("k", self.ENTRY)
        assert TuneCache(tmp_path / "c.json").lookup("k") is not None


# ---------------------------------------------------------------------------
# Measured d_µ in the heuristic (vs the geometry prior)
# ---------------------------------------------------------------------------


def _shallow_exit_vine(depth: int = 14) -> "Node":
    """A depth-``depth`` vine whose root sends *every* record to a depth-1
    leaf: geometry prior d_µ ≈ (log₂N + depth)/2, measured d_µ = 1."""
    node = Node(attr=0, threshold=0.0, left=Node(class_val=0), right=Node(class_val=1))
    for _ in range(depth - 1):
        node = Node(attr=0, threshold=0.0, left=node, right=Node(class_val=2))
    # root: threshold -1e9 ⇒ r[0] > -1e9 for all finite records ⇒ go right
    return Node(attr=0, threshold=-1e9, left=node, right=Node(class_val=3))


class TestMeasuredDmu:
    def test_measured_d_mu_sees_shallow_traffic(self):
        enc = breadth_first_encode(_shallow_exit_vine())
        rec = _records(200, 5, seed=30)
        assert measured_d_mu(enc, rec) == 1.0

    def test_crossover_shifts_with_measured_d_mu(self, tmp_path):
        """Equation (1)'s crossover moves with d_µ: at p_group=4 the prior
        (d_µ ≈ 9.4) predicts speculative wins, the measured depth (d_µ = 1)
        predicts data decomposition.  Dispatch must follow the measurement."""
        from repro.tune.heuristic import default_d_mu

        enc = breadth_first_encode(_shallow_exit_vine(depth=14))
        rec = _records(64, 5, seed=31)
        shape = WorkloadShape.of(rec, enc)
        hk = {"cm": CostModel(t_e=1.0, t_c=1.0), "p_group": 4.0}

        prior = heuristic_candidate(shape, d_mu=default_d_mu(shape), **hk)
        measured = heuristic_candidate(shape, d_mu=measured_d_mu(enc, rec), **hk)
        assert get_variant(prior.variant).algorithm == "speculative"
        assert get_variant(measured.variant).algorithm == "data_parallel"

        ev_meas = TunedEvaluator(enc, cache=TuneCache(tmp_path / "a.json"),
                                 heuristic_kw=hk)
        cand, source = ev_meas.resolve(rec)
        assert source == "heuristic"
        assert get_variant(cand.variant).algorithm == "data_parallel"

        ev_prior = TunedEvaluator(enc, cache=TuneCache(tmp_path / "b.json"),
                                  measure_d_mu=False, heuristic_kw=hk)
        cand, _ = ev_prior.resolve(rec)
        assert get_variant(cand.variant).algorithm == "speculative"

        # either way, dispatch stays bit-identical to the serial reference
        assert np.array_equal(np.asarray(ev_meas(rec)), eval_serial(enc, rec))
        assert np.array_equal(np.asarray(ev_prior(rec)), eval_serial(enc, rec))


# ---------------------------------------------------------------------------
# Heuristic fallback vs the §4 analysis
# ---------------------------------------------------------------------------


class TestHeuristic:
    def test_model_choice_matches_crossover(self):
        """With t_e = t_c and no overheads, the model-predicted winner must
        flip exactly at equation (1): p < 2·d_µ/(1 + log₂ d_µ)."""
        cm = CostModel(t_e=1.0, t_c=1.0, t_i=0.0, sigma=0.0, gamma=0.0)
        shape = WorkloadShape(m=1024, n_nodes=31, n_attrs=19, depth=8)
        for d_mu in (2.0, 4.0, 8.0, 16.0, 32.0):
            for p_factor in (0.5, 0.9, 1.1, 2.0):
                from repro.core.analysis import crossover_group_size

                p = crossover_group_size(d_mu) * p_factor
                times = predicted_times(shape, cm=cm, d_mu=d_mu, p_group=p)
                model_says_spec = times["speculative"] < times["data_parallel"]
                assert model_says_spec == speculative_wins(d_mu, p), (d_mu, p)

    def test_heuristic_follows_synthetic_timings(self):
        """Feeding the cost model synthetic operating points drives the
        candidate's algorithm exactly as the analysis predicts."""
        cm = CostModel(t_e=1.0, t_c=1.0)
        shape = WorkloadShape(m=512, n_nodes=31, n_attrs=19, depth=8)
        # tiny record groups, deep traversals -> speculative wins
        c_spec = heuristic_candidate(shape, cm=cm, d_mu=30.0, p_group=2.0)
        assert get_variant(c_spec.variant).algorithm == "speculative"
        # huge groups, shallow traversals -> data decomposition wins
        c_dp = heuristic_candidate(shape, cm=cm, d_mu=2.0, p_group=500.0)
        assert get_variant(c_dp.variant).algorithm == "data_parallel"

    def test_heuristic_yields_valid_candidate(self):
        for depth, n in ((2, 7), (11, 31), (8, 511)):
            shape = WorkloadShape(m=256, n_nodes=n, n_attrs=19, depth=depth)
            c = heuristic_candidate(shape)
            spec = get_variant(c.variant)
            assert set(c.param_dict) <= set(spec.tunables)


# ---------------------------------------------------------------------------
# Dispatch correctness: bit-identical to the serial reference
# ---------------------------------------------------------------------------


class TestDispatch:
    def test_heuristic_path_bit_identical(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json")
        enc = breadth_first_encode(paper_tree())
        rec = _records(300, 19, seed=3)
        out = np.asarray(tuned_eval(rec, enc, cache=cache))
        assert out.dtype == np.int32
        assert np.array_equal(out, eval_serial(enc, rec))

    @given(
        seed=st.integers(0, 40),
        depth=st.integers(1, 9),
        balance=st.floats(0.3, 1.0),
        m=st.integers(1, 150),
    )
    @settings(max_examples=15, deadline=None)
    def test_randomized_trees_bit_identical(self, seed, depth, balance, m):
        enc = breadth_first_encode(
            random_tree(n_attrs=7, n_classes=5, max_depth=depth, seed=seed, balance=balance)
        )
        import tempfile
        from pathlib import Path

        rec = _records(m, 7, seed=seed + 1)
        cache = TuneCache(Path(tempfile.gettempdir()) / "repro_tune_test_absent.json")
        out = np.asarray(tuned_eval(rec, enc, cache=cache))
        assert np.array_equal(out, eval_serial(enc, rec))

    def test_autotuned_path_bit_identical_and_cached(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json")
        enc = breadth_first_encode(
            random_tree(n_attrs=5, n_classes=4, max_depth=5, seed=7)
        )
        rec = _records(64, 5, seed=8)
        ev = TunedEvaluator(enc, cache=cache, autotune=True,
                            measure_kw={"warmup": 1, "iters": 2})
        out = np.asarray(ev(rec))
        assert np.array_equal(out, eval_serial(enc, rec))
        assert len(cache) == 1  # winner persisted under the bucket key

        # a fresh evaluator on a fresh cache handle must hit, not re-tune
        ev2 = TunedEvaluator(enc, cache=TuneCache(tmp_path / "c.json"))
        _, source = ev2.resolve(rec)
        assert source == "cache"
        assert np.array_equal(np.asarray(ev2(rec)), eval_serial(enc, rec))

    def test_tune_workload_winner_is_measured_minimum(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json")
        enc = breadth_first_encode(paper_tree())
        rec = _records(32, 19, seed=9)
        entry, measurements = tune_workload(rec, enc, cache=cache, warmup=1, iters=2)
        ok = [m for m in measurements if not m.failed]
        assert entry.median_ms == min(m.median_ms for m in ok)
        assert entry.variant in VARIANTS

    def test_dispatch_stale_cache_variant_falls_back(self, tmp_path):
        """An entry naming a since-removed variant must not break dispatch."""
        cache = TuneCache(tmp_path / "c.json")
        enc = breadth_first_encode(paper_tree())
        rec = _records(40, 19, seed=10)
        key = WorkloadShape.of(rec, enc).key()  # default backend_tag
        cache.store(key, TuneEntry(variant="gone_variant", params={}, median_ms=1.0))
        ev = TunedEvaluator(enc, cache=cache)
        cand, source = ev.resolve(rec)
        assert source == "heuristic"
        assert np.array_equal(np.asarray(ev(rec)), eval_serial(enc, rec))

    def test_memo_source_on_second_resolve(self, tmp_path):
        enc = breadth_first_encode(paper_tree())
        rec = _records(16, 19)
        ev = TunedEvaluator(enc, cache=TuneCache(tmp_path / "c.json"))
        assert ev.resolve(rec)[1] == "heuristic"
        assert ev.resolve(rec)[1] == "memo"

    def test_explicit_candidate_params_respected(self):
        c = Candidate.make("jnp_speculative_gather", jumps_per_round=3)
        assert c.param_dict == {"jumps_per_round": 3}
        # frozen/hashable: usable as dict keys in resolution memos
        assert hash(c) == hash(Candidate.make("jnp_speculative_gather", jumps_per_round=3))


# ---------------------------------------------------------------------------
# Tuned forest + serving wiring
# ---------------------------------------------------------------------------


class TestWiring:
    def test_eval_forest_tuned_matches_serial(self, tmp_path):
        from repro.core import EncodedForest, eval_forest_tuned

        trees = [
            breadth_first_encode(random_tree(n_attrs=9, n_classes=6, max_depth=d, seed=d))
            for d in (2, 5, 8)
        ]
        forest = EncodedForest(trees)
        rec = _records(120, 9, seed=11)
        out = np.asarray(eval_forest_tuned(forest, rec, cache=TuneCache(tmp_path / "c.json")))
        assert out.shape == (3, 120)
        for i in range(3):
            assert np.array_equal(out[i], eval_serial(forest.tree(i), rec))

    def test_forest_shape_buckets_and_keys(self):
        s = ForestShape(t=3, m=100, n_nodes=31, n_attrs=19, depth_min=3, depth_max=11)
        b = s.bucket()
        assert b == ForestShape(t=4, m=128, n_nodes=128, n_attrs=128,
                                depth_min=4, depth_max=16)
        assert b.bucket() == b  # idempotent
        # forest keys are disjoint from per-tree keys in the shared cache
        tree_key = WorkloadShape(m=100, n_nodes=31, n_attrs=19, depth=11).key("cpu")
        assert s.key("cpu") != tree_key and "|T4|" in s.key("cpu")
        # the depth profile is part of the bucket identity
        flat = ForestShape(t=3, m=100, n_nodes=31, n_attrs=19, depth_min=11, depth_max=11)
        assert flat.key("cpu") != s.key("cpu")

    def test_forest_search_space_spans_three_families(self):
        shape = ForestShape(t=4, m=256, n_nodes=31, n_attrs=19, depth_min=6, depth_max=6)
        cands = list(forest_search_space(shape, engines=("pallas", "jnp")))
        variants = {c.variant for c in cands}
        assert PER_TREE_FAMILY in variants
        assert any(v.startswith("forest_vmap_") for v in variants)
        assert any(v.startswith("forest_fused_") for v in variants)
        for c in cands:
            if c.variant == PER_TREE_FAMILY:
                continue
            spec = get_forest_variant(c.variant)
            assert set(c.param_dict) <= set(spec.tunables)
        # onehot candidates vanish for huge trees, per_tree never does
        huge = ForestShape(t=4, m=256, n_nodes=100_000, n_attrs=19,
                           depth_min=17, depth_max=17)
        for c in forest_search_space(huge, engines=("pallas", "jnp")):
            if c.variant != PER_TREE_FAMILY:
                assert get_forest_variant(c.variant).jump_mode != "onehot"

    def test_forest_heuristic_profile_drives_family(self):
        """Homogeneous depth profiles go stacked (one launch, no padding
        waste); spread profiles flip to the per-tree vector."""
        uniform = ForestShape(t=8, m=1024, n_nodes=127, n_attrs=19,
                              depth_min=6, depth_max=6)
        c = forest_heuristic_candidate(uniform, d_mu=5.0)
        assert c.variant != PER_TREE_FAMILY
        spread = ForestShape(t=8, m=1024, n_nodes=127, n_attrs=19,
                             depth_min=1, depth_max=24)
        c = forest_heuristic_candidate(spread, d_mu=12.0, launch_overhead=1e-6)
        assert c.variant == PER_TREE_FAMILY
        # families filter is honoured
        c = forest_heuristic_candidate(spread, families=("vmap",))
        assert get_forest_variant(c.variant).family == "vmap"

    def test_forest_evaluator_bit_identical_all_families(self, tmp_path):
        trees = [
            breadth_first_encode(random_tree(n_attrs=9, n_classes=6, max_depth=d, seed=d))
            for d in (2, 5, 8)
        ]
        forest = EncodedForest(trees)
        rec = _records(150, 9, seed=40)
        ref = np.stack([eval_serial(forest.tree(i), rec) for i in range(3)])
        for families in ((PER_TREE_FAMILY,), ("vmap",), ("fused",), None):
            ev = ForestTunedEvaluator(
                forest, cache=TuneCache(tmp_path / "c.json"), families=families
            )
            out = np.asarray(ev(rec))
            assert out.shape == (3, 150)
            assert np.array_equal(out, ref), families

    def test_forest_autotune_persists_and_hits(self, tmp_path):
        trees = [
            breadth_first_encode(random_tree(n_attrs=7, n_classes=5, max_depth=4, seed=s))
            for s in (1, 2)
        ]
        forest = EncodedForest(trees)
        rec = _records(64, 7, seed=41)
        cache = TuneCache(tmp_path / "c.json")
        ev = ForestTunedEvaluator(forest, cache=cache, autotune=True,
                                  measure_kw={"warmup": 1, "iters": 2})
        ref = np.stack([eval_serial(forest.tree(i), rec) for i in range(2)])
        assert np.array_equal(np.asarray(ev(rec)), ref)
        # the forest winner landed under the forest bucket key
        fkey = ev.shape_of(rec).key()
        entry = cache.lookup(fkey)
        assert entry is not None
        assert entry.variant in FOREST_VARIANTS or entry.variant == PER_TREE_FAMILY

        # a fresh evaluator on a fresh cache handle must hit, not re-tune
        ev2 = ForestTunedEvaluator(forest, cache=TuneCache(tmp_path / "c.json"))
        cand, source = ev2.resolve(rec)
        assert source == "cache"
        assert cand.variant == entry.variant
        assert np.array_equal(np.asarray(ev2(rec)), ref)

    def test_family_restricted_evaluator_ignores_foreign_cache_hit(self, tmp_path):
        """A families-restricted evaluator must not run another family's
        cached winner (it would silently invalidate e.g. the per-tree
        baseline in the forest sweep bench)."""
        trees = [breadth_first_encode(random_tree(n_attrs=7, n_classes=5,
                                                  max_depth=4, seed=s))
                 for s in (8, 9)]
        forest = EncodedForest(trees)
        rec = _records(64, 7, seed=45)
        cache = TuneCache(tmp_path / "c.json")
        restricted = ForestTunedEvaluator(forest, cache=cache,
                                          families=(PER_TREE_FAMILY,))
        # a sibling evaluator cached the vmap winner under the same bucket
        cache.store(restricted.shape_of(rec).key(),
                    TuneEntry(variant="forest_vmap_data_parallel", params={},
                              median_ms=0.1))
        cand, source = restricted.resolve(rec)
        assert source == "heuristic"          # the foreign hit was refused
        assert cand.variant == PER_TREE_FAMILY
        # an unrestricted evaluator does take the hit
        cand, source = ForestTunedEvaluator(forest, cache=cache).resolve(rec)
        assert source == "cache" and cand.variant == "forest_vmap_data_parallel"

    def test_forest_stale_cache_entry_falls_back(self, tmp_path):
        trees = [breadth_first_encode(random_tree(n_attrs=5, n_classes=4,
                                                  max_depth=3, seed=s))
                 for s in (3, 4)]
        forest = EncodedForest(trees)
        rec = _records(32, 5, seed=42)
        cache = TuneCache(tmp_path / "c.json")
        ev = ForestTunedEvaluator(forest, cache=cache)
        cache.store(ev.shape_of(rec).key(),
                    TuneEntry(variant="gone_forest_variant", params={}, median_ms=1.0))
        cand, source = ev.resolve(rec)
        assert source == "heuristic"
        ref = np.stack([eval_serial(forest.tree(i), rec) for i in range(2)])
        assert np.array_equal(np.asarray(ev(rec)), ref)

    def test_tune_forest_workload_winner_is_measured_minimum(self, tmp_path):
        trees = [breadth_first_encode(random_tree(n_attrs=5, n_classes=4,
                                                  max_depth=4, seed=s))
                 for s in (5, 6, 7)]
        forest = EncodedForest(trees)
        rec = _records(48, 5, seed=43)
        entry, measurements = tune_forest_workload(
            rec, forest, cache=TuneCache(tmp_path / "c.json"), warmup=1, iters=2
        )
        ok = [m for m in measurements if not m.failed]
        assert entry.median_ms == min(m.median_ms for m in ok)
        variants = {m.candidate.variant for m in ok}
        assert PER_TREE_FAMILY in variants  # all families were really timed
        assert any(v in FOREST_VARIANTS for v in variants)

    def test_eval_forest_tuned_functional_wrapper(self, tmp_path):
        trees = [breadth_first_encode(random_tree(n_attrs=9, n_classes=6,
                                                  max_depth=d, seed=d))
                 for d in (2, 5, 8)]
        forest = EncodedForest(trees)
        rec = _records(120, 9, seed=44)
        out = np.asarray(tuned_eval_forest(rec, forest,
                                           cache=TuneCache(tmp_path / "c.json")))
        for i in range(3):
            assert np.array_equal(out[i], eval_serial(forest.tree(i), rec))

    def test_tree_serve_engine_waves(self, tmp_path):
        from repro.serve import TreeRequest, TreeServeEngine

        enc = breadth_first_encode(paper_tree())
        rng = np.random.default_rng(12)
        reqs = [
            TreeRequest(uid=i, records=rng.normal(size=(int(rng.integers(1, 100)), 19)).astype(np.float32))
            for i in range(9)
        ]
        eng = TreeServeEngine(enc, max_batch=256, cache=TuneCache(tmp_path / "c.json"))
        eng.run(reqs)
        assert eng.stats.waves >= 2
        assert eng.stats.records == sum(r.records.shape[0] for r in reqs)
        for r in reqs:
            assert r.done
            assert np.array_equal(r.out, eval_serial(enc, r.records))


# ---------------------------------------------------------------------------
# Quantized layouts in the tuner (opt-in candidates, cache identity, refusal)
# ---------------------------------------------------------------------------


class TestQuantLayoutTuning:
    SHAPE = ForestShape(t=4, m=256, n_nodes=31, n_attrs=19, depth_min=6, depth_max=6)

    def _forest(self, seeds=(8, 9)):
        trees = [breadth_first_encode(random_tree(n_attrs=7, n_classes=5,
                                                  max_depth=4, seed=s))
                 for s in seeds]
        return EncodedForest(trees)

    def test_quant_candidates_are_opt_in(self):
        default = {c.variant for c in
                   forest_search_space(self.SHAPE, engines=("pallas", "jnp"))}
        assert not any(v.endswith("_q") for v in default)

        cands = list(forest_search_space(self.SHAPE, engines=("pallas", "jnp"),
                                         layouts=("f32", "quant")))
        quant = [c for c in cands if c.variant.endswith("_q")]
        assert quant, "layouts opt-in must add quantized candidates"
        from repro.tune.space import QUANT_THR_DTYPES
        for c in quant:
            # thr_dtype is a cache-identity parameter: every quant candidate
            # must carry one so different node dtypes never collide.
            assert c.param_dict.get("thr_dtype") in QUANT_THR_DTYPES
        # both dtypes are actually enumerated
        assert {c.param_dict["thr_dtype"] for c in quant} == set(QUANT_THR_DTYPES)

        only_quant = {c.variant for c in
                      forest_search_space(self.SHAPE, engines=("pallas", "jnp"),
                                          layouts=("quant",))}
        assert only_quant and all(v.endswith("_q") for v in only_quant)
        assert PER_TREE_FAMILY not in only_quant  # per-tree rides on f32 tables

    def test_thr_dtype_is_candidate_identity(self):
        a = Candidate.make("forest_fused_speculative_q", block_m=256,
                           thr_dtype="bfloat16")
        b = Candidate.make("forest_fused_speculative_q", block_m=256,
                           thr_dtype="float16")
        assert a != b and hash(a) != hash(b)
        # and the dtype survives a cache round-trip inside the params blob
        assert a.param_dict["thr_dtype"] == "bfloat16"

    def test_thr_dtype_round_trips_through_cache(self, tmp_path):
        cache = TuneCache(tmp_path / "c.json")
        cache.store("k", TuneEntry(variant="forest_fused_speculative_q",
                                   params={"block_m": 256, "thr_dtype": "float16"},
                                   median_ms=0.5))
        hit = TuneCache(tmp_path / "c.json").lookup("k")
        assert hit.params == {"block_m": 256, "thr_dtype": "float16"}

    def test_default_evaluator_refuses_cached_quant_winner(self, tmp_path):
        """layouts=None means f32-only: a quant winner cached by an opted-in
        sibling must not be replayed by a default evaluator."""
        forest = self._forest()
        rec = _records(64, 7, seed=45)
        cache = TuneCache(tmp_path / "c.json")
        ev = ForestTunedEvaluator(forest, cache=cache)
        cache.store(ev.shape_of(rec).key(),
                    TuneEntry(variant="forest_fused_data_parallel_q",
                              params={"block_m": 256, "thr_dtype": "bfloat16"},
                              median_ms=0.01))
        cand, source = ev.resolve(rec)
        assert source == "heuristic"            # quant hit refused
        assert not cand.variant.endswith("_q")
        # an evaluator that opted into quant layouts does take the hit
        opted = ForestTunedEvaluator(forest, cache=cache,
                                     layouts=("f32", "quant"))
        cand, source = opted.resolve(rec)
        assert source == "cache"
        assert cand.variant == "forest_fused_data_parallel_q"
        # and its replay stays bit-exact
        ref = np.stack([eval_serial(forest.tree(i), rec) for i in range(2)])
        assert np.array_equal(np.asarray(opted(rec)), ref)

    def test_layout_restricted_winner_not_stored(self, tmp_path):
        """A layout-filtered autotune winner must not overwrite the bucket's
        unrestricted entry (same rule as family restriction)."""
        forest = self._forest(seeds=(10, 11))
        rec = _records(64, 7, seed=46)
        cache = TuneCache(tmp_path / "c.json")
        ev = ForestTunedEvaluator(forest, cache=cache, autotune=True,
                                  layouts=("quant",),
                                  engines=("pallas", "jnp"),  # quant is pallas-only
                                  measure_kw={"warmup": 1, "iters": 2})
        ref = np.stack([eval_serial(forest.tree(i), rec) for i in range(2)])
        assert np.array_equal(np.asarray(ev(rec)), ref)
        assert cache.lookup(ev.shape_of(rec).key()) is None

    def test_stale_version_quant_winner_discarded(self, tmp_path):
        """A CACHE_VERSION bump orphans stored winners — the medians priced
        node tables that predate the quantized registry."""
        from repro.tune.cache import CACHE_VERSION

        path = tmp_path / "c.json"
        path.write_text(json.dumps({
            "version": CACHE_VERSION - 1,
            "registry": registry_fingerprint(),
            "entries": {"k": {"variant": "forest_fused_speculative_q",
                              "params": {"thr_dtype": "bfloat16"},
                              "median_ms": 0.1}},
        }))
        assert TuneCache(path).lookup("k") is None

    def test_fingerprint_covers_layout(self):
        """The live fingerprint must change if a spec's layout tag changes:
        stored winners priced a registry where that name meant other tables."""
        import dataclasses as _dc

        spec = FOREST_VARIANTS["forest_fused_speculative_q"]
        assert spec.layout == "quant"
        registry_fingerprint.cache_clear()   # fingerprint is memoised
        fp = registry_fingerprint()
        FOREST_VARIANTS["forest_fused_speculative_q"] = _dc.replace(spec, layout="f32")
        registry_fingerprint.cache_clear()
        try:
            assert registry_fingerprint() != fp
        finally:
            FOREST_VARIANTS["forest_fused_speculative_q"] = spec
            registry_fingerprint.cache_clear()
        assert registry_fingerprint() == fp
