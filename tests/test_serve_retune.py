"""Serve-path background re-tune: hot-bucket promotion, atomic winner swap,
and the load-bearing property — a re-tune can NEVER change results, even
while evaluations run concurrently with the measurement and the swap
(every candidate is exact, so promotion only moves latency).
"""

import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np

from repro.core import breadth_first_encode, paper_tree, random_tree, eval_serial
from repro.core.forest import EncodedForest
from repro.kernels.tree_eval import FOREST_VARIANTS, PER_TREE_FAMILY
from repro.serve import BackgroundRetuner, ForestServeEngine, RetunePolicy, TreeRequest, TreeServeEngine
from repro.tune import Candidate, TuneCache, TunedEvaluator, WorkloadShape


def _records(m, a, seed=0):
    return np.random.default_rng(seed).normal(size=(m, a)).astype(np.float32)


def _requests(n, m, a, seed=0):
    rng = np.random.default_rng(seed)
    return [
        TreeRequest(uid=i, records=rng.normal(size=(m, a)).astype(np.float32))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Hot-bucket promotion
# ---------------------------------------------------------------------------


class TestHotBucketPromotion:
    def test_cold_buckets_never_measure(self, tmp_path):
        enc = breadth_first_encode(paper_tree())
        eng = TreeServeEngine(enc, max_batch=64,
                              cache=TuneCache(tmp_path / "c.json"),
                              retune=RetunePolicy(hot_waves=100))
        eng.run(_requests(5, 50, 19))
        eng.retuner.drain(timeout=60)
        assert eng.stats.retunes == 0
        assert len(eng.retuner.started) == 0
        assert len(eng.stats.bucket_waves) == 1  # same bucket every wave

    def test_hot_bucket_measured_once_and_promoted(self, tmp_path):
        enc = breadth_first_encode(paper_tree())
        cache = TuneCache(tmp_path / "c.json")
        eng = TreeServeEngine(enc, max_batch=64, cache=cache,
                              retune=RetunePolicy(hot_waves=3, warmup=1, iters=2))
        reqs = _requests(10, 50, 19, seed=1)
        eng.run(reqs)
        eng.retuner.drain(timeout=120)
        assert eng.retuner.errors == []
        assert eng.stats.retunes == 1          # promoted exactly once
        assert len(eng.retuner.started) == 1   # no duplicate launches

        # the measured winner is persisted under the hot bucket's key and
        # the evaluator's memo now carries it (the "retune" provenance)
        key = next(iter(eng.stats.bucket_waves))
        entry = cache.lookup(key)
        assert entry is not None
        cand, src = eng._eval._resolved[key]
        assert src == "retune"
        assert cand == Candidate.make(entry.variant, **entry.params)
        for r in reqs:
            assert np.array_equal(r.out, eval_serial(enc, r.records))

    def test_request_path_not_blocked_by_measurement(self, tmp_path):
        """note() must return immediately: a slow measurement runs on the
        worker thread while waves keep being served."""
        started = threading.Event()
        release = threading.Event()

        def slow_measure(batch):
            started.set()
            assert release.wait(timeout=60)
            return None

        promoted = []
        ret = BackgroundRetuner(slow_measure, lambda k, e: promoted.append(k),
                                RetunePolicy(hot_waves=1))
        batch = _records(8, 4)
        ret.note("bucket", batch)
        assert started.wait(timeout=60)
        # the worker is parked inside measure; further notes return instantly
        t0 = time.perf_counter()
        for _ in range(50):
            ret.note("bucket", batch)
        assert time.perf_counter() - t0 < 1.0
        release.set()
        ret.drain(timeout=60)
        assert promoted == ["bucket"]

    def test_failed_measurement_never_takes_serving_down(self, tmp_path):
        def broken(batch):
            raise RuntimeError("measurement exploded")

        ret = BackgroundRetuner(broken, lambda k, e: None, RetunePolicy(hot_waves=1))
        ret.note("bucket", _records(8, 4))
        ret.drain(timeout=60)
        assert ret.retunes == 0
        assert len(ret.errors) == 1 and "exploded" in str(ret.errors[0][1])


# ---------------------------------------------------------------------------
# Atomic winner swap
# ---------------------------------------------------------------------------


class TestAtomicSwap:
    def test_promote_swaps_resolution(self, tmp_path):
        enc = breadth_first_encode(paper_tree())
        ev = TunedEvaluator(enc, cache=TuneCache(tmp_path / "c.json"))
        rec = _records(64, 19, seed=2)
        before, _ = ev.resolve(rec)
        forced = Candidate.make("jnp_speculative_gather", jumps_per_round=3)
        assert before != forced
        key = WorkloadShape.of(rec, enc, ev.depth).key()
        ev.promote(key, forced)
        after, _ = ev.resolve(rec)
        assert after == forced
        assert np.array_equal(np.asarray(ev(rec)), eval_serial(enc, rec))

    def test_swap_under_concurrent_evaluation_is_bit_identical(self, tmp_path):
        """Readers racing a promote must only ever see correct results —
        either kernel, never a torn state."""
        enc = breadth_first_encode(
            random_tree(n_attrs=7, n_classes=5, max_depth=6, seed=9)
        )
        ev = TunedEvaluator(enc, cache=TuneCache(tmp_path / "c.json"))
        rec = _records(96, 7, seed=3)
        want = eval_serial(enc, rec)
        key = WorkloadShape.of(rec, enc, ev.depth).key()
        candidates = [
            Candidate.make("jnp_data_parallel"),
            Candidate.make("jnp_speculative_gather", jumps_per_round=2),
            Candidate.make("jnp_speculative_onehot", jumps_per_round=1),
        ]
        stop = threading.Event()
        failures: list = []

        def reader():
            while not stop.is_set():
                out = np.asarray(ev(rec))
                if not np.array_equal(out, want):
                    failures.append(out)
                    return

        threads = [threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        for i in range(30):
            ev.promote(key, candidates[i % len(candidates)])
            time.sleep(0.002)
        stop.set()
        for t in threads:
            t.join(timeout=60)
        assert failures == []


# ---------------------------------------------------------------------------
# End-to-end: engines re-tune under live traffic, results never change
# ---------------------------------------------------------------------------


class TestEngineRetuneBitIdentity:
    def test_tree_engine_concurrent_retune_bit_identity(self, tmp_path):
        """Serve waves while the background re-tune measures and swaps:
        every response must equal the serial reference."""
        enc = breadth_first_encode(paper_tree())
        eng = TreeServeEngine(enc, max_batch=128,
                              cache=TuneCache(tmp_path / "c.json"),
                              retune=RetunePolicy(hot_waves=2, warmup=1, iters=2))
        for round_ in range(6):  # re-tune fires mid-stream, traffic continues
            reqs = _requests(4, 100, 19, seed=round_)
            eng.run(reqs)
            for r in reqs:
                assert np.array_equal(r.out, eval_serial(enc, r.records)), round_
        eng.retuner.drain(timeout=120)
        assert eng.retuner.errors == []
        assert eng.stats.retunes >= 1
        # post-swap traffic still exact
        reqs = _requests(3, 100, 19, seed=99)
        eng.run(reqs)
        for r in reqs:
            assert np.array_equal(r.out, eval_serial(enc, r.records))

    def test_forest_engine_retune_promotes_forest_bucket(self, tmp_path):
        trees = [
            breadth_first_encode(random_tree(n_attrs=9, n_classes=6, max_depth=d, seed=d))
            for d in (3, 5, 7)
        ]
        forest = EncodedForest(trees)
        cache = TuneCache(tmp_path / "c.json")
        eng = ForestServeEngine(forest, max_batch=128, chunk_records=128,
                                cache=cache,
                                retune=RetunePolicy(hot_waves=2, warmup=1, iters=2))
        for round_ in range(5):
            reqs = _requests(1, 100, 9, seed=round_)
            eng.run(reqs)
            for r in reqs:
                per = np.stack([eval_serial(forest.tree(i), r.records)
                                for i in range(forest.n_trees)])
                assert np.array_equal(r.out, per), round_
        eng.retuner.drain(timeout=240)
        assert eng.retuner.errors == []
        assert eng.stats.retunes >= 1
        # the forest bucket key now holds a measured family winner
        key = next(iter(eng.stats.bucket_waves))
        entry = cache.lookup(key)
        assert entry is not None
        assert entry.variant in FOREST_VARIANTS or entry.variant == PER_TREE_FAMILY
        # and post-promotion traffic is still exact
        reqs = _requests(1, 100, 9, seed=77)
        eng.run(reqs)
        per = np.stack([eval_serial(forest.tree(i), reqs[0].records)
                        for i in range(forest.n_trees)])
        assert np.array_equal(reqs[0].out, per)

    def test_mesh_executor_retune_stores_shard_key(self):
        """On a real mesh the re-tune must measure at the *shard* operating
        point and store under the key _shard_kernel probes — otherwise the
        background measurement is a no-op for multi-device serving."""
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        code = textwrap.dedent("""
            import numpy as np, jax, tempfile, pathlib
            from repro.core import EncodedForest, breadth_first_encode, random_tree, eval_serial
            from repro.dist import ShardedForestEvaluator, ShardPlan
            from repro.kernels.tree_eval import FOREST_VARIANTS
            from repro.tune import TuneCache

            assert jax.device_count() == 8
            trees = [breadth_first_encode(random_tree(n_attrs=9, n_classes=6,
                                                      max_depth=5, seed=i))
                     for i in range(8)]
            forest = EncodedForest(trees)
            rec = np.random.default_rng(3).normal(size=(512, 9)).astype(np.float32)
            oracle = np.stack([np.asarray(eval_serial(forest.tree(i), rec))
                               for i in range(8)])
            cache = TuneCache(pathlib.Path(tempfile.mkdtemp()) / 'c.json')
            plan = ShardPlan(record_shards=4, tree_shards=2,
                             algorithm='data_parallel', predicted=0.0)
            ev = ShardedForestEvaluator(forest, plan=plan, cache=cache)
            assert np.array_equal(np.asarray(ev(rec)), oracle)
            pre_source = ev.resolved[1]

            entry = ev.retune(rec, warmup=1, iters=2)
            assert entry.variant in FOREST_VARIANTS, entry.variant
            ev.invalidate_resolution()
            assert np.array_equal(np.asarray(ev(rec)), oracle)
            cand, source = ev.resolved
            # the promoted shard-shape winner is what resolution now finds
            assert source == 'cache', (pre_source, source)
            assert cand.variant == entry.variant, (cand, entry)
            print('OK')
        """)
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, timeout=420, env=env)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout
