"""Pallas tree-evaluation kernels vs the pure-jnp oracle (ref.py).

Shape/dtype sweeps in interpret mode per the kernel-validation contract:
records M ∈ {1, 7, 8, 100, 1000}, attrs A ∈ {1, 19, 130}, trees from depth 1
to 10, dtypes f32/bf16, both algorithms × both jump modes.
"""

import numpy as np
import pytest
import jax.numpy as jnp

# hypothesis is optional: the shim runs a deterministic fixed-example sweep
# when the real package is not installed (see hypothesis_compat.py).
from hypothesis_compat import given, settings, st

from repro.core import breadth_first_encode, paper_tree, random_tree, tree_depth
from repro.kernels.tree_eval import (
    PackedForest,
    PackedTree,
    forest_eval,
    forest_eval_fused,
    tree_eval,
    tree_eval_ref,
)
from repro.kernels.tree_eval.ops import choose_block_m


def _enc(depth=6, attrs=19, seed=0, balance=1.0):
    return breadth_first_encode(
        random_tree(n_attrs=attrs, n_classes=7, max_depth=depth, seed=seed, balance=balance)
    )


def _ref(enc, rec):
    return np.asarray(
        tree_eval_ref(
            jnp.asarray(rec),
            jnp.asarray(enc.attr_idx),
            jnp.asarray(enc.threshold),
            jnp.asarray(enc.child),
            jnp.asarray(enc.class_val),
            max_depth=max(tree_depth(enc), 1),
        )
    )


@pytest.mark.parametrize("algorithm,jump_mode", [
    ("speculative", "gather"),
    ("speculative", "onehot"),
    ("data_parallel", "gather"),
])
@pytest.mark.parametrize("m", [1, 7, 8, 100])
def test_kernel_matches_ref_shapes(algorithm, jump_mode, m):
    enc = _enc(depth=5, seed=2)
    rec = np.random.default_rng(m).normal(size=(m, 19)).astype(np.float32)
    out = np.asarray(tree_eval(rec, enc, algorithm=algorithm, jump_mode=jump_mode))
    assert np.array_equal(out, _ref(enc, rec))


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    enc = _enc(depth=4, seed=5)
    rec = jnp.asarray(
        np.random.default_rng(0).normal(size=(64, 19)), dtype=dtype
    )
    out = np.asarray(tree_eval(rec, enc, algorithm="speculative"))
    ref = _ref(enc, np.asarray(rec, np.float32))
    assert np.array_equal(out, ref)


@given(
    seed=st.integers(0, 60),
    depth=st.integers(1, 10),
    balance=st.floats(0.3, 1.0),
    m=st.integers(1, 200),
    attrs=st.sampled_from([1, 5, 19, 130]),
)
@settings(max_examples=20, deadline=None)
def test_kernel_property_sweep(seed, depth, balance, m, attrs):
    enc = breadth_first_encode(
        random_tree(n_attrs=attrs, n_classes=7, max_depth=depth, seed=seed, balance=balance)
    )
    rec = np.random.default_rng(seed + 1).normal(size=(m, attrs)).astype(np.float32)
    ref = _ref(enc, rec)
    for algorithm in ("speculative", "data_parallel"):
        out = np.asarray(tree_eval(rec, enc, algorithm=algorithm))
        assert np.array_equal(out, ref), algorithm


def test_large_tree_multi_lane_blocks():
    """N > 128 exercises the lane-padded multi-block tree layout."""
    enc = _enc(depth=8, seed=9)          # perfect depth-8: 511 nodes > 128
    assert enc.n_nodes > 128
    rec = np.random.default_rng(3).normal(size=(256, 19)).astype(np.float32)
    out = np.asarray(tree_eval(rec, enc, algorithm="speculative"))
    assert np.array_equal(out, _ref(enc, rec))


def test_paper_tree_kernel_all_paths():
    enc = breadth_first_encode(paper_tree())
    rec = np.random.default_rng(4).normal(size=(1024, 19)).astype(np.float32)
    ref = _ref(enc, rec)
    for alg, jm in [("speculative", "gather"), ("speculative", "onehot"), ("data_parallel", "gather")]:
        assert np.array_equal(np.asarray(tree_eval(rec, enc, algorithm=alg, jump_mode=jm)), ref)


def test_forest_eval_kernel():
    trees = [_enc(depth=d, seed=d) for d in (3, 5, 7)]
    packed = [PackedTree(t, 19) for t in trees]
    rec = np.random.default_rng(5).normal(size=(128, 19)).astype(np.float32)
    out = np.asarray(forest_eval(rec, packed))
    assert out.shape == (3, 128)
    for i, t in enumerate(trees):
        assert np.array_equal(out[i], _ref(t, rec))


@pytest.mark.parametrize("algorithm,jump_mode", [
    ("speculative", "gather"),
    ("speculative", "onehot"),
    ("data_parallel", "gather"),
])
@pytest.mark.parametrize("m", [1, 7, 100])
def test_fused_forest_kernel_matches_ref(algorithm, jump_mode, m):
    """The fused stacked-forest launch is bit-identical to tree-by-tree
    evaluation for every algorithm × jump mode × ragged record count."""
    from repro.core.forest import EncodedForest

    trees = [_enc(depth=d, seed=10 + d) for d in (2, 5, 7)]
    forest = EncodedForest(trees)
    rec = np.random.default_rng(m).normal(size=(m, 19)).astype(np.float32)
    out = np.asarray(
        forest_eval_fused(rec, forest, algorithm=algorithm, jump_mode=jump_mode)
    )
    assert out.shape == (3, m)
    assert out.dtype == np.int32
    for i in range(3):
        assert np.array_equal(out[i], _ref(forest.tree(i), rec))


def test_fused_forest_packed_reuse_and_block_m():
    """A prebuilt PackedForest (the dispatch fast path) and explicit block_m
    overrides produce the same bits as the one-shot call."""
    from repro.core.forest import EncodedForest

    trees = [_enc(depth=d, seed=20 + d) for d in (3, 6)]
    forest = EncodedForest(trees)
    rec = np.random.default_rng(9).normal(size=(130, 19)).astype(np.float32)
    ref = np.asarray(forest_eval_fused(rec, forest))
    packed = PackedForest(forest, 19)
    assert np.array_equal(np.asarray(forest_eval_fused(rec, packed)), ref)
    for bm in (8, 32):
        assert np.array_equal(
            np.asarray(forest_eval_fused(rec, packed, block_m=bm)), ref
        )


def test_block_m_vmem_model():
    """BlockSpec sizing: chosen tile must fit the VMEM budget model."""
    bm = choose_block_m(128, 128)
    assert bm >= 8 and bm & (bm - 1) == 0      # power of two, ≥ sublane
    bm_big_tree = choose_block_m(1024, 256)
    assert bm_big_tree <= bm
    bm_onehot = choose_block_m(256, 128, jump_mode="onehot")
    assert bm_onehot <= choose_block_m(256, 128, jump_mode="gather")


def test_explicit_block_m_override():
    enc = _enc(depth=4, seed=11)
    rec = np.random.default_rng(6).normal(size=(64, 19)).astype(np.float32)
    for bm in (8, 16, 64):
        out = np.asarray(tree_eval(rec, enc, algorithm="speculative", block_m=bm))
        assert np.array_equal(out, _ref(enc, rec))
