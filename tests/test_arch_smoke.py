"""Per-arch smoke tests: reduced same-family configs run one forward/train
step on CPU asserting output shapes + finite values, plus prefill→decode
consistency (a decode step after prefill must equal the teacher-forced
forward at that position)."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, cells_for
from repro.data.pipeline import pipeline_for
from repro.models.api import build_model
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step

SMOKE_SHAPE = ShapeConfig(name="smoke", seq_len=32, global_batch=2, kind="train")


def _batch(cfg):
    pipe = pipeline_for(cfg, SMOKE_SHAPE, seed=0)
    return jax.tree.map(jnp.asarray, pipe(0))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    logits, aux = model.forward(params, batch)
    v_pad = model.v_pad
    assert logits.shape == (2, 32, v_pad), arch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    step = make_train_step(model, TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    opt = adamw_init(params)
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum()) for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_decreases(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    step = jax.jit(make_train_step(model, TrainConfig(lr=3e-3, warmup_steps=1, total_steps=50)))
    opt = adamw_init(params)
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """decode(prefill(prompt)) logits == forward(prompt+token) logits."""
    cfg = get_smoke_config(arch)
    if cfg.embeds_input:
        pytest.skip("vlm stub consumes embeddings; decode parity covered by dense")
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(2, 17)).astype(np.int32)
    batch_full = {"tokens": jnp.asarray(toks)}
    if cfg.family == "audio":
        emb = jnp.asarray(rng.normal(size=(2, cfg.encoder.n_frames, cfg.d_model)), jnp.float32) * 0.02
        batch_full["embeds"] = emb
    # serving parity: tree-router MoE serves with HARD speculative routing in
    # both prefill and decode, so the reference forward must route hard too
    fwd_kwargs = {}
    if cfg.moe is not None and cfg.moe.router == "tree":
        fwd_kwargs["serve_hard_tree"] = True
    logits_full, _ = model.forward(params, batch_full, **fwd_kwargs)

    prompt = {k: (v[:, :16] if k == "tokens" else v) for k, v in batch_full.items()}
    lg_prefill, cache = model.prefill(params, prompt, max_len=24)
    np.testing.assert_allclose(
        np.asarray(lg_prefill[:, -1], np.float32),
        np.asarray(logits_full[:, 15], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    lg_dec, cache = model.decode_step(params, cache, {"tokens": jnp.asarray(toks[:, 16:17])})
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0], np.float32),
        np.asarray(logits_full[:, 16], np.float32),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_declared_exactly(arch):
    """Full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch.startswith("phi3.5"):
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (16, 2)
    if arch.startswith("granite"):
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (40, 8)
    if arch == "hymba-1.5b":
        assert cfg.ssm.state_dim == 16


def test_cells_for_skips_long500k_for_full_attention():
    dense = get_config("yi-6b")
    cells = {s.name: ok for s, ok, _ in cells_for(dense)}
    assert cells == {"train_4k": True, "prefill_32k": True,
                     "decode_32k": True, "long_500k": False}
    hybrid = get_config("hymba-1.5b")
    assert all(ok for _, ok, _ in cells_for(hybrid))
    ssm = get_config("xlstm-125m")
    assert all(ok for _, ok, _ in cells_for(ssm))
