"""repro.dist: planner vs the closed-form mesh cost model, sharded execution.

Planner tests run in-process (pure host math).  Executor tests that need a
real multi-device mesh run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (jax locks the device
count at first init), mirroring ``test_distributed.py``.

The load-bearing property: whatever decomposition the planner picks,
``eval_forest_sharded`` must be bit-identical to ``eval_forest_tuned`` —
sharding is purely a performance decision.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.analysis import CostModel
from repro.dist import (
    ForestWorkload,
    MeshCostModel,
    ShardPlan,
    enumerate_plans,
    make_plan,
    plan_forest,
    predicted_plan_time,
    shard_extents,
)

from hypothesis_compat import given, settings, st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout=420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def wl(m=4096, t=16, n=31, a=19, depth=11, d_mu=6.0) -> ForestWorkload:
    return ForestWorkload(m=m, n_trees=t, n_nodes=n, n_attrs=a, depth=depth, d_mu=d_mu)


# ---------------------------------------------------------------------------
# Planner vs the closed-form model
# ---------------------------------------------------------------------------


class TestPlanner:
    def test_plan_cost_matches_closed_form(self):
        """Every enumerated plan carries exactly predicted_plan_time(R, G)."""
        mcm = MeshCostModel()
        for p in enumerate_plans(wl(), 8, mcm):
            t, alg = predicted_plan_time(wl(), p.record_shards, p.tree_shards, mcm)
            assert p.predicted == t
            assert p.algorithm == alg

    def test_plan_forest_is_argmin(self):
        mcm = MeshCostModel()
        plans = enumerate_plans(wl(), 8, mcm)
        chosen = plan_forest(wl(), 8, mesh_cost=mcm)
        assert chosen.predicted == min(p.predicted for p in plans)

    def test_single_device_degenerates(self):
        p = plan_forest(wl(), 1)
        assert (p.record_shards, p.tree_shards) == (1, 1)
        assert p.decomposition == "single"

    def test_decomposition_classification(self):
        assert ShardPlan(8, 1, "data_parallel", 0.0).decomposition == "records"
        assert ShardPlan(1, 8, "data_parallel", 0.0).decomposition == "trees"
        assert ShardPlan(4, 2, "data_parallel", 0.0).decomposition == "hybrid"
        assert ShardPlan(1, 1, "data_parallel", 0.0).decomposition == "single"

    def test_forced_decomposition_filter(self):
        for deco in ("records", "trees", "hybrid"):
            p = plan_forest(wl(), 8, decomposition=deco)
            assert p.decomposition == deco

    def test_more_devices_never_predicted_slower(self):
        """With a zero-overhead model, doubling D cannot raise the optimum
        (the D-device plan set contains the D/2 one)."""
        mcm = MeshCostModel(sigma_rec=0.0, sigma_tree=0.0, sigma_out=0.0, gamma_launch=0.0)
        prev = float("inf")
        for d in (1, 2, 4, 8, 16):
            t = plan_forest(wl(), d, mesh_cost=mcm).predicted
            assert t <= prev + 1e-9, (d, t, prev)
            prev = t

    def test_feasibility_clamps(self):
        """Never more record shards than records or tree shards than trees."""
        tiny = wl(m=3, t=2)
        for p in enumerate_plans(tiny, 8):
            assert p.record_shards <= 3
            assert p.tree_shards <= 2
        chosen = plan_forest(tiny, 8)
        assert chosen.n_devices <= 6

    def test_transfer_crossover_records_vs_trees(self):
        """The §3.6-style transmission terms drive the decomposition choice:
        record-heavy workloads shard records (tree sharding would re-send
        the full M·A record array to every device row), tree-heavy
        workloads shard trees (record sharding re-broadcasts the forest)."""
        mcm = MeshCostModel(sigma_rec=1.0, sigma_tree=1.0, gamma_launch=0.0)
        record_heavy = wl(m=65536, t=4)
        tree_heavy = wl(m=64, t=512)
        assert plan_forest(record_heavy, 4, mesh_cost=mcm).decomposition == "records"
        assert plan_forest(tree_heavy, 4, mesh_cost=mcm).decomposition == "trees"

    def test_shard_extents_cover_workload(self):
        m_s, t_s = shard_extents(wl(m=1000, t=10), 8, 2)
        assert m_s * 8 >= 1000 and t_s * 2 >= 10

    def test_algorithm_follows_crossover(self):
        """The per-shard algorithm is the §3.6 winner at the shard shape:
        tiny record groups + deep traversals → speculative, and vice versa
        (same contract as repro.tune's heuristic, equation (1))."""
        mcm = MeshCostModel(cm=CostModel(t_e=1.0, t_c=1.0), p_device=1.0)
        deep = wl(n=7, depth=30, d_mu=30.0)     # p_group=(7-1)/2=3 < crossover(30)
        shallow = wl(n=1023, depth=10, d_mu=2.0)
        assert make_plan(deep, 2, 1, mcm).algorithm == "speculative"
        assert make_plan(shallow, 2, 1, mcm).algorithm == "data_parallel"

    @given(
        m=st.integers(1, 100_000),
        t=st.integers(1, 64),
        d=st.sampled_from([1, 2, 4, 6, 8]),
        d_mu=st.floats(1.0, 16.0),
    )
    @settings(max_examples=20, deadline=None)
    def test_planner_properties_randomized(self, m, t, d, d_mu):
        w = wl(m=m, t=t, d_mu=d_mu)
        plans = enumerate_plans(w, d)
        assert any(p.record_shards == p.tree_shards == 1 for p in plans)
        chosen = plan_forest(w, d)
        assert chosen.predicted <= min(p.predicted for p in plans) + 1e-12
        for p in plans:
            assert p.n_devices <= d or p.n_devices == 1
            assert p.record_shards <= max(m, 1)
            assert p.tree_shards <= t
            assert p.predicted > 0.0


# ---------------------------------------------------------------------------
# Single-device fallback (in-process: the default CPU host has one device)
# ---------------------------------------------------------------------------


class TestSingleDeviceFallback:
    def test_bit_match_and_no_shard_map(self, tmp_path):
        out = run_with_devices("""
            import numpy as np, tempfile, pathlib
            from repro.core import (EncodedForest, breadth_first_encode, random_tree,
                                    eval_forest_tuned, eval_forest_sharded)
            from repro.dist import ShardedForestEvaluator
            from repro.tune import TuneCache

            trees = [breadth_first_encode(random_tree(n_attrs=7, n_classes=5,
                                                      max_depth=d, seed=d))
                     for d in (2, 5, 8)]
            forest = EncodedForest(trees)
            rec = np.random.default_rng(3).normal(size=(333, 7)).astype(np.float32)
            cache = TuneCache(pathlib.Path(tempfile.mkdtemp()) / 'c.json')
            ref = np.asarray(eval_forest_tuned(forest, rec, cache=cache))
            ev = ShardedForestEvaluator(forest, cache=cache)
            out = np.asarray(ev(rec))
            assert np.array_equal(ref, out)
            # the planner degraded to the plain tuned path: no mesh, no
            # shard_map program was ever built
            assert ev.plan.decomposition == 'single'
            assert ev.mesh is None and ev.record_sharding is None
            assert not ev._fast
            out2 = np.asarray(eval_forest_sharded(forest, rec, cache=cache))
            assert np.array_equal(ref, out2)
            print('OK')
        """, n_devices=1)
        assert "OK" in out


# ---------------------------------------------------------------------------
# Sharded execution on a forced 8-device host mesh
# ---------------------------------------------------------------------------


def test_sharded_matches_tuned_all_decompositions():
    """Acceptance: record-, tree- and hybrid-sharded plans are numerically
    identical to eval_forest_tuned on an 8-device host mesh."""
    out = run_with_devices("""
        import numpy as np, jax, tempfile, pathlib
        from repro.core import (EncodedForest, breadth_first_encode, random_tree,
                                eval_forest_tuned, eval_forest_sharded)
        from repro.dist import ShardedForestEvaluator
        from repro.tune import TuneCache

        assert jax.device_count() == 8
        trees = [breadth_first_encode(random_tree(n_attrs=9, n_classes=6,
                                                  max_depth=3 + (i % 6), seed=i))
                 for i in range(12)]
        forest = EncodedForest(trees)
        rec = np.random.default_rng(1).normal(size=(1000, 9)).astype(np.float32)
        cache = TuneCache(pathlib.Path(tempfile.mkdtemp()) / 'c.json')
        ref = np.asarray(eval_forest_tuned(forest, rec, cache=cache))
        for deco in ('records', 'trees', 'hybrid', None):
            ev = ShardedForestEvaluator(forest, decomposition=deco, cache=cache)
            out = np.asarray(ev(rec))
            assert np.array_equal(ref, out), deco
            if deco is not None:
                assert ev.plan.decomposition == deco
                assert ev.mesh is not None      # genuinely lowered via shard_map
        # odd small M exercises the divisibility padding
        for m in (7, 3, 2):
            r = rec[:m]
            got = np.asarray(eval_forest_sharded(forest, r,
                                                 decomposition='hybrid', cache=cache))
            assert np.array_equal(ref[:, :m], got), m
        print('OK')
    """)
    assert "OK" in out


def test_stream_chunker_and_serve_engine():
    """Chunked streaming equals the monolithic result; per-chunk latency is
    recorded; ForestServeEngine round-trips requests with majority votes."""
    out = run_with_devices("""
        import numpy as np, jax.numpy as jnp, tempfile, pathlib
        from repro.core import (EncodedForest, breadth_first_encode, random_tree,
                                eval_forest_tuned, eval_serial, majority_vote)
        from repro.dist import ShardedForestEvaluator, StreamingChunker
        from repro.serve import ForestServeEngine, TreeRequest
        from repro.tune import TuneCache

        trees = [breadth_first_encode(random_tree(n_attrs=9, n_classes=6,
                                                  max_depth=2 + (i % 5), seed=i))
                 for i in range(8)]
        forest = EncodedForest(trees)
        rec = np.random.default_rng(2).normal(size=(1500, 9)).astype(np.float32)
        cache = TuneCache(pathlib.Path(tempfile.mkdtemp()) / 'c.json')
        ref = np.asarray(eval_forest_tuned(forest, rec, cache=cache))

        ev = ShardedForestEvaluator(forest, cache=cache)
        ck = StreamingChunker(ev, chunk_records=256)
        out = ck.eval(rec)
        assert np.array_equal(ref, out)
        assert ck.stats.chunks == 6               # ceil(1500/256)
        assert ck.stats.records == 1500
        assert len(ck.stats.chunk_ms) == 6
        assert all(l > 0 for l in ck.stats.chunk_ms)

        rng = np.random.default_rng(5)
        reqs = [TreeRequest(uid=i, records=rng.normal(
                    size=(int(rng.integers(1, 200)), 9)).astype(np.float32))
                for i in range(7)]
        eng = ForestServeEngine(forest, max_batch=512, chunk_records=128,
                                n_classes=6, cache=cache)
        eng.run(reqs)
        assert eng.stats.waves >= 2
        assert eng.stats.chunks == len(eng.stats.chunk_ms) >= eng.stats.waves
        assert eng.stats.records == sum(r.records.shape[0] for r in reqs)
        for r in reqs:
            per = np.stack([np.asarray(eval_serial(forest.tree(i), r.records))
                            for i in range(forest.n_trees)])
            want = np.asarray(majority_vote(jnp.asarray(per), 6))
            assert r.done and np.array_equal(r.out, want), r.uid
        print('OK')
    """)
    assert "OK" in out


def test_executor_resolves_through_tune_cache():
    """The per-shard kernel choice flows through the repro.tune cache: a
    pre-seeded winner at the shard shape is what the executor picks up."""
    out = run_with_devices("""
        import numpy as np, tempfile, pathlib
        from repro.core import (EncodedForest, breadth_first_encode, random_tree,
                                eval_forest_tuned)
        from repro.dist import ShardedForestEvaluator, ShardPlan
        from repro.tune import TuneCache, TuneEntry, WorkloadShape

        trees = [breadth_first_encode(random_tree(n_attrs=9, n_classes=6,
                                                  max_depth=6, seed=i))
                 for i in range(8)]
        forest = EncodedForest(trees)
        rec = np.random.default_rng(1).normal(size=(1024, 9)).astype(np.float32)
        cache = TuneCache(pathlib.Path(tempfile.mkdtemp()) / 'c.json')
        plan = ShardPlan(record_shards=4, tree_shards=2,
                         algorithm='data_parallel', predicted=0.0)
        # seed the cache at the shard shape (M/R=256) with a specific winner
        shard_shape = WorkloadShape(m=256, n_nodes=forest.n_nodes, n_attrs=9,
                                    depth=forest.max_depth)
        cache.store(shard_shape.key(),
                    TuneEntry(variant='jnp_speculative_gather',
                              params={'jumps_per_round': 3}, median_ms=0.1))
        ev = ShardedForestEvaluator(forest, plan=plan, cache=cache)
        out = np.asarray(ev(rec))
        cand, source = ev.resolved
        assert source == 'cache', source
        assert cand.variant == 'jnp_speculative_gather'
        assert cand.param_dict == {'jumps_per_round': 3}
        ref = np.asarray(eval_forest_tuned(forest, rec, cache=cache))
        assert np.array_equal(ref, out)
        print('OK')
    """)
    assert "OK" in out
